"""The paper's technique end-to-end on the TPU-pod adaptation: a
multi-tenant cluster where training/serving jobs of the 10 assigned
architectures arrive over time, FAR molds each to a pod-slice count and
schedules batches, seams are overlapped (§4), and a mid-run pod-slice
failure triggers elastic degradation + checkpoint restarts.  A second act
runs the arrival-driven :class:`SchedulingService`: jobs trickle in with
Poisson gaps, accumulate within a latency budget, flush through
multi-batch FAR and fall back to greedy placement when the stream thins.

  PYTHONPATH=src python examples/multibatch_cluster.py
"""

import itertools
import sys

import numpy as np

sys.path.insert(0, "src")

from repro.configs import ARCHS
from repro.core import SchedulerConfig, SchedulingService, validate_schedule
from repro.core.device_spec import TPU_POD_256
from repro.core.synth import generate_tasks, workload
from repro.models.config import SHAPES
from repro.runtime import ClusterManager, Fault, Slowdown


def main() -> None:
    mgr = ClusterManager(TPU_POD_256, concat_mode="auto")
    shapes = [SHAPES["train_4k"], SHAPES["decode_32k"],
              SHAPES["prefill_32k"]]
    stream = itertools.cycle(itertools.product(ARCHS.values(), shapes))

    print(f"pod: {mgr.spec.name} = {mgr.spec.n_slices} slices x "
          f"{mgr.spec.chips_per_slice} chips\n")

    for batch_no in range(4):
        for _ in range(8):
            cfg, shape = next(stream)
            mgr.submit(mgr.new_job(cfg, shape, steps=100 + 50 * batch_no))
        faults, slows = [], []
        if batch_no == 2:  # inject a pod-slice failure mid-batch
            t = mgr.tail.release["reconfig"] + 200.0
            faults = [Fault(t, 0, 5)]
            slows = [Slowdown(0, 1, 1.15)]
        rec = mgr.run_batch(faults=faults, slowdowns=slows)
        r = rec.result
        print(f"batch {batch_no}: {len(rec.jobs)} jobs on {rec.spec_name} "
              f"-> makespan {r.makespan:9.1f}s  finished {len(r.finished):2d}  "
              f"killed {len(r.killed)}  stragglers {len(r.stragglers)}")
        if r.killed:
            print(f"   slice failure -> spec degraded to "
                  f"{mgr.spec.n_slices} slices; "
                  f"{len([j for j in mgr.queue if 'restart' in (j.name or '')])} "
                  f"jobs restarting from checkpoints")
        for it in sorted(rec.schedule.items, key=lambda x: x.begin)[:4]:
            print(f"     {it.task.name:<40s} slices={it.size} "
                  f"[{it.begin:9.1f}, {it.end:9.1f})")
        if len(rec.schedule.items) > 4:
            print(f"     ... {len(rec.schedule.items) - 4} more")
    print(f"\ncluster utilization: {mgr.utilization():.1%} "
          f"(busy slice-seconds / available)")


def serve_demo() -> None:
    """Latency-budget online serving on the same pod, 2-pod pool."""
    svc = SchedulingService(
        TPU_POD_256,
        policy="far",
        config=SchedulerConfig(max_wait_s=10.0, max_batch=12),
        pool_size=2,
    )
    print(f"\n== SchedulingService on a {svc.spec.name} pool "
          f"({svc.spec.n_slices} slices) ==")
    cfg = workload("mixed", "wide", svc.spec)
    tasks = generate_tasks(40, svc.spec, cfg, seed=7)
    rng = np.random.default_rng(7)
    # dense burst, then a sparse trickle that falls back to greedy placement
    gaps = np.concatenate([rng.exponential(1.5, 30), rng.exponential(60.0, 10)])
    for task, arrival in zip(tasks, np.cumsum(gaps)):
        svc.submit(task, arrival=float(arrival))
    combined = svc.drain()
    validate_schedule(combined, tasks, check_reconfig=False)
    delays = svc.stats.queue_delays()
    print(f"{svc.stats.submitted} tasks -> {svc.stats.batches} FAR batches + "
          f"{svc.stats.online_placements} greedy placements, "
          f"makespan {svc.makespan:.1f}s")
    print(f"queue delay p50 {np.percentile(delays, 50):.1f}s "
          f"p95 {np.percentile(delays, 95):.1f}s "
          f"(budget {svc.config.max_wait_s:.0f}s)")


if __name__ == "__main__":
    main()
    serve_demo()
