"""Deadline-aware serving on an A100: admission control + tail re-planning.

A bursty Poisson stream of moldable tasks is fed to the
:class:`~repro.core.service.SchedulingService` three times —

  1. plain latency-budget batching (the PR-2 baseline),
  2. with tail re-planning (queued-but-unstarted placements are pulled
     back and re-planned together with each flush's arrivals),
  3. re-planning plus ``admission="reject"`` (provably-unmeetable
     deadlines are refused at submit time instead of missing silently)

— and the makespans, deadline miss-rates and replan wins are compared.

  PYTHONPATH=src python examples/serve_deadlines.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import A100, SchedulerConfig, SchedulingService
from repro.core.synth import generate_tasks, workload


def run(tasks, arrivals, deadlines, replan=False, admission="none"):
    svc = SchedulingService(
        A100,
        policy="far",
        config=SchedulerConfig(
            max_wait_s=6.0, max_batch=12,
            replan=replan, admission=admission,
        ),
    )
    for t, a in zip(tasks, arrivals):
        svc.submit(t, arrival=float(a), deadline=deadlines[t.id])
    svc.drain()
    return svc


def main() -> None:
    n = 48
    tasks = generate_tasks(n, A100, workload("mixed", "wide", A100), seed=7)
    rng = np.random.default_rng(7)
    arrivals = np.cumsum(rng.exponential(1.2, size=n))
    deadlines = {
        t.id: float(a) + 6.0 + float(s) * min(t.times.values())
        for t, a, s in zip(tasks, arrivals, rng.uniform(2.0, 10.0, size=n))
    }

    plain = run(tasks, arrivals, deadlines)
    re = run(tasks, arrivals, deadlines, replan=True)
    strict = run(tasks, arrivals, deadlines, replan=True,
                 admission="reject")

    print(f"stream: {n} tasks over {arrivals[-1]:.0f}s, "
          f"{plain.stats.batches} batch flushes\n")
    for name, svc in [("plain", plain), ("replan", re),
                      ("replan+admission", strict)]:
        rep = svc.deadline_report()
        print(f"{name:>17}: makespan {svc.makespan:7.1f}s   "
              f"miss {100 * rep['miss_rate']:5.1f}%  "
              f"rejected {len(rep['rejected']):2d}  "
              f"replan wins {svc.stats.replan_wins}"
              f" (pulled back {svc.stats.withdrawn} placements)")
    assert re.makespan <= plain.makespan + 1e-9  # the shadow guarantee
    saved = plain.makespan - re.makespan
    print(f"\nre-planning saved {saved:.1f}s "
          f"({100 * saved / plain.makespan:.1f}% of the plain makespan) "
          f"without ever moving a running task.")


if __name__ == "__main__":
    main()
