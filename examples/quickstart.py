"""Quickstart: schedule a batch of tasks on an A100 with FAR.

  PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's core loop in 30 lines: profile tasks per instance
size, run the 3-phase FAR algorithm through the policy registry, print the
resulting Gantt chart and the comparison against every registered baseline
policy (one loop over names — paper Fig. 12).
"""

import sys

sys.path.insert(0, "src")

from repro.core import A100, SchedulerConfig, get_policy, rho, validate_schedule
from repro.core.baselines import partition_whole
from repro.core.rodinia import rodinia_tasks


def gantt(schedule, width: int = 72) -> str:
    span = schedule.makespan
    lines = []
    for it in sorted(schedule.items, key=lambda x: x.begin):
        lo = int(it.begin / span * width)
        hi = max(lo + 1, int(it.end / span * width))
        slices = f"S{it.node.start}-{it.node.start + it.node.size - 1}"
        bar = " " * lo + "█" * (hi - lo)
        lines.append(f"  {it.task.name:>15s} {slices:>6s} |{bar:<{width}}|")
    return "\n".join(lines)


def main() -> None:
    tasks = rodinia_tasks(A100)
    cfg = SchedulerConfig()
    result = get_policy("far").plan(tasks, A100, cfg)
    validate_schedule(result.schedule, tasks)
    far = result.extras["far"]

    print(f"FAR on A100: {len(tasks)} tasks, makespan "
          f"{result.makespan:.2f}s, rho={rho(result, tasks):.3f} "
          f"(paper: 1.22), scheduled in {result.elapsed_s * 1e3:.1f} ms")
    print(f"phase 2 winner: allocation #{far.winner_index} of "
          f"{far.family_size}; phase 3: {far.refine_stats.moves} "
          f"moves, {far.refine_stats.swaps} swaps\n")
    print(gantt(result.schedule))

    print("\nversus (paper Fig. 12):")
    baselines = [
        ("MISO-OPT", "miso", cfg),
        ("FixPart(1x7)", "fix-part", cfg),
        ("FixPartBest", "fix-part-best", cfg),
        ("FixPart(7)", "fix-part",
         cfg.replace(partition=partition_whole(A100))),
    ]
    for label, name, c in baselines:
        plan = get_policy(name).plan(tasks, A100, c)
        print(f"  {label:<15s} {plan.makespan / result.makespan:.2f}x")


if __name__ == "__main__":
    main()
