"""Quickstart: schedule a batch of tasks on an A100 with FAR.

  PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's core loop in 30 lines: profile tasks per instance
size, run the 3-phase FAR algorithm, print the resulting Gantt chart and
the comparison against MISO-OPT / fixed partitions.
"""

import sys

sys.path.insert(0, "src")

from repro.core import A100, rho, schedule_batch, validate_schedule
from repro.core.baselines import (
    fix_part, fix_part_best, miso_opt, partition_of_ones, partition_whole,
)
from repro.core.rodinia import rodinia_tasks


def gantt(schedule, width: int = 72) -> str:
    span = schedule.makespan
    lines = []
    for it in sorted(schedule.items, key=lambda x: x.begin):
        lo = int(it.begin / span * width)
        hi = max(lo + 1, int(it.end / span * width))
        slices = f"S{it.node.start}-{it.node.start + it.node.size - 1}"
        bar = " " * lo + "█" * (hi - lo)
        lines.append(f"  {it.task.name:>15s} {slices:>6s} |{bar:<{width}}|")
    return "\n".join(lines)


def main() -> None:
    tasks = rodinia_tasks(A100)
    result = schedule_batch(tasks, A100)
    validate_schedule(result.schedule, tasks)

    print(f"FAR on A100: {len(tasks)} tasks, makespan "
          f"{result.makespan:.2f}s, rho={rho(result, tasks):.3f} "
          f"(paper: 1.22), scheduled in {result.elapsed_s * 1e3:.1f} ms")
    print(f"phase 2 winner: allocation #{result.winner_index} of "
          f"{result.family_size}; phase 3: {result.refine_stats.moves} "
          f"moves, {result.refine_stats.swaps} swaps\n")
    print(gantt(result.schedule))

    far = result.makespan
    print("\nversus (paper Fig. 12):")
    print(f"  MISO-OPT        {miso_opt(tasks, A100).makespan / far:.2f}x")
    print(f"  FixPart(1x7)    "
          f"{fix_part(tasks, A100, partition_of_ones(A100)).makespan / far:.2f}x")
    print(f"  FixPartBest     "
          f"{fix_part_best(tasks, A100)[0].makespan / far:.2f}x")
    print(f"  FixPart(7)      "
          f"{fix_part(tasks, A100, partition_whole(A100)).makespan / far:.2f}x")


if __name__ == "__main__":
    main()
