"""Batched serving example: prefill a batch of prompts, decode with a KV
cache, report throughput.

  PYTHONPATH=src python examples/serve_batch.py --arch gemma3-12b
  PYTHONPATH=src python examples/serve_batch.py --arch zamba2-2.7b --gen 64
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.serve import serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b",
                    help="any assigned architecture (smoke config)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    out = serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
                gen=args.gen, smoke=True)
    print(f"generated token matrix: {out['tokens'].shape}; "
          f"throughput {out['tokens_per_s']:.1f} tok/s "
          f"(CPU smoke config — the same code path drives a pod)")


if __name__ == "__main__":
    main()
