"""End-to-end training driver: a ~100M-parameter LM for a few hundred
steps on local devices, with checkpointing and restart.

  PYTHONPATH=src python examples/train_e2e.py                 # ~100M, 200 steps
  PYTHONPATH=src python examples/train_e2e.py --quick         # tiny, 40 steps

Interrupt it and run again with the same --ckpt-dir: it resumes from the
last checkpoint and reproduces the uninterrupted run exactly (the data
pipeline is a pure function of the step index).
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import gemma_2b
from repro.launch import train as train_mod
from repro.models.config import ArchConfig

# ~100M-parameter decoder LM (gemma-style family)
LM_100M = ArchConfig(
    name="lm-100m",
    family="dense",
    n_layers=12,
    d_model=512,
    n_heads=8,
    n_kv_heads=2,
    d_ff=2048,
    vocab_size=32768,
    activation="geglu",
    tie_embeddings=True,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_e2e")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    if args.quick:
        cfg = dataclasses.replace(
            LM_100M, n_layers=2, d_model=128, d_ff=256, vocab_size=2048
        )
        steps = min(args.steps, 40)
    else:
        cfg = LM_100M
        steps = args.steps

    print(f"training {cfg.name}: {cfg.param_count() / 1e6:.0f}M params, "
          f"{steps} steps, batch {args.batch} x seq {args.seq}, "
          f"{len(jax.devices())} device(s)")

    # register the inline config so the train driver can build it
    import repro.configs as configs

    configs.ARCHS[cfg.name] = cfg
    configs.SMOKES[cfg.name] = cfg

    out = train_mod.train(
        cfg.name, steps=steps, batch=args.batch, seq=args.seq,
        smoke=True, ckpt_dir=args.ckpt_dir, ckpt_every=50,
    )
    print(f"loss: {out['first_loss']:.3f} -> {out['last_loss']:.3f} "
          f"over {out['steps']} steps")


if __name__ == "__main__":
    main()
