"""Production mesh construction.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state.  Shapes:

  single pod : (16, 16)      axes ("data", "model")          = 256 chips
  multi-pod  : (2, 16, 16)   axes ("pod", "data", "model")   = 512 chips

The ``pod`` axis is an outer data-parallel dimension (gradient all-reduce
over DCI); ``model`` carries TP/EP/SP collectives over ICI.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_submesh(devices, data: int, model: int, pod: int = 1):
    """Mesh over an explicit device subset (FAR pod-slice instances)."""
    import numpy as np

    arr = np.asarray(devices)
    if pod > 1:
        arr = arr.reshape(pod, data, model)
        return jax.sharding.Mesh(arr, ("pod", "data", "model"))
    arr = arr.reshape(data, model)
    return jax.sharding.Mesh(arr, ("data", "model"))


def mesh_shape_dict(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
