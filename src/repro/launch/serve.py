"""Serving driver: batched prefill + decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get, get_smoke
from repro.launch.mesh import mesh_shape_dict
from repro.models.config import ShapeConfig
from repro.models.model import build_model
from repro.parallel.sharding import make_rules
from repro.parallel.steps import make_decode_step, make_prefill_step


def serve(
    arch: str,
    batch: int = 4,
    prompt_len: int = 64,
    gen: int = 16,
    smoke: bool = True,
    mesh=None,
    temperature: float = 0.0,
    seed: int = 0,
    log_fn=print,
) -> dict:
    cfg = get_smoke(arch) if smoke else get(arch)
    model = build_model(cfg)
    if mesh is None:
        n = len(jax.devices())
        mesh = jax.make_mesh((n, 1), ("data", "model"))
    rules = make_rules(cfg, mesh_shape_dict(mesh), fsdp=False)
    shape = ShapeConfig("serve", prompt_len, batch, "prefill")

    pre = make_prefill_step(model, rules, mesh, shape)
    dec = make_decode_step(
        model, rules, mesh, ShapeConfig("serve", prompt_len, batch, "decode")
    )
    rng = np.random.default_rng(seed)
    prompts = rng.integers(
        0, cfg.vocab_size, size=(batch, prompt_len)
    ).astype(np.int32)

    with mesh:
        prefill_fn = jax.jit(pre.fn, in_shardings=pre.in_shardings,
                             out_shardings=pre.out_shardings)
        decode_fn = jax.jit(dec.fn, in_shardings=dec.in_shardings,
                            out_shardings=dec.out_shardings,
                            donate_argnums=dec.donate_argnums)
        params = model.init(jax.random.key(0))
        batch_in = {"tokens": jnp.asarray(prompts)}
        if cfg.is_encoder_decoder:
            batch_in["frames"] = jnp.zeros(
                (batch, cfg.encoder_frames, cfg.d_model), jnp.bfloat16
            )
        t0 = time.time()
        logits, cache = prefill_fn(params, batch_in)
        prefill_s = time.time() - t0

        key = jax.random.key(seed)

        def sample(lg, key):
            if temperature <= 0:
                return jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
            return jax.random.categorical(
                key, lg[:, -1].astype(jnp.float32) / temperature, axis=-1
            ).astype(jnp.int32)

        key, sub = jax.random.split(key)
        token = sample(logits, sub)[:, None]
        generated = [np.asarray(token)]
        t1 = time.time()
        for _ in range(gen - 1):
            logits, cache = decode_fn(params, cache, token)
            key, sub = jax.random.split(key)
            token = sample(logits, sub)[:, None]
            generated.append(np.asarray(token))
        decode_s = time.time() - t1
    tokens = np.concatenate(generated, axis=1)
    tput = batch * (gen - 1) / max(decode_s, 1e-9)
    log_fn(f"[serve] prefill {prompt_len}tok×{batch} in {prefill_s*1e3:.0f}ms; "
           f"decode {gen-1} steps at {tput:.1f} tok/s")
    return {
        "tokens": tokens,
        "prefill_s": prefill_s,
        "decode_s": decode_s,
        "tokens_per_s": tput,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
          gen=args.gen, smoke=not args.full)


if __name__ == "__main__":
    main()
