"""Training driver.

Runs real steps on whatever devices exist (CPU smoke configs here; the
same code path drives a pod — the mesh shape is the only difference).
Supports checkpoint/restart (``--ckpt-dir``): on start it resumes from the
latest complete checkpoint, and the deterministic data pipeline replays
from the restored step, so a killed run continues bit-exact.

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import ckpt as ckpt_lib
from repro.configs import get, get_smoke
from repro.data import SyntheticTokens
from repro.launch.mesh import mesh_shape_dict
from repro.models.config import ShapeConfig, input_specs
from repro.models.model import build_model
from repro.optim import wsd_schedule
from repro.parallel.sharding import make_rules
from repro.parallel.steps import init_train_state, make_train_step


def train(
    arch: str,
    steps: int = 20,
    batch: int = 8,
    seq: int = 128,
    smoke: bool = True,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    lr: float = 3e-4,
    mesh=None,
    log_every: int = 10,
    data_seed: int = 0,
    compress_grads: bool = False,
    total_steps: int | None = None,
    microbatches: int = 1,
    log_fn=print,
) -> dict:
    cfg = get_smoke(arch) if smoke else get(arch)
    model = build_model(cfg)
    shape = ShapeConfig("custom", seq, batch, "train")
    total_steps = total_steps or steps  # LR schedule horizon (for restarts)

    if mesh is None:
        n = len(jax.devices())
        mesh = jax.make_mesh((n, 1), ("data", "model"))
    rules = make_rules(cfg, mesh_shape_dict(mesh), fsdp=False)

    bundle = make_train_step(
        model, rules, mesh, shape,
        lr_schedule=wsd_schedule(lr, warmup=min(20, total_steps // 10 + 1),
                                 total=total_steps),
        compress_grads=compress_grads,
        microbatches=microbatches,
    )
    with mesh:
        step_fn = jax.jit(
            bundle.fn,
            in_shardings=bundle.in_shardings,
            out_shardings=bundle.out_shardings,
            donate_argnums=bundle.donate_argnums,
        )

        start_step = 0
        state = None
        if ckpt_dir is not None:
            latest = ckpt_lib.latest_step(ckpt_dir)
            if latest is not None:
                like = jax.eval_shape(
                    lambda: init_train_state(model, jax.random.key(0))
                )
                state, meta = ckpt_lib.restore_checkpoint(ckpt_dir, like)
                if "ef" in dict(bundle.in_shardings[0]) and "ef" not in state:
                    pass
                start_step = meta["step"]
                log_fn(f"[train] resumed from step {start_step}")
        if state is None:
            state = init_train_state(model, jax.random.key(0))
            if compress_grads:
                from repro.parallel.compression import ef_init
                state["ef"] = ef_init(state["params"])

        source = SyntheticTokens(cfg.padded_vocab(), seq, batch,
                                 seed=data_seed)
        losses = []
        t0 = time.time()
        for i in range(start_step, steps):
            np_batch = source.batch(i)
            jb = {k: jnp.asarray(v) for k, v in np_batch.items()}
            if cfg.is_encoder_decoder:
                jb["frames"] = jnp.zeros(
                    (batch, cfg.encoder_frames, cfg.d_model), jnp.bfloat16
                )
            state, metrics = step_fn(state, jb)
            loss = float(metrics["loss"])
            losses.append(loss)
            if (i + 1) % log_every == 0 or i == steps - 1:
                log_fn(f"[train] step {i+1:5d} loss={loss:.4f} "
                       f"gnorm={float(metrics['grad_norm']):.3f} "
                       f"({(time.time()-t0)/max(i+1-start_step,1)*1e3:.0f} ms/step)")
            if ckpt_dir is not None and (i + 1) % ckpt_every == 0:
                ckpt_lib.save_checkpoint(ckpt_dir, i + 1, state)
        if ckpt_dir is not None:
            ckpt_lib.save_checkpoint(ckpt_dir, steps, state)
    return {
        "losses": losses,
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "steps": steps,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="use the full config (pod-scale!) not the smoke")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    out = train(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        smoke=not args.full, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, lr=args.lr,
        compress_grads=args.compress_grads, microbatches=args.microbatches,
    )
    print(f"[train] done: loss {out['first_loss']:.3f} -> "
          f"{out['last_loss']:.3f} over {out['steps']} steps")


if __name__ == "__main__":
    main()
