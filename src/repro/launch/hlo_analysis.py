"""Optimised-HLO analysis for the roofline terms.

``compiled.cost_analysis()`` counts a ``while`` body **once**, but every
model here scans over layer groups, so FLOPs/bytes/collective counts must
be multiplied by loop trip counts.  This module parses the optimised HLO
text into computations, builds the call graph (``fusion``/``call``/
``while``/``conditional`` edges), reads each while's trip count from the
comparison constant in its condition computation, and propagates
multipliers from ENTRY.

Per-op accounting (per device, SPMD-partitioned shapes):

  * flops: ``dot`` ops — 2 · |result| · contracted-dim size (plus batch
    handled implicitly via the result shape); convolutions 2·|out|·K·Cin.
  * bytes: operand + result sizes of compute/data ops at fusion
    granularity (a fusion is one memory pass — roofline-level estimate).
  * collectives: bytes moved per device with per-primitive factors
    (ring all-reduce moves ~2× the payload, others ~1×).

The estimates are cross-checked against ``cost_analysis`` in the report
(the latter is a lower bound since loops are counted once).
"""

from __future__ import annotations

import re
from typing import Iterable

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "u4": 1, "s4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}
COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# bytes-on-the-wire factor per payload byte (ring algorithms, large n)
_COLL_FACTOR = {
    "all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype, 4)
    if not dims:
        return b
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * b


def _all_shape_bytes(text: str) -> int:
    return sum(_shape_bytes(m.group(1), m.group(2))
               for m in _SHAPE_RE.finditer(text))


def _result_of(line: str) -> tuple[str, str] | None:
    m = re.search(r"=\s*\(?([a-z0-9]+)\[([0-9,]*)\]", line)
    if m:
        return m.group(1), m.group(2)
    return None


def _split_computations(hlo: str) -> dict[str, list[str]]:
    """Computation name -> body lines (incl. the header for param types)."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped:
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            if m:
                cur = m.group(1)
                comps[cur] = [stripped]
                continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is not None and stripped:
            comps[cur].append(stripped)
    return comps


_DEF_RE = re.compile(r"%([\w\.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\]")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*([a-z0-9]+)\[([0-9,]*)\]")


def _symbol_table(comps: dict[str, list[str]]) -> dict[str, tuple[str, str]]:
    """name -> (dtype, dims) for every op result and computation param.
    Tuple-typed results are skipped (we only need dot operand arrays)."""
    tab: dict[str, tuple[str, str]] = {}
    for lines in comps.values():
        header, body = lines[0], lines[1:]
        for m in _PARAM_RE.finditer(header):
            tab.setdefault(m.group(1), (m.group(2), m.group(3)))
        for line in body:
            m = _DEF_RE.search(line)
            if m and "= (" not in line.split(m.group(1))[0] + m.group(1):
                name = m.group(1)
                tab.setdefault(name, (m.group(2), m.group(3)))
    return tab


def _elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


#: dot lhs operand, tolerating the inline-shape form newer XLA emits
#: (``dot(f32[256,256]{1,0} %lhs, ...)``) as well as the bare ``dot(%lhs``
_DOT_LHS_RE = re.compile(
    r"dot\(\s*(?:[a-z0-9]+\[([0-9,]*)\][^\s]*\s+)?%?([\w\.\-]+)"
)


def _dot_flops(line: str, symtab: dict[str, tuple[str, str]]) -> int:
    res = _result_of(line)
    if res is None:
        return 0
    out_elems = _elems(res[1])
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    mo = _DOT_LHS_RE.search(line)
    if mc is None or mo is None:
        return 2 * out_elems
    if mo.group(1) is not None:
        lhs_shape = mo.group(1)          # inline shape on the operand
    else:
        lhs = symtab.get(mo.group(2))
        if lhs is None:
            return 2 * out_elems
        lhs_shape = lhs[1]
    lhs_dims = lhs_shape.split(",") if lhs_shape else []
    contract = 1
    for idx in mc.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            contract *= int(lhs_dims[int(idx)])
    return 2 * out_elems * contract


_OP_RE = re.compile(r"=\s*\(?[a-z0-9]+\[[0-9,]*\][^\s]*\s+([a-z\-]+)[\.\(]")


def analyze(hlo: str) -> dict:
    comps = _split_computations(hlo)
    symtab = _symbol_table(comps)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None:  # fall back: computation named main-ish
        entry = next((c for c in comps if "main" in c), next(iter(comps)))

    # --- per-computation raw stats + edges ---------------------------------
    stats = {}
    for name, lines in comps.items():
        flops = 0
        bytes_ = 0
        coll: dict[str, float] = {c: 0.0 for c in COLLECTIVES}
        coll_raw: dict[str, float] = {c: 0.0 for c in COLLECTIVES}
        edges: list[tuple[str, str]] = []  # (callee, kind)
        for line in lines[1:]:  # skip header
            opm = _OP_RE.search(line)
            op = opm.group(1) if opm else ""
            if op == "dot":
                flops += _dot_flops(line, symtab)
                # lhs + rhs + out bytes
                res = _result_of(line)
                if res:
                    bytes_ += _shape_bytes(*res)
                for mo in re.finditer(r"dot\(([^)]*)\)", line):
                    for nm in re.findall(r"%([\w\.\-]+)", mo.group(1)):
                        opshape = symtab.get(nm)
                        if opshape:
                            bytes_ += _shape_bytes(*opshape)
            elif op in ("fusion", "custom-call"):
                bytes_ += _all_shape_bytes(line.split(", calls")[0]
                                           .split(", metadata")[0])
            elif op == "dynamic-update-slice":
                # in-place update: traffic = the updated slice (operand 1),
                # not the whole buffer (XLA aliases the result)
                mo = re.search(r"dynamic-update-slice\(([^)]*)\)", line)
                if mo:
                    names = re.findall(r"%([\w\.\-]+)", mo.group(1))
                    if len(names) >= 2:
                        upd = symtab.get(names[1])
                        if upd:
                            bytes_ += 2 * _shape_bytes(*upd)
            elif op in ("dynamic-slice", "copy", "transpose", "reshape",
                        "concatenate", "scatter", "gather", "reduce",
                        "broadcast", "select", "add", "multiply",
                        "convert", "iota", "pad", "slice"):
                res = _result_of(line)
                if res:
                    bytes_ += 2 * _shape_bytes(*res)
            for cname in COLLECTIVES:
                if re.search(rf"\s{cname}[\.\(]", line) or \
                   re.search(rf"{cname}-start[\.\(]", line):
                    res = _result_of(line)
                    if res:
                        payload = _shape_bytes(*res)
                        # CPU-backend artifact: bf16 matmuls are legalised
                        # to f32, so collectives fed by convert fusions
                        # carry 2x the bytes they would on a TPU.  Count
                        # those at bf16 width (raw number kept separately).
                        mo = re.search(rf"{cname}[\w\.]*\(\s*%([\w\.\-]+)",
                                       line)
                        src_name = mo.group(1) if mo else ""
                        if res[0] == "f32" and "convert" in src_name:
                            coll_raw[cname] += payload * _COLL_FACTOR[cname]
                            payload = payload // 2
                        else:
                            coll_raw[cname] += payload * _COLL_FACTOR[cname]
                        coll[cname] += payload * _COLL_FACTOR[cname]
            # call edges
            for attr, kind in (("calls", "fusion"), ("to_apply", "call"),
                               ("body", "while_body"),
                               ("condition", "while_cond")):
                for m in re.finditer(rf"{attr}=%?([\w\.\-]+)", line):
                    edges.append((m.group(1), kind))
            m = re.search(r"branch_computations=\{([^}]*)\}", line)
            if m:
                for b in m.group(1).split(","):
                    edges.append((b.strip().lstrip("%"), "branch"))
            if " while(" in line:
                mb = re.search(r"body=%?([\w\.\-]+)", line)
                mc = re.search(r"condition=%?([\w\.\-]+)", line)
                if mb and mc:
                    tc = _trip_count(comps.get(mc.group(1), []))
                    edges.append((mb.group(1), f"trip:{tc}"))
                    edges.append((mc.group(1), f"trip:{tc}"))
        stats[name] = {
            "flops": flops, "bytes": bytes_, "coll": coll,
            "coll_raw": coll_raw, "edges": edges,
        }

    # --- propagate multipliers from entry -----------------------------------
    # bytes inside fused computations are register/VMEM traffic, not HBM:
    # only the fusion op's boundary (counted at the call site) moves HBM
    # bytes, so a separate byte-multiplier stays 0 under fusion edges.
    mult: dict[str, float] = {}
    bmult: dict[str, float] = {}

    def visit(name: str, m: float, bm: float) -> None:
        if name not in stats:
            return
        mult[name] = mult.get(name, 0.0) + m
        bmult[name] = bmult.get(name, 0.0) + bm
        for callee, kind in stats[name]["edges"]:
            if kind.startswith("trip:"):
                visit(callee, m * float(kind.split(":")[1]),
                      bm * float(kind.split(":")[1]))
            elif kind in ("while_body", "while_cond"):
                continue  # handled by trip edges
            elif kind == "fusion":
                visit(callee, m, 0.0)
            else:
                visit(callee, m, bm)

    visit(entry, 1.0, 1.0)

    total_flops = 0.0
    total_bytes = 0.0
    total_coll: dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    total_coll_raw: dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    for name, st in stats.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        total_flops += st["flops"] * m
        total_bytes += st["bytes"] * bmult.get(name, 0.0)
        for c in COLLECTIVES:
            total_coll[c] += st["coll"][c] * m
            total_coll_raw[c] += st["coll_raw"][c] * m
    return {
        "flops_per_device": total_flops,
        "bytes_per_device": total_bytes,
        "collective_bytes_per_device": total_coll,
        "collective_total": sum(total_coll.values()),
        "collective_bytes_raw": total_coll_raw,
        "collective_total_raw": sum(total_coll_raw.values()),
        "n_computations": len(comps),
    }


def _trip_count(cond_lines: list[str]) -> int:
    """Trip count of a jax-emitted while: the s32 comparison constant."""
    cands = []
    for line in cond_lines:
        for m in re.finditer(r"s32\[\]\s+constant\((\d+)\)", line):
            cands.append(int(m.group(1)))
    return max(cands) if cands else 1


def collective_traffic(hlo: str) -> dict:
    return analyze(hlo)


# ---------------------------------------------------------------------------
# report assembly (used by dryrun.py / benchmarks.roofline)
# ---------------------------------------------------------------------------

# TPU v5e-like constants (DESIGN.md §6)
PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
HBM_CAP = 16 * 2**30
ICI_BW = 50e9            # bytes/s per link; 2D torus budget below
ICI_LINKS = 2            # usable link-pairs per chip for our collectives


def summarize(*, arch, shape, mesh, cfg, mem, cost, coll, compile_s,
              multi_pod) -> dict:
    n_dev = mesh.devices.size
    hlo_flops = coll["flops_per_device"]
    hlo_bytes = coll["bytes_per_device"]
    coll_bytes = coll["collective_total"]

    compute_s = hlo_flops / PEAK_FLOPS
    memory_s = hlo_bytes / HBM_BW
    collective_s = coll_bytes / (ICI_BW * ICI_LINKS)
    terms = {
        "compute": compute_s, "memory": memory_s, "collective": collective_s,
    }
    bottleneck = max(terms, key=terms.get)

    # model flops (global): 6·N·D for train, 2·N·D for inference
    n_params = (
        cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    )
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1
    )
    factor = 6 if shape.kind == "train" else 2
    model_flops = factor * n_params * tokens
    model_flops_per_dev = model_flops / n_dev

    report = {
        "arch": arch,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": int(n_dev),
        "status": "ok",
        "compile_s": compile_s,
        # memory_analysis (per device)
        "bytes_per_device": int(
            mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes
        ),
        "argument_bytes": int(mem.argument_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "fits_hbm": bool(
            mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes
            < HBM_CAP
        ),
        # xla cost_analysis (loop bodies counted once — lower bound)
        "xla_flops_lower_bound": float(cost.get("flops", 0.0)),
        # loop-aware analyzer (per device)
        "hlo_flops_per_device": hlo_flops,
        "hlo_bytes_per_device": hlo_bytes,
        "collective_bytes_per_device": coll["collective_bytes_per_device"],
        "collective_total_per_device": coll_bytes,
        "collective_total_raw_f32_legalised": coll.get(
            "collective_total_raw", coll_bytes
        ),
        # roofline
        "roofline_s": terms,
        "bottleneck": bottleneck,
        "step_time_lower_bound_s": max(terms.values()),
        "model_flops_global": model_flops,
        "model_flops_per_device": model_flops_per_dev,
        "useful_flops_ratio": (
            model_flops_per_dev / hlo_flops if hlo_flops else 0.0
        ),
        "mfu_upper_bound": (
            model_flops_per_dev / PEAK_FLOPS / max(terms.values())
            if max(terms.values()) > 0 else 0.0
        ),
    }
    return report
