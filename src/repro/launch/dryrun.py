import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST run before any other import (jax locks the device
count at first init).  For every cell this script:

  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  2. resolves the architecture's sharding rules on that mesh,
  3. lowers the appropriate step (train_step / prefill_step / decode_step)
     against ShapeDtypeStruct inputs (no allocation),
  4. compiles it, and
  5. records ``memory_analysis`` / ``cost_analysis`` / per-collective byte
     counts (parsed from the optimised HLO, scan trip-counts applied) to
     ``reports/dryrun/<arch>__<shape>__<mesh>.json``.

Any failure here (sharding mismatch, OOM at compile, unsupported
collective) is a bug in the framework, not in the run.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--quick]
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, get
from repro.launch.hlo_analysis import collective_traffic, summarize
from repro.launch.mesh import make_production_mesh, mesh_shape_dict
from repro.models.config import SHAPES, input_specs, shape_applicable
from repro.models.model import build_model
from repro.parallel.sharding import make_rules
from repro.parallel.steps import (
    init_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False):
    """Lower + compile one cell; returns the report dict."""
    cfg = get(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(cfg, mesh_shape_dict(mesh),
                       batch_size=shape.global_batch)
    model = build_model(cfg, impl="xla")

    t0 = time.time()
    with mesh:
        specs = input_specs(cfg, shape)
        if shape.kind == "train":
            bundle = make_train_step(model, rules, mesh, shape)
            state_sds = jax.eval_shape(
                lambda: init_train_state(model, jax.random.key(0))
            )
            args = (state_sds, specs)
        elif shape.kind == "prefill":
            bundle = make_prefill_step(model, rules, mesh, shape)
            params_sds = model.param_shapes()
            args = (params_sds, specs)
        else:  # decode
            bundle = make_decode_step(model, rules, mesh, shape)
            params_sds = model.param_shapes()
            cache_sds = model.cache_shapes(shape.global_batch, shape.seq_len)
            args = (params_sds, cache_sds, specs["token"])

        jitted = jax.jit(
            bundle.fn,
            in_shardings=bundle.in_shardings,
            out_shardings=bundle.out_shardings,
            donate_argnums=bundle.donate_argnums,
        )
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = collective_traffic(compiled.as_text())

    report = summarize(
        arch=arch, shape=shape, mesh=mesh, cfg=cfg,
        mem=mem, cost=cost, coll=coll,
        compile_s=time.time() - t0,
        multi_pod=multi_pod,
    )
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=REPORT_DIR)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else sorted(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                try:
                    rep = lower_cell(arch, shape, multi_pod=multi)
                    status = rep.get("status", "ok")
                    print(f"[{status:7s}] {tag}  "
                          + (f"compile={rep.get('compile_s', 0):.1f}s "
                             f"mem/dev={rep.get('bytes_per_device', 0)/2**30:.2f}GiB"
                             if status == "ok" else rep.get("reason", "")))
                except Exception as e:  # noqa: BLE001 - report and continue
                    rep = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi else "single",
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    failures.append(tag)
                    print(f"[ERROR  ] {tag}  {e!r}")
                with open(path, "w") as f:
                    json.dump(rep, f, indent=2)
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")


if __name__ == "__main__":
    main()
