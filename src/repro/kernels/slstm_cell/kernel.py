"""Fused sLSTM cell Pallas TPU kernel (EXPERIMENTS.md §Perf H1 follow-up).

The sLSTM recurrence is inherently sequential; under XLA each timestep
re-reads the four recurrent matrices from HBM, which made xlstm-350m's
training memory term explode.  This kernel keeps the per-head recurrent
weights **resident in VMEM** across the whole time loop and streams the
gate pre-activations through in chunks:

  grid = (batch, heads, time_chunks)   (time minor, sequential)
  VMEM: rz/ri/rf/ro [D,D] (via BlockSpec, revisited per chunk but pinned
        by the pipeline since the index map is constant in the chunk axis),
        xs chunk [4, Tc, D], carry scratch c/n/h/m [D].

HBM traffic per layer drops from O(T·D²) weight reads to O(T·D) activation
streaming — the roofline projection that closes H1.

Per-step math (stabilised, matches ``repro.models.xlstm.slstm_scan``):
  z = tanh(zx + h·Rz); i = ix + h·Ri; f = fx + h·Rf; o = σ(ox + h·Ro)
  m' = max(log σ(f) + m, i)
  c' = e^{logσ(f)+m-m'}·c + e^{i-m'}·z ;  n' likewise ;  h' = o·c'/max(n',ε)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _slstm_kernel(
    zx_ref, ix_ref, fx_ref, ox_ref,   # [1, 1, 1, Tc, D] gate pre-activations
    rz_ref, ri_ref, rf_ref, ro_ref,   # [1, D, D] recurrent weights (VMEM)
    h_out_ref,                        # [1, 1, 1, Tc, D]
    c_ref, n_ref, h_ref, m_ref,       # scratch [1, D] f32 (carry)
    *,
    tc: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        h_ref[...] = jnp.zeros_like(h_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)

    rz = rz_ref[0].astype(jnp.float32)
    ri = ri_ref[0].astype(jnp.float32)
    rf = rf_ref[0].astype(jnp.float32)
    ro = ro_ref[0].astype(jnp.float32)

    def step(t, _):
        h = h_ref[...]                                      # [1, D]
        zt = zx_ref[0, 0, 0, t].astype(jnp.float32)[None] + h @ rz
        it = ix_ref[0, 0, 0, t].astype(jnp.float32)[None] + h @ ri
        ft = fx_ref[0, 0, 0, t].astype(jnp.float32)[None] + h @ rf
        ot = ox_ref[0, 0, 0, t].astype(jnp.float32)[None] + h @ ro
        zt = jnp.tanh(zt)
        ot = jax.nn.sigmoid(ot)
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m_ref[...], it)
        fp = jnp.exp(logf + m_ref[...] - m_new)
        ip = jnp.exp(it - m_new)
        c = fp * c_ref[...] + ip * zt
        n = fp * n_ref[...] + ip
        h_new = ot * c / jnp.maximum(n, 1e-6)
        c_ref[...] = c
        n_ref[...] = n
        h_ref[...] = h_new
        m_ref[...] = m_new
        h_out_ref[0, 0, 0, t] = h_new[0].astype(h_out_ref.dtype)
        return ()

    jax.lax.fori_loop(0, tc, step, ())


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def slstm_cell(
    zx: jax.Array, ix: jax.Array, fx: jax.Array, ox: jax.Array,  # [B,T,H,D]
    rz: jax.Array, ri: jax.Array, rf: jax.Array, ro: jax.Array,  # [H,D,D]
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, t, h, d = zx.shape
    tc = min(chunk, t)
    assert t % tc == 0, (t, tc)
    nc = t // tc

    gates = [a.transpose(0, 2, 1, 3).reshape(b, h, nc, tc, d)
             for a in (zx, ix, fx, ox)]

    kernel = functools.partial(_slstm_kernel, tc=tc)
    gate_spec = pl.BlockSpec(
        (1, 1, 1, tc, d), lambda b_, h_, ci: (b_, h_, ci, 0, 0)
    )
    w_spec = pl.BlockSpec((1, d, d), lambda b_, h_, ci: (h_, 0, 0))
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[gate_spec] * 4 + [w_spec] * 4,
        out_specs=pl.BlockSpec(
            (1, 1, 1, tc, d), lambda b_, h_, ci: (b_, h_, ci, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, nc, tc, d), zx.dtype),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)] * 4,
        interpret=interpret,
    )(*gates, rz, ri, rf, ro)
    return out.reshape(b, h, t, d).transpose(0, 2, 1, 3)  # [B,T,H,D]