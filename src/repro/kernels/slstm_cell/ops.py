"""Jit'd public wrapper for the fused sLSTM cell."""

from __future__ import annotations

import jax

from repro.kernels.slstm_cell.kernel import slstm_cell as _kernel
from repro.kernels.slstm_cell.ref import slstm_cell_ref


def slstm_cell(zx, ix, fx, ox, rz, ri, rf, ro, *, chunk: int = 256):
    interpret = jax.default_backend() != "tpu"
    return _kernel(zx, ix, fx, ox, rz, ri, rf, ro, chunk=chunk,
                   interpret=interpret)


__all__ = ["slstm_cell", "slstm_cell_ref"]
