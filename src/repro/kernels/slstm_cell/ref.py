"""Pure-jnp oracle for the fused sLSTM cell: the model-side scan."""

from __future__ import annotations

import jax

from repro.models.xlstm import slstm_scan


def slstm_cell_ref(zx, ix, fx, ox, rz, ri, rf, ro) -> jax.Array:
    hs, _ = slstm_scan(zx, ix, fx, ox,
                       {"rz": rz, "ri": ri, "rf": rf, "ro": ro}, None)
    return hs.astype(zx.dtype)
