"""Mamba2 SSD chunked-scan Pallas TPU kernel.

Grid = (batch, ssm_heads, chunks) with the chunk axis minor/sequential: the
[N, P] inter-chunk state is carried in VMEM scratch while each grid step
computes the within-chunk quadratic form on the MXU:

    y[i]  = Σ_{j<=i} (C_i·B_j) exp(cum_i - cum_j) dt_j x_j  +  C_i·state·exp(cum_i)
    state = state·exp(cum_last) + Σ_j exp(cum_last - cum_j) dt_j B_j ⊗ x_j

VMEM per step: x/B/C chunk tiles (c×P, c×N), the c×c decay-masked score
tile and the [N, P] state — with c=256, N=P=64 that is ~0.6 MB.

The pure-jnp oracle is ``repro.models.mamba2.ssd_chunked``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,     # [1, 1, c, P]
    dt_ref,    # [1, 1, c]
    a_ref,     # [1]
    b_ref,     # [1, c, N]
    c_ref,     # [1, c, N]
    y_ref,     # [1, 1, c, P]
    state_ref,  # scratch [N, P] f32
    *,
    chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)      # [c, P]
    dt = dt_ref[0, 0, 0].astype(jnp.float32)    # [c]
    a = a_ref[0].astype(jnp.float32)             # scalar
    bm = b_ref[0, 0].astype(jnp.float32)         # [c, N]
    cm = c_ref[0, 0].astype(jnp.float32)         # [c, N]

    adt = dt * a                                  # [c], negative
    cum = jnp.cumsum(adt)                         # [c]
    atot = cum[-1]

    # intra-chunk decay-masked scores
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    dec = jnp.where(jj <= ii, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    scores = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                             # [c, c]
    w = scores * dec * dt[None, :]
    y = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                             # [c, P]
    # inter-chunk contribution
    y += jax.lax.dot_general(
        cm, state_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * jnp.exp(cum)[:, None]
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    # state update
    g = jnp.exp(atot - cum) * dt                  # [c]
    state_ref[...] = state_ref[...] * jnp.exp(atot) + jax.lax.dot_general(
        bm * g[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,    # [B, S, H, P]
    dt: jax.Array,   # [B, S, H] (post-softplus)
    a: jax.Array,    # [H] negative
    bmat: jax.Array,  # [B, S, N]
    cmat: jax.Array,  # [B, S, N]
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    nc = s // c

    xt = x.transpose(0, 2, 1, 3).reshape(b, h, nc, c, p)
    dtt = dt.transpose(0, 2, 1).reshape(b, h, nc, c)
    bt = bmat.reshape(b, nc, c, n)
    ct = cmat.reshape(b, nc, c, n)

    kernel = functools.partial(_ssd_kernel, chunk=c)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, c, p),
                         lambda b_, h_, ci: (b_, h_, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, c), lambda b_, h_, ci: (b_, h_, ci, 0)),
            pl.BlockSpec((1,), lambda b_, h_, ci: (h_,)),
            pl.BlockSpec((1, 1, c, n), lambda b_, h_, ci: (b_, ci, 0, 0)),
            pl.BlockSpec((1, 1, c, n), lambda b_, h_, ci: (b_, ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, c, p), lambda b_, h_, ci: (b_, h_, ci, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, nc, c, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, a, bt, ct)
    return out.reshape(b, h, s, p).transpose(0, 2, 1, 3)