"""Pure-jnp oracle for the SSD scan kernel: the model-side chunked scan."""

from __future__ import annotations

import jax

from repro.models.mamba2 import ssd_chunked


def ssd_scan_ref(x, dt, a, bmat, cmat, chunk: int = 256) -> jax.Array:
    y, _ = ssd_chunked(x, dt, a, bmat, cmat, chunk=chunk)
    return y.astype(x.dtype)
