"""Jit'd public wrapper for the SSD scan (see flash_attention/ops.py)."""

from __future__ import annotations

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan as _kernel
from repro.kernels.ssd_scan.ref import ssd_scan_ref


def ssd_scan(x, dt, a, bmat, cmat, *, chunk: int = 256):
    interpret = jax.default_backend() != "tpu"
    return _kernel(x, dt, a, bmat, cmat, chunk=chunk, interpret=interpret)


__all__ = ["ssd_scan", "ssd_scan_ref"]
