from repro.kernels.chains_makespan.ops import (
    chains_makespan_batch_pallas,
    pallas_usable,
)
from repro.kernels.chains_makespan.ref import chains_makespan_batch_ref

__all__ = [
    "chains_makespan_batch_pallas",
    "chains_makespan_batch_ref",
    "pallas_usable",
]
