"""Public wrapper for the batched chains-makespan kernel.

``chains_makespan_batch_pallas`` matches
:func:`repro.core.timing.chains_makespan_batch` bit for bit (see
kernel.py for why).  ``pallas_usable`` is the dispatch gate the
vectorized family evaluator consults: the fused kernel only pays off on
an accelerator backend — on CPU the interpret-mode emulation is far
slower than the numpy lockstep, so CPU runs keep numpy and CI verifies
the kernel through ``interpret=True`` instead.
"""

from __future__ import annotations

import numpy as np

_PALLAS_OK: bool | None = None


def pallas_usable() -> bool:
    """True when the compiled kernel is worth dispatching to."""
    global _PALLAS_OK
    if _PALLAS_OK is None:
        try:
            import jax
            from jax.experimental import pallas  # noqa: F401

            _PALLAS_OK = jax.default_backend() in ("gpu", "tpu")
        except Exception:  # pragma: no cover - no jax / broken backend
            _PALLAS_OK = False
    return _PALLAS_OK


def reset_for_tests() -> None:
    """Drop the cached backend probe (test hook)."""
    global _PALLAS_OK
    _PALLAS_OK = None


def chains_makespan_batch_pallas(
    spec, chain_durs, chain_len, *, blk: int = 8, interpret=None
):
    """``(C,)`` makespans for ``(C, N, L)`` zero-padded duration chains.

    ``interpret=None`` follows the repo's kernel idiom (compile only on
    TPU); tests pass ``interpret=True`` explicitly for the CPU
    bit-exactness check.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core.timing import _batch_spec_arrays
    from repro.kernels.chains_makespan.kernel import chains_makespan_scan

    (tc, td, childmask, descmask, root_idx, grp_idx,
     n_groups) = _batch_spec_arrays(spec)
    C, N, L = chain_durs.shape
    if C == 0:
        return np.zeros(0)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    Cp = -(-C // blk) * blk  # pad with all-empty (makespan 0) candidates
    durs = np.zeros((Cp, N, L))
    durs[:C] = chain_durs
    lens = np.zeros((Cp, N), dtype=np.int32)
    lens[:C] = chain_len
    # constants, tracing and execution must all sit inside the x64
    # scope, or the program silently truncates to float32
    with enable_x64():
        out = chains_makespan_scan(
            jnp.asarray(durs),
            jnp.asarray(lens),
            jnp.asarray(np.asarray(tc, dtype=np.float64)),
            jnp.asarray(np.asarray(td, dtype=np.float64)),
            jnp.asarray(childmask.astype(np.int32)),
            jnp.asarray(descmask.astype(np.int32)),
            jnp.asarray(np.asarray(grp_idx, dtype=np.int32)),
            root_idx=tuple(int(i) for i in root_idx),
            n_groups=int(n_groups),
            blk=blk,
            interpret=bool(interpret),
        )
        res = np.asarray(out)
    return res[:C]


__all__ = [
    "chains_makespan_batch_pallas",
    "pallas_usable",
    "reset_for_tests",
]
