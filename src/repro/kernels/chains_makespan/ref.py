"""Numpy oracle for the batched chains-makespan kernel.

The reference is the lockstep event walk in
:func:`repro.core.timing.chains_makespan_batch`, itself pinned
bit-identical per candidate to the scalar :func:`chains_makespan`
scorer — so kernel == ref == scalar is one transitive contract.
"""

from __future__ import annotations

from repro.core.timing import chains_makespan_batch


def chains_makespan_batch_ref(spec, chain_durs, chain_len):
    return chains_makespan_batch(spec, chain_durs, chain_len)
