"""Batched chains-makespan Pallas kernel (phase-2 candidate scoring).

One fused kernel replaces the per-step numpy dispatch of
:func:`repro.core.timing.chains_makespan_batch`: grid over candidate
blocks, each grid step running the whole replay-semantics event walk for
its ``blk`` candidates in lockstep.  The device tree is tiny (N <= ~16
nodes for every shipped spec), so the event queue holds at most one
pending event per node and a pop is a masked argmin over the node axis —
exactly the lockstep the numpy walk performs, which in turn reproduces
the scalar ``chains_makespan`` heap order because ``(when, seq)`` is a
total order (seqs are unique).

Bit-exactness is by construction, not tolerance:

* the chain fold is a sequential ``fori_loop`` of double additions —
  the same left fold as ``np.add.accumulate`` / Python's ``sum`` — never
  a ``cumsum``/associative scan, whose re-association would change
  roundings;
* all selects are one-hot masked sums where the masked-out lanes
  contribute exact ``+0.0`` (durations and reconfiguration ends are
  non-negative), so gathers introduce no arithmetic;
* the walk runs a fixed ``2 * N`` iterations — each live candidate pops
  exactly one event per iteration and every node contributes at most one
  visit and one done pop, so trailing iterations are masked no-ops.

``chain_durs`` rows must be zero-padded past ``chain_len`` (the fold
runs the full row; trailing zeros are exact no-op additions).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cm_kernel(
    durs_ref,    # [blk, N, L] f64, zero-padded chains
    len_ref,     # [blk, N] i32
    tc_ref,      # [N] f64 creation charge per node
    td_ref,      # [N] f64 destruction charge per node
    child_ref,   # [N, N] i32, child_ref[p, c]: c is a child of p
    desc_ref,    # [N, N] i32, desc_ref[a, b]: b in subtree(a)
    grp_ref,     # [N] i32 reconfiguration-sequence group per node
    out_ref,     # [blk] f64 makespans
    *,
    root_idx: tuple,
    n_groups: int,
    blk: int,
    n_nodes: int,
    chain_cap: int,
):
    durs = durs_ref[...]
    lens = len_ref[...]
    tc = tc_ref[...]
    td = td_ref[...]
    child = child_ref[...] > 0
    desc = desc_ref[...] > 0
    grp = grp_ref[...]
    f64 = durs.dtype

    active = lens > 0                                        # (blk, N)
    # 0/1 matmuls: counts <= N, exact in f64
    sub_act = jnp.dot(active.astype(f64), desc.T.astype(f64)) > 0
    goflag = jnp.dot(sub_act.astype(f64), child.T.astype(f64)) > 0

    BIG = jnp.int32(2**30)
    tevt = jnp.full((blk, n_nodes), jnp.inf, f64)
    sevt = jnp.full((blk, n_nodes), BIG, jnp.int32)
    wevt = jnp.zeros((blk, n_nodes), jnp.int32)              # 0=visit 1=done
    seqctr = jnp.zeros((blk,), jnp.int32)
    for i in root_idx:  # static unroll: roots pushed in spec order
        pushed = sub_act[:, i]
        tevt = tevt.at[:, i].set(jnp.where(pushed, 0.0, tevt[:, i]))
        sevt = sevt.at[:, i].set(jnp.where(pushed, seqctr, sevt[:, i]))
        seqctr = seqctr + pushed.astype(jnp.int32)
    re = jnp.zeros((blk, n_groups), f64)
    mk = jnp.zeros((blk,), f64)
    iota_n = jax.lax.broadcasted_iota(jnp.int32, (blk, n_nodes), 1)
    iota_g = jax.lax.broadcasted_iota(jnp.int32, (blk, n_groups), 1)

    def step(_, carry):
        tevt, sevt, wevt, seqctr, re, mk = carry
        rows = jnp.isfinite(tevt).any(1)
        when = tevt.min(1)
        cand = tevt == when[:, None]
        seqm = jnp.where(cand, sevt, BIG)
        sel = cand & (seqm == seqm.min(1)[:, None]) & rows[:, None]
        n_star = jnp.argmax(sel, 1).astype(jnp.int32)
        onehot = iota_n == n_star[:, None]
        ohf = onehot.astype(f64)
        g_star = jnp.sum(jnp.where(onehot, grp[None, :], 0), 1)
        oh_g = iota_g == g_star[:, None]
        re_cur = jnp.sum(jnp.where(oh_g, re, 0.0), 1)
        what = jnp.sum(jnp.where(onehot, wevt, 0), 1)
        act = (onehot & active).any(1)
        m_visit = rows & (what == 0)
        m_va = m_visit & act
        m_done = rows & (what == 1)
        tc_star = jnp.sum(jnp.where(onehot, tc[None, :], 0.0), 1)
        td_star = jnp.sum(jnp.where(onehot, td[None, :], 0.0), 1)

        # visit of an active node: creation charge + exact chain fold
        t0 = jnp.maximum(re_cur, when) + tc_star
        chosen = jnp.sum(durs * ohf[:, :, None], 1)          # (blk, L)
        end = jax.lax.fori_loop(
            0, chain_cap, lambda l, t: t + chosen[:, l], t0
        )
        re = jnp.where(oh_g & m_va[:, None], t0[:, None], re)
        mk = jnp.where(m_va & (end > mk), end, mk)
        # visit -> done event in place (chain end if active, else when)
        upd_v = onehot & m_visit[:, None]
        tevt = jnp.where(upd_v, jnp.where(m_va, end, when)[:, None], tevt)
        wevt = jnp.where(upd_v, 1, wevt)
        sevt = jnp.where(upd_v, seqctr[:, None], sevt)
        seqctr = seqctr + m_visit.astype(jnp.int32)

        # done: destroy (active node, active subtree remains) + children
        go = (onehot & goflag).any(1)
        m_dgo = m_done & go
        m_destroy = m_dgo & act
        re_d = jnp.maximum(re_cur, when) + td_star
        re = jnp.where(oh_g & m_destroy[:, None], re_d[:, None], re)
        tevt = jnp.where(onehot & m_done[:, None], jnp.inf, tevt)
        childrow = jnp.dot(ohf, child.astype(f64)) > 0       # (blk, N)
        push = childrow & sub_act & m_dgo[:, None]
        rank = jnp.cumsum(push.astype(jnp.int32), 1) - 1
        tevt = jnp.where(push, when[:, None], tevt)
        wevt = jnp.where(push, 0, wevt)
        sevt = jnp.where(push, seqctr[:, None] + rank, sevt)
        seqctr = seqctr + jnp.sum(
            push.astype(jnp.int32), 1, dtype=jnp.int32
        )
        return tevt, sevt, wevt, seqctr, re, mk

    carry = (tevt, sevt, wevt, seqctr, re, mk)
    carry = jax.lax.fori_loop(0, 2 * n_nodes, step, carry)
    out_ref[...] = carry[5]


@functools.partial(
    jax.jit, static_argnames=("root_idx", "n_groups", "blk", "interpret")
)
def chains_makespan_scan(
    durs,        # [C, N, L] f64, C a multiple of blk
    lens,        # [C, N] i32
    tc,          # [N] f64
    td,          # [N] f64
    childmask,   # [N, N] i32
    descmask,    # [N, N] i32
    grp_idx,     # [N] i32
    *,
    root_idx: tuple,
    n_groups: int,
    blk: int = 8,
    interpret: bool = False,
):
    C, N, L = durs.shape
    assert C % blk == 0, (C, blk)
    kernel = functools.partial(
        _cm_kernel,
        root_idx=root_idx,
        n_groups=n_groups,
        blk=blk,
        n_nodes=N,
        chain_cap=L,
    )
    return pl.pallas_call(
        kernel,
        grid=(C // blk,),
        in_specs=[
            pl.BlockSpec((blk, N, L), lambda b: (b, 0, 0)),
            pl.BlockSpec((blk, N), lambda b: (b, 0)),
            pl.BlockSpec((N,), lambda b: (0,)),
            pl.BlockSpec((N,), lambda b: (0,)),
            pl.BlockSpec((N, N), lambda b: (0, 0)),
            pl.BlockSpec((N, N), lambda b: (0, 0)),
            pl.BlockSpec((N,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct((C,), durs.dtype),
        interpret=interpret,
    )(durs, lens, tc, td, childmask, descmask, grp_idx)
