"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # [B, Sq, H, hd]
    k: jax.Array,  # [B, Skv, KV, hd]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
) -> jax.Array:
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    if scale is None:
        scale = hd ** -0.5
    qg = q.reshape(b, sq, kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)
