"""Flash attention forward Pallas TPU kernel.

Tiled online-softmax attention.  Grid = (batch, q_heads, q_blocks,
kv_blocks); the kv dimension is the minor (sequential) grid axis, so the
running max / sum / accumulator live in VMEM scratch and are carried across
kv steps ("arbitrary" TPU grid semantics).  Block sizes are MXU-aligned
(multiples of 128 on the sequence dims; head_dim is kept whole — 64…256 for
the assigned archs).

Supports causal masking, sliding-window masking, logit soft-capping and
GQA (kv head = q head // group) without materialising the [Sq, Skv] score
matrix in HBM.  VMEM footprint per step:
  q tile  bq×hd, k/v tiles bk×hd, acc bq×hd (f32), m/l bq — with the
  default bq=bk=256, hd≤256 that is ≤ 0.9 MB, far under the ~16 MB budget,
  leaving room for double-buffered pipelining.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref,        # VMEM tiles
    o_ref,                      # output tile
    m_ref, l_ref, acc_ref,      # scratch: running max / sum / accumulator
    *,
    scale: float,
    causal: bool,
    window: int,
    softcap: float,
    bq: int,
    bk: int,
    n_kv_blocks: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window:
        ok &= k_pos > q_pos - window

    # skip fully-masked blocks (causal: ki beyond the diagonal; window:
    # ki before the band) — cheap static-ish predicate on block indexes
    run = jnp.bool_(True)
    if causal:
        run &= ki * bk <= qi * bq + bq - 1
    if window:
        run &= (ki + 1) * bk - 1 > qi * bq - window

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, hd]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                       # [bq, bk]
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                             # [bq]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])                 # [bq, bk]
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)             # [bk, hd]
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_cur

    @pl.when(ki == n_kv_blocks - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "scale", "bq", "bk", "interpret"
    ),
)
def flash_attention(
    q: jax.Array,  # [B, Sq, H, hd]
    k: jax.Array,  # [B, Skv, KV, hd]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    bq: int = 256,
    bk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    group = h // kv
    if scale is None:
        scale = hd ** -0.5
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    n_q, n_k = sq // bq, skv // bk

    grid = (b, h, n_q, n_k)
    kernel = functools.partial(
        _fa_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        bq=bq, bk=bk, n_kv_blocks=n_k,
    )
    # layout: [B, H, S, hd] blocks of [1, 1, bq|bk, hd]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec(
                (1, 1, bk, hd),
                lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0),
            ),
            pl.BlockSpec(
                (1, 1, bk, hd),
                lambda b_, h_, qi, ki, g=group: (b_, h_ // g, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, hd), lambda b_, h_, qi, ki: (b_, h_, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)  # [B, Sq, H, hd]