"""Jit'd public wrapper for flash attention.

On a TPU backend the Pallas kernel runs natively; elsewhere (this CPU
container) ``interpret=True`` executes the kernel body in Python for
correctness runs, and model code defaults to the XLA path anyway
(``attention_impl="xla"``).
"""

from __future__ import annotations

import jax

from repro.kernels.flash_attention.kernel import flash_attention as _kernel
from repro.kernels.flash_attention.ref import attention_ref


def flash_attention(
    q, k, v, *, causal=True, window=0, softcap=0.0, scale=None,
    bq=256, bk=256,
):
    interpret = jax.default_backend() != "tpu"
    return _kernel(
        q, k, v, causal=causal, window=window, softcap=softcap,
        scale=scale, bq=bq, bk=bk, interpret=interpret,
    )


__all__ = ["flash_attention", "attention_ref"]
