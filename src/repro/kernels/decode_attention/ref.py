"""Pure-jnp oracle for the decode attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jax.Array,      # [B, 1, H, hd]
    k: jax.Array,      # [B, L, KV, hd]
    v: jax.Array,
    valid: jax.Array,  # [L] bool
    *,
    softcap: float = 0.0,
    scale: float | None = None,
) -> jax.Array:
    b, _, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    if scale is None:
        scale = hd ** -0.5
    qg = q.reshape(b, kv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", w, v.astype(jnp.float32))
    return o.reshape(b, 1, h, hd).astype(q.dtype)
