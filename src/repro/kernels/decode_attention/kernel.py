"""Single-token decode attention Pallas TPU kernel.

The decode hot spot is bandwidth: one query row against a KV cache of up to
500k entries.  Grid = (batch, kv_heads, kv_blocks) with the kv-block axis
minor/sequential; the online-softmax running stats for the *whole GQA
group* of this kv head ([G, hd] accumulator) sit in VMEM scratch, so every
cache byte is read exactly once and the arithmetic rides the MXU via
[G, bk] score tiles.  A boolean validity mask handles rolling-window caches
and partially-filled buffers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _da_kernel(
    q_ref,      # [1, 1, G, hd]
    k_ref,      # [1, bk, 1, hd]
    v_ref,
    valid_ref,  # [bk] bool
    o_ref,      # [1, 1, G, hd]
    m_ref, l_ref, acc_ref,   # scratch: [G], [G], [G, hd]
    *,
    scale: float,
    softcap: float,
    n_blocks: int,
):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # [G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)            # [bk, hd]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                          # [G, bk]
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(valid_ref[...][None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    v = v_ref[0, :, 0].astype(jnp.float32)             # [bk, hd]
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_cur

    @pl.when(ki == n_blocks - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("softcap", "scale", "bk", "interpret")
)
def decode_attention(
    q: jax.Array,       # [B, 1, H, hd]
    k: jax.Array,       # [B, L, KV, hd]
    v: jax.Array,
    valid: jax.Array,   # [L] bool
    *,
    softcap: float = 0.0,
    scale: float | None = None,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, _, h, hd = q.shape
    l, kv = k.shape[1], k.shape[2]
    g = h // kv
    if scale is None:
        scale = hd ** -0.5
    bk = min(bk, l)
    assert l % bk == 0, (l, bk)
    n_blocks = l // bk

    # [B, KV, G, hd] query layout: all G queries of one kv head together
    qt = q.reshape(b, kv, g, hd)

    kernel = functools.partial(
        _da_kernel, scale=scale, softcap=softcap, n_blocks=n_blocks
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, kv, n_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b_, kh, ki: (b_, kh, 0, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b_, kh, ki: (b_, ki, kh, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b_, kh, ki: (b_, ki, kh, 0)),
            pl.BlockSpec((bk,), lambda b_, kh, ki: (ki,)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, g, hd), lambda b_, kh, ki: (b_, kh, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, k, v, valid)
    # out is [B, KV, G, hd] == attention for q-head (kh*g + gi)
    return out.reshape(b, 1, h, hd)