"""Jit'd public wrapper for decode attention (see flash_attention/ops.py)."""

from __future__ import annotations

import jax

from repro.kernels.decode_attention.kernel import decode_attention as _kernel
from repro.kernels.decode_attention.ref import decode_attention_ref


def decode_attention(q, k, v, valid, *, softcap=0.0, scale=None, bk=512):
    interpret = jax.default_backend() != "tpu"
    return _kernel(
        q, k, v, valid, softcap=softcap, scale=scale, bk=bk,
        interpret=interpret,
    )


__all__ = ["decode_attention", "decode_attention_ref"]
