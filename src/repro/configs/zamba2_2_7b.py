"""zamba2-2.7b [hybrid] — arXiv:2411.15242 (tier: hf).

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64.
Mamba2 backbone with one shared attention+MLP transformer block applied
every 6 layers (single parameter set, reused).
"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    shared_attn_every=6,
    source="arXiv:2411.15242; hf",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=512, ssm_state=8, shared_attn_every=2,
    )
