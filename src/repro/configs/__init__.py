"""Assigned architecture registry: ``get(name)`` / ``ARCHS`` / ``--arch``.

One module per architecture with the exact public config (see each file's
source tag) plus a ``smoke()`` reduced config of the same family for CPU
tests.
"""

from __future__ import annotations

from repro.models.config import ArchConfig

from repro.configs import (
    chameleon_34b,
    gemma3_12b,
    gemma_2b,
    moonshot_v1_16b_a3b,
    qwen1_5_110b,
    qwen2_5_3b,
    qwen2_moe_a2_7b,
    whisper_small,
    xlstm_350m,
    zamba2_2_7b,
)

_MODULES = (
    qwen2_moe_a2_7b,
    moonshot_v1_16b_a3b,
    qwen2_5_3b,
    qwen1_5_110b,
    gemma3_12b,
    gemma_2b,
    chameleon_34b,
    xlstm_350m,
    zamba2_2_7b,
    whisper_small,
)

ARCHS: dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}
SMOKES: dict[str, ArchConfig] = {m.CONFIG.name: m.smoke() for m in _MODULES}


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def get_smoke(name: str) -> ArchConfig:
    return SMOKES[name]
