"""qwen2-moe-a2.7b [moe] — hf:Qwen/Qwen1.5-MoE-A2.7B (tier: hf).

24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936,
MoE: 4 shared + 60 routed experts, top-4.
"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    n_experts=60,
    n_shared_experts=4,
    top_k=4,
    expert_d_ff=1408,
    # §Perf H3: 4 dead expert slots let EP shard 64 ways instead of
    # paying intra-expert-TP partial-sum all-reduces on [G,E,C,D]
    expert_pad=4,
    qkv_bias=True,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, expert_d_ff=96, n_experts=8, n_shared_experts=2,
        vocab_size=512,
    )
