"""gemma-2b [dense] — arXiv:2403.08295 (tier: hf).

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000,
GeGLU, head_dim=256, tied embeddings.
"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab_size=256000,
    head_dim=256,
    activation="geglu",
    tie_embeddings=True,
    source="arXiv:2403.08295; hf",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
        d_ff=128, vocab_size=512, head_dim=16,
    )
