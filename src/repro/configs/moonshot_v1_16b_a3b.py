"""moonshot-v1-16b-a3b [moe] — hf:moonshotai/Moonlight-16B-A3B (tier: hf).

48L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=163840,
MoE: 64 routed experts, top-6 (kimi/moonlight).
"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    n_experts=64,
    n_shared_experts=0,
    top_k=6,
    expert_d_ff=1408,
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, expert_d_ff=96, n_experts=8, top_k=2, vocab_size=512,
    )
