"""chameleon-34b [vlm] — arXiv:2405.09818 (tier: unverified).

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.
Early-fusion VLM: images are VQ-quantised into the token vocabulary, so
the backbone is a plain decoder LM over the fused token stream (the VQ
tokenizer frontend is outside the assigned scope).
"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    source="arXiv:2405.09818; unverified",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab_size=512,
    )
