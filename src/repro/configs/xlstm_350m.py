"""xlstm-350m [ssm] — arXiv:2405.04517 (tier: unverified).

24L d_model=1024 4H vocab=50304; alternating sLSTM + mLSTM blocks
(1 sLSTM per 8 blocks here), matrix-memory mLSTM with expansion 2.
"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm_expand=2,
    slstm_every=8,
    source="arXiv:2405.04517; unverified",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        vocab_size=512, slstm_every=2,
    )
