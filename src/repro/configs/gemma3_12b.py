"""gemma3-12b [dense] — hf:google/gemma-3 family (tier: unverified).

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144,
5:1 local:global sliding-window attention (window 1024), 128k context,
head_dim=256, tied embeddings (gemma family).
"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=256,
    local_global=5,
    sliding_window=1024,
    activation="geglu",
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    source="hf:google/gemma-3-1b-pt; unverified",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab_size=512, head_dim=16, sliding_window=16,
    )
