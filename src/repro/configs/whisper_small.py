"""whisper-small [audio] — arXiv:2212.04356 (tier: unverified).

Enc-dec: 12+12L d_model=768 12H d_ff=3072 vocab=51865 (padded to 51968
for even TP shards).  Conv/mel frontend is a stub: input_specs() provides
precomputed frame embeddings [B, 1500, 768].
"""
import dataclasses

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    encoder_layers=12,
    encoder_frames=1500,
    qkv_bias=True,
    source="arXiv:2212.04356; unverified",
)


def smoke() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, encoder_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=512, encoder_frames=20,
    )
