"""Atomic npz checkpointing for pytrees.

Layout: ``<dir>/step_<n>/state.npz`` + ``meta.json``; writes go to a
``.tmp`` sibling and are renamed only after fsync, so a crash mid-write
never corrupts the latest checkpoint (restart picks the newest complete
step directory).  Pytree structure is recorded as flattened key paths.

On a real multi-host pod each host writes its own addressable shards
(``jax.experimental.multihost_utils``); in this single-process container
arrays are fully addressable and saved whole.
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import numpy as np

Params = Any
_SEP = "/"


def _flatten(tree: Params) -> dict[str, np.ndarray]:
    """Flatten to npz-safe arrays.  Non-native dtypes (bfloat16, fp8 — the
    ml_dtypes family numpy cannot serialise) are stored as same-width uint
    views with the true dtype recorded in the key (``name@bfloat16``)."""
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or not isinstance(
            arr.dtype.type(0).item(), (int, float, complex, bool)
        ):
            width = arr.dtype.itemsize
            uint = {1: np.uint8, 2: np.uint16, 4: np.uint32}[width]
            flat[f"{key}@{leaf.dtype.name}"] = arr.view(uint)
        else:
            flat[key] = arr
    return flat


def save_checkpoint(
    directory: str,
    step: int,
    state: Params,
    extra_meta: dict | None = None,
    keep: int = 3,
) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    np.savez(os.path.join(tmp, "state.npz"), **flat)
    meta = {"step": step, **(extra_meta or {})}
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d))


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(directory, d, "meta.json"))
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str, like: Params, step: int | None = None
) -> tuple[Params, dict]:
    """Restore into the structure (and dtypes) of ``like``."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "state.npz")) as data:
        flat = dict(data)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)

    # resolve tagged dtypes back to real arrays
    import ml_dtypes  # shipped with jax

    resolved: dict[str, np.ndarray] = {}
    for key, arr in flat.items():
        if "@" in key:
            base, dname = key.rsplit("@", 1)
            dt = np.dtype(getattr(ml_dtypes, dname, dname))
            resolved[base] = arr.view(dt)
        else:
            resolved[key] = arr

    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for pth, leaf in leaves_like:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in pth
        )
        if key not in resolved:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = resolved[key]
        out.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out
    ), meta
