"""Small AST helpers shared by the checker plugins."""

from __future__ import annotations

import ast
from typing import Iterator

__all__ = [
    "dotted_name",
    "annotation_names",
    "class_functions",
    "decorator_call_name",
    "function_scopes",
    "positional_arity",
    "walk_scope",
]


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def annotation_names(node: ast.AST | None) -> set[str]:
    """Plain type names mentioned in an annotation — handles ``X``,
    ``"X"``, ``X | None``, ``Optional[X]``, ``list[X]`` (outer + args)."""
    out: set[str] = set()
    if node is None:
        return out
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        try:
            node = ast.parse(node.value, mode="eval").body
        except SyntaxError:
            return out
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            out.add(sub.id)
        elif isinstance(sub, ast.Attribute):
            out.add(sub.attr)
    return out


def class_functions(cls: ast.ClassDef) -> dict[str, ast.FunctionDef]:
    """Directly-defined methods by name (no inheritance)."""
    return {
        stmt.name: stmt
        for stmt in cls.body
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
    }


def decorator_call_name(dec: ast.expr) -> str | None:
    """The callee name of a ``@f(...)`` decorator (``f`` for ``@m.f(...)``)."""
    if isinstance(dec, ast.Call):
        name = dotted_name(dec.func)
        if name is not None:
            return name.rsplit(".", 1)[-1]
    return None


def function_scopes(tree: ast.Module) -> Iterator[tuple[ast.AST, list[ast.stmt]]]:
    """Yield (scope node, body) for the module and every function in it.

    Class bodies are not scopes of their own here — methods are yielded
    individually, and class-level statements belong to the module walk.
    """
    yield tree, tree.body
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node, node.body


def walk_scope(body: list[ast.stmt]) -> Iterator[ast.AST]:
    """Walk statements without descending into nested function/lambda
    scopes (pair with :func:`function_scopes`, which yields each scope
    exactly once)."""
    stack: list[ast.AST] = list(body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(
            node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
        ):
            continue
        stack.extend(ast.iter_child_nodes(node))


def positional_arity(fn: ast.FunctionDef) -> tuple[int, bool]:
    """(number of named positional params, accepts-extra?) — extra means
    ``*args``/``**kwargs`` can absorb protocol arguments."""
    a = fn.args
    count = len(a.posonlyargs) + len(a.args)
    return count, a.vararg is not None or a.kwarg is not None
