"""Core of the scheduler contract analyzer.

The analyzer is a plain-``ast`` static pass (no third-party deps) that
walks a set of Python source files and runs every registered
:class:`Checker` over each of them.  A checker encodes one *standing
contract* of the scheduler core (ROADMAP "Standing contracts") as a
syntactic rule; findings carry the offending ``file:line``, the contract
name, and a fix hint, so a violation reads like a review comment rather
than a stack trace.

Suppression has two layers, both requiring a human-written justification:

* an inline pragma on the flagged line::

      x = frobnicate()  # contracts: ignore[determinism] -- why it is safe

* a committed baseline file for grandfathered findings (see
  :mod:`repro.analysis.baseline`).

A pragma without a justification is itself a finding — the point of the
pass is that every exception to a contract is explained in-tree.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Iterable, Iterator, Sequence

__all__ = [
    "Finding",
    "Checker",
    "SourceModule",
    "AnalysisContext",
    "collect_files",
    "load_module",
    "run_analysis",
]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation at a source location.

    ``key`` is a line-number-free fingerprint component (symbol-ish, e.g.
    ``"call:replay"``) so baseline entries survive unrelated edits that
    shift lines; duplicates within one ``(check, path, key)`` get an
    ``#n`` ordinal suffix appended by the runner.
    """

    check: str      # checker id, e.g. "determinism"
    contract: str   # human-readable contract name
    path: str       # posix-style path as analyzed
    line: int
    message: str
    hint: str       # how to fix (or how to legitimately suppress)
    key: str        # stable fingerprint component (no line numbers)

    @property
    def fingerprint(self) -> tuple[str, str, str]:
        return (self.check, self.path, self.key)

    def render(self) -> str:
        return (
            f"{self.path}:{self.line}: [{self.check}] {self.message}\n"
            f"    contract: {self.contract}\n"
            f"    fix: {self.hint}"
        )


_PRAGMA_RE = re.compile(
    r"#\s*contracts:\s*ignore\[(?P<checks>[\w\-*,\s]+)\]"
    r"(?:\s*--\s*(?P<why>.*\S))?"
)


@dataclasses.dataclass
class SourceModule:
    """A parsed source file plus its suppression pragmas."""

    path: str                  # normalized posix-style path
    basename: str
    text: str
    tree: ast.Module
    # line -> (set of check ids or {"*"}, justification or None)
    pragmas: dict[int, tuple[frozenset[str], str | None]]

    def pragma_for(self, check: str, line: int) -> tuple[bool, str | None]:
        """(suppressed?, justification) for ``check`` at ``line``."""
        entry = self.pragmas.get(line)
        if entry is None:
            return False, None
        checks, why = entry
        if check in checks or "*" in checks:
            return True, why
        return False, None


@dataclasses.dataclass
class AnalysisContext:
    """Cross-module state shared by all checkers in one run."""

    modules: list[SourceModule]

    def module_named(self, basename: str) -> SourceModule | None:
        for mod in self.modules:
            if mod.basename == basename:
                return mod
        return None


class Checker:
    """Base class for checker plugins.

    Subclasses set ``id`` / ``contract`` and implement :meth:`run`,
    yielding :class:`Finding`s for one module.  ``ctx`` gives access to
    every other module in the run for cross-file rules (e.g. resolving
    ``SchedulerConfig`` fields from wherever the class is defined).
    """

    id: str = ""
    contract: str = ""

    def run(self, module: SourceModule, ctx: AnalysisContext
            ) -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, module: SourceModule, line: int, message: str,
                hint: str, key: str) -> Finding:
        return Finding(
            check=self.id, contract=self.contract, path=module.path,
            line=line, message=message, hint=hint, key=key,
        )


def _norm(path: str) -> str:
    return path.replace(os.sep, "/")


def collect_files(paths: Sequence[str]) -> list[str]:
    """Expand files/directories into a sorted, deduplicated .py list."""
    out: set[str] = set()
    for p in paths:
        if os.path.isdir(p):
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = sorted(
                    d for d in dirnames if d != "__pycache__"
                )
                for fn in sorted(filenames):
                    if fn.endswith(".py"):
                        out.add(_norm(os.path.join(dirpath, fn)))
        elif p.endswith(".py"):
            out.add(_norm(p))
        else:
            raise FileNotFoundError(f"not a .py file or directory: {p}")
    return sorted(out)


def _parse_pragmas(text: str) -> dict[int, tuple[frozenset[str], str | None]]:
    pragmas: dict[int, tuple[frozenset[str], str | None]] = {}
    for lineno, line in enumerate(text.splitlines(), start=1):
        m = _PRAGMA_RE.search(line)
        if m is None:
            continue
        checks = frozenset(
            c.strip() for c in m.group("checks").split(",") if c.strip()
        )
        pragmas[lineno] = (checks, m.group("why"))
    return pragmas


def load_module(path: str) -> SourceModule:
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    tree = ast.parse(text, filename=path)
    return SourceModule(
        path=_norm(path),
        basename=os.path.basename(path),
        text=text,
        tree=tree,
        pragmas=_parse_pragmas(text),
    )


def _ordinal_keys(findings: list[Finding]) -> list[Finding]:
    """Disambiguate repeated ``(check, path, key)`` with ``#n`` suffixes,
    in (line, column-free) source order so the mapping is stable."""
    seen: dict[tuple[str, str, str], int] = {}
    out: list[Finding] = []
    for f in findings:
        n = seen.get(f.fingerprint, 0)
        seen[f.fingerprint] = n + 1
        if n:
            f = dataclasses.replace(f, key=f"{f.key}#{n + 1}")
        out.append(f)
    return out


_PRAGMA_CONTRACT = (
    "every suppression carries a one-line justification"
)


def run_analysis(
    paths: Sequence[str],
    checkers: Sequence[Checker],
    select: frozenset[str] | None = None,
) -> list[Finding]:
    """Run ``checkers`` over ``paths``; returns unsuppressed findings.

    Pragma suppression is applied here; baseline suppression is the
    caller's job (the CLI needs the used/stale entry split for
    reporting).  Findings are sorted by (path, line, check) and carry
    ordinal-disambiguated fingerprint keys.
    """
    files = collect_files(paths)
    modules: list[SourceModule] = []
    findings: list[Finding] = []
    for path in files:
        try:
            modules.append(load_module(path))
        except SyntaxError as exc:
            findings.append(Finding(
                check="parse", contract="source must parse",
                path=_norm(path), line=exc.lineno or 0,
                message=f"syntax error: {exc.msg}",
                hint="fix the syntax error", key="syntax-error",
            ))
    ctx = AnalysisContext(modules=modules)
    active = [
        c for c in checkers if select is None or c.id in select
    ]
    for mod in modules:
        raw: list[Finding] = []
        for checker in active:
            raw.extend(checker.run(mod, ctx))
        raw.sort(key=lambda f: (f.line, f.check, f.key))
        for f in raw:
            suppressed, why = mod.pragma_for(f.check, f.line)
            if not suppressed:
                findings.append(f)
            elif not why:
                findings.append(Finding(
                    check="pragma", contract=_PRAGMA_CONTRACT,
                    path=mod.path, line=f.line,
                    message=(
                        f"suppression of [{f.check}] has no justification"
                    ),
                    hint=(
                        "append `-- <reason>` to the contracts: ignore "
                        "pragma"
                    ),
                    key=f"missing-justification:{f.check}",
                ))
        # a pragma that matches nothing is stale — it documents a
        # violation that no longer exists and would silently mask a
        # future, different one on the same line.  Only meaningful when
        # every checker ran (a --select subset can't see all findings).
        for lineno, (checks, _why) in (
            sorted(mod.pragmas.items()) if select is None else ()
        ):
            live = {
                f.check for f in raw if f.line == lineno
            }
            dead = sorted(
                c for c in checks if c != "*" and c not in live
            )
            for c in dead:
                findings.append(Finding(
                    check="pragma", contract=_PRAGMA_CONTRACT,
                    path=mod.path, line=lineno,
                    message=f"stale suppression: no [{c}] finding here",
                    hint="delete the pragma (or the stale check id)",
                    key=f"stale:{c}",
                ))
    findings.sort(key=lambda f: (f.path, f.line, f.check, f.key))
    return _ordinal_keys(findings)


def walk_functions(tree: ast.AST) -> Iterator[ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node
