"""Frozen-surface check: config/result/task objects are not mutated.

``SchedulerConfig`` and ``Task`` are frozen dataclasses; ``PlanResult``
is *documented* as a builder that only its producing policy finalises
(``policy.py``'s ``BasePolicy.plan``).  An attribute assignment on any
of them from arbitrary code would either crash at runtime (the frozen
ones) or — worse for the reproducibility story — quietly rewrite a plan
after the invariant harness blessed it.  dataclasses only enforce this
dynamically and ``object.__setattr__`` bypasses even that, so the
contract is enforced here syntactically.

Type inference is local and deliberately simple: a name is considered
one of the guarded types when it is annotated as such (parameter or
variable), assigned from the type's constructor, from
``dataclasses.replace`` / ``.replace()`` of a guarded value, or from a
``.plan(...)`` / ``._plan_fresh(...)`` call (the policy protocol returns
``PlanResult``).  Mutation inside the type's *defining module* is
allowed — that is where the constructor/builder idiom lives.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.astutil import (
    annotation_names, dotted_name, function_scopes, walk_scope,
)
from repro.analysis.framework import (
    AnalysisContext, Checker, Finding, SourceModule,
)

__all__ = ["FrozenSurfaceChecker", "GUARDED_TYPES"]

# type name -> defining module (mutation allowed there: constructors,
# __post_init__, and the documented PlanResult builder in BasePolicy.plan)
GUARDED_TYPES = {
    "SchedulerConfig": "policy.py",
    "PlanResult": "policy.py",
    "Task": "problem.py",
}

# protocol methods whose return type is known repo-wide
_KNOWN_RETURNS = {"plan": "PlanResult", "_plan_fresh": "PlanResult"}


def _infer(scope_node: ast.AST, body: list[ast.stmt],
           returns: dict[str, str]) -> dict[str, str]:
    """name -> guarded type name, flow-insensitive, one scope."""
    types: dict[str, str] = {}
    if isinstance(scope_node, (ast.FunctionDef, ast.AsyncFunctionDef)):
        args = scope_node.args
        for arg in (*args.posonlyargs, *args.args, *args.kwonlyargs):
            hit = annotation_names(arg.annotation) & GUARDED_TYPES.keys()
            if hit:
                types[arg.arg] = next(iter(hit))

    def expr_type(node: ast.expr) -> str | None:
        if isinstance(node, ast.Call):
            fn = node.func
            tail = fn.attr if isinstance(fn, ast.Attribute) else (
                fn.id if isinstance(fn, ast.Name) else None
            )
            if tail is not None:
                name = dotted_name(fn) or tail
                if tail in GUARDED_TYPES:
                    return tail
                if tail in _KNOWN_RETURNS:
                    return _KNOWN_RETURNS[tail]
                if tail in returns:
                    return returns[tail]
                if tail == "replace":
                    if name in ("dataclasses.replace", "replace"):
                        if node.args:
                            return expr_type(node.args[0])
                    elif isinstance(fn, ast.Attribute):
                        return expr_type(fn.value)
        elif isinstance(node, ast.Name):
            return types.get(node.id)
        elif isinstance(node, ast.BoolOp):
            for v in node.values:
                t = expr_type(v)
                if t is not None:
                    return t
        elif isinstance(node, ast.IfExp):
            return expr_type(node.body) or expr_type(node.orelse)
        return None

    for stmt in body:
        for node in walk_scope([stmt]):
            if isinstance(node, ast.Assign):
                t = expr_type(node.value)
                if t is not None:
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            types[tgt.id] = t
            elif isinstance(node, ast.AnnAssign) \
                    and isinstance(node.target, ast.Name):
                hit = annotation_names(node.annotation) \
                    & GUARDED_TYPES.keys()
                if hit:
                    types[node.target.id] = next(iter(hit))
                elif node.value is not None:
                    t = expr_type(node.value)
                    if t is not None:
                        types[node.target.id] = t
    return types


class FrozenSurfaceChecker(Checker):
    id = "frozen-surface"
    contract = (
        "SchedulerConfig/PlanResult/Task instances are never mutated "
        "outside their defining module (constructors / replace / the "
        "documented PlanResult builder)"
    )

    def run(self, module: SourceModule, ctx: AnalysisContext
            ) -> Iterable[Finding]:
        returns = _return_types(module.tree)
        for scope_node, body in function_scopes(module.tree):
            types = _infer(scope_node, body, returns)
            fn_name = getattr(scope_node, "name", "<module>")
            for node in walk_scope(body):
                yield from self._check_node(module, node, types, fn_name)

    def _check_node(self, module, node, types: dict[str, str],
                    fn_name: str) -> Iterable[Finding]:
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for tgt in targets:
                if isinstance(tgt, ast.Attribute) \
                        and isinstance(tgt.value, ast.Name):
                    t = types.get(tgt.value.id)
                    if t is not None \
                            and module.basename != GUARDED_TYPES[t]:
                        yield self.finding(
                            module, tgt.lineno,
                            f"attribute assignment "
                            f"`{tgt.value.id}.{tgt.attr} = ...` on a "
                            f"{t} instance",
                            f"build a new {t} via its constructor or "
                            f"dataclasses.replace(); only the defining "
                            f"module may mutate",
                            key=f"mutate:{t}.{tgt.attr}",
                        )
        elif isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if name == "object.__setattr__" and len(node.args) >= 2 \
                    and fn_name not in ("__init__", "__post_init__",
                                        "__setattr__"):
                t = None
                if isinstance(node.args[0], ast.Name):
                    t = types.get(node.args[0].id)
                yield self.finding(
                    module, node.lineno,
                    "object.__setattr__ outside __init__/__post_init__"
                    + (f" on a {t} instance" if t else ""),
                    "frozen means frozen — construct a new instance "
                    "instead of bypassing the dataclass guard",
                    key="setattr-bypass",
                )


def _return_types(tree: ast.Module) -> dict[str, str]:
    """function name -> guarded return type, from annotations in this
    module (methods included — resolution is by bare name)."""
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            hit = annotation_names(node.returns) & GUARDED_TYPES.keys()
            if hit:
                out[node.name] = next(iter(hit))
    return out
