"""Checker plugin registry.

Adding a checker = write a :class:`~repro.analysis.framework.Checker`
subclass in this package and list it here; the CLI, the baseline
machinery and the test harness discover it through
:func:`all_checkers`.
"""

from __future__ import annotations

from repro.analysis.checkers.determinism import DeterminismChecker
from repro.analysis.checkers.engine_routing import EngineRoutingChecker
from repro.analysis.checkers.frozen_surface import FrozenSurfaceChecker
from repro.analysis.checkers.registry_conformance import (
    RegistryConformanceChecker,
)
from repro.analysis.checkers.undo_completeness import (
    UndoCompletenessChecker,
)

__all__ = ["all_checkers"]

_CHECKERS = (
    DeterminismChecker,
    EngineRoutingChecker,
    UndoCompletenessChecker,
    FrozenSurfaceChecker,
    RegistryConformanceChecker,
)


def all_checkers():
    """Fresh instances of every registered checker, in a fixed order."""
    return [cls() for cls in _CHECKERS]
