"""Registry-conformance check: registered plugins satisfy their protocol.

``@register_policy`` / ``@register_evaluator`` wire classes into the
string-keyed registries at import time; nothing checks the class shape
until a benchmark or the invariant harness calls it — and a typo'd
``SchedulerConfig`` field read (``config.max_refine_iters``) raises only
on the config paths a test happens to exercise.  This checker validates
statically, against the dataclass definition itself:

* every ``@register_policy`` class defines ``plan(self, tasks, spec,
  config, tail)`` or the ``BasePolicy`` hook ``_plan_fresh(self, tasks,
  spec, config)`` with the protocol arity;
* every ``@register_evaluator`` class defines ``evaluate(self, tasks,
  spec, first, deltas, config)``;
* attribute reads on a value *annotated* ``SchedulerConfig`` (or
  assigned from its constructor / ``.replace()``) name real fields —
  the field set is parsed from the ``SchedulerConfig`` class body
  wherever it is defined in the analyzed file set.  Inference is
  annotation-driven on purpose: a bare ``cfg`` name proves nothing
  (``costmodel.py`` uses it for model configs).
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.astutil import (
    annotation_names, class_functions, decorator_call_name,
    function_scopes, positional_arity, walk_scope,
)
from repro.analysis.framework import (
    AnalysisContext, Checker, Finding, SourceModule,
)

__all__ = ["RegistryConformanceChecker"]




def _config_surface(ctx: AnalysisContext) -> set[str] | None:
    """Fields + methods of SchedulerConfig, or None when the class is
    not in the analyzed file set (the field check then stays silent —
    the analyzer never guesses an API it cannot see)."""
    for mod in ctx.modules:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef) \
                    and node.name == "SchedulerConfig":
                names: set[str] = set()
                for stmt in node.body:
                    if isinstance(stmt, ast.AnnAssign) \
                            and isinstance(stmt.target, ast.Name):
                        names.add(stmt.target.id)
                    elif isinstance(stmt, ast.Assign):
                        for tgt in stmt.targets:
                            if isinstance(tgt, ast.Name):
                                names.add(tgt.id)
                    elif isinstance(
                        stmt, (ast.FunctionDef, ast.AsyncFunctionDef)
                    ):
                        names.add(stmt.name)
                return names
    return None


class RegistryConformanceChecker(Checker):
    id = "registry-conformance"
    contract = (
        "registered policies/evaluators satisfy the protocol shape and "
        "reference only existing SchedulerConfig fields"
    )

    def run(self, module: SourceModule, ctx: AnalysisContext
            ) -> Iterable[Finding]:
        surface = _config_surface(ctx)
        for node in ast.walk(module.tree):
            if isinstance(node, ast.ClassDef):
                yield from self._check_class(module, node)
        if surface is not None and module.basename != "policy.py":
            yield from self._check_config_reads(module, surface)

    def _check_class(self, module: SourceModule, cls: ast.ClassDef
                     ) -> Iterable[Finding]:
        decs = {decorator_call_name(d) for d in cls.decorator_list}
        fns = class_functions(cls)
        if "register_policy" in decs:
            plan, fresh = fns.get("plan"), fns.get("_plan_fresh")
            if plan is None and fresh is None:
                yield self.finding(
                    module, cls.lineno,
                    f"registered policy {cls.name} defines neither "
                    f"plan() nor _plan_fresh()",
                    "implement _plan_fresh(self, tasks, spec, config) "
                    "(BasePolicy handles tails) or override plan() "
                    "with the full protocol",
                    key=f"policy-missing-plan:{cls.name}",
                )
            if plan is not None:
                n, extra = positional_arity(plan)
                if n < 5 and not extra:
                    yield self.finding(
                        module, plan.lineno,
                        f"{cls.name}.plan takes {n} parameters; the "
                        f"protocol is plan(self, tasks, spec, config, "
                        f"tail)",
                        "match the SchedulerPolicy protocol — the "
                        "registry calls every policy identically",
                        key=f"policy-shape:{cls.name}.plan",
                    )
            if fresh is not None:
                n, extra = positional_arity(fresh)
                if n < 4 and not extra:
                    yield self.finding(
                        module, fresh.lineno,
                        f"{cls.name}._plan_fresh takes {n} parameters; "
                        f"the hook is _plan_fresh(self, tasks, spec, "
                        f"config)",
                        "match the BasePolicy hook signature",
                        key=f"policy-shape:{cls.name}._plan_fresh",
                    )
        if "register_evaluator" in decs:
            ev = fns.get("evaluate")
            if ev is None:
                yield self.finding(
                    module, cls.lineno,
                    f"registered evaluator {cls.name} defines no "
                    f"evaluate()",
                    "implement evaluate(self, tasks, spec, first, "
                    "deltas, config)",
                    key=f"evaluator-missing:{cls.name}",
                )
            else:
                n, extra = positional_arity(ev)
                if n < 6 and not extra:
                    yield self.finding(
                        module, ev.lineno,
                        f"{cls.name}.evaluate takes {n} parameters; "
                        f"the protocol is evaluate(self, tasks, spec, "
                        f"first, deltas, config)",
                        "match the FamilyEvaluator protocol",
                        key=f"evaluator-shape:{cls.name}.evaluate",
                    )

    def _check_config_reads(self, module: SourceModule,
                            surface: set[str]) -> Iterable[Finding]:
        for scope_node, body in function_scopes(module.tree):
            receivers = _config_receivers(scope_node, body)
            if not receivers:
                continue
            for node in walk_scope(body):
                if isinstance(node, ast.Attribute) \
                        and isinstance(node.value, ast.Name) \
                        and node.value.id in receivers \
                        and isinstance(node.ctx, ast.Load) \
                        and not node.attr.startswith("__") \
                        and node.attr not in surface:
                    yield self.finding(
                        module, node.lineno,
                        f"`{node.value.id}.{node.attr}` is not a "
                        f"SchedulerConfig field",
                        "fix the field name, or add the field to "
                        "SchedulerConfig (policy.py) with a default",
                        key=f"unknown-field:{node.attr}",
                    )


def _config_receivers(scope_node: ast.AST, body: list[ast.stmt]
                      ) -> set[str]:
    """Names in this scope proven to hold a SchedulerConfig: parameters
    annotated with it, and locals assigned from its constructor or from
    ``<receiver>.replace(...)``."""
    names: set[str] = set()
    if isinstance(scope_node, (ast.FunctionDef, ast.AsyncFunctionDef)):
        args = scope_node.args
        for arg in (*args.posonlyargs, *args.args, *args.kwonlyargs):
            if "SchedulerConfig" in annotation_names(arg.annotation):
                names.add(arg.arg)

    def is_config(node: ast.expr) -> bool:
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Name) and fn.id == "SchedulerConfig":
                return True
            if isinstance(fn, ast.Attribute) and fn.attr in (
                "SchedulerConfig", "replace"
            ) and (
                fn.attr != "replace" or is_config(fn.value)
            ):
                return True
        elif isinstance(node, ast.Name):
            return node.id in names
        elif isinstance(node, ast.BoolOp):
            return any(is_config(v) for v in node.values)
        return False

    for node in walk_scope(body):
        if isinstance(node, ast.Assign) and is_config(node.value):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    names.add(tgt.id)
        elif isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name) \
                and "SchedulerConfig" in annotation_names(node.annotation):
            names.add(node.target.id)
    return names
