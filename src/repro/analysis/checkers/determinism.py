"""Determinism lint: plan bytes must depend only on (tasks, spec, config,
seed).

The whole evaluation methodology — replay equivalence, the fault
injector's counterfactuals, cross-run benchmark comparisons — assumes a
schedule is a pure function of its inputs.  This checker flags the
syntactic ways nondeterminism leaks into that function:

* **wall-clock reads** (``time.time``, ``datetime.now``, ...).
  ``time.perf_counter`` is deliberately allowed: by repo policy it only
  feeds the ``elapsed_s``/``phase_s`` instrumentation fields, never a
  placement decision, and banning it would bury the real signal.
* **unseeded RNG** — ``random.Random()`` / ``np.random.default_rng()``
  with no seed argument, and any call through the *module-level* global
  RNG (``random.random()``, ``np.random.shuffle`` ...).  The blessed
  pattern (``synth.py`` / ``faults.py``) is a seeded constructor whose
  seed arrives from the caller.
* **iteration over sets** in ordering-sensitive positions: a ``for``
  statement, list comprehension or generator expression whose iterable
  is (or was assigned from) a set expression.  Set iteration order is
  hash-layout order; for ``str``/object elements it varies per process.
  Building an *unordered* container from a set (dict/set comprehension)
  is allowed — order only leaks when such a derived dict is itself
  iterated, which is flagged separately.  Wrapping the iterable in
  ``sorted(...)`` clears the finding.
* **``set.pop()``** — pops an arbitrary element.
* **``id(...)``** — identity reflects memory layout; used as (part of)
  a key it can order results by allocation history.

Sites that are deterministic by a non-local argument (e.g. iteration
over a set of int-tuples whose hash CPython pins, mirrored exactly by
the replay reference) are suppressed inline with a justification pragma.
"""

from __future__ import annotations

import ast
from typing import Iterable, Iterator

from repro.analysis.astutil import dotted_name, function_scopes, walk_scope
from repro.analysis.framework import (
    AnalysisContext, Checker, Finding, SourceModule,
)

__all__ = ["DeterminismChecker"]

_WALL_CLOCK = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.localtime", "time.gmtime",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today",
}

# functions on the module-level global RNG state
_RANDOM_MODULE_FNS = {
    "random", "randint", "randrange", "choice", "choices", "shuffle",
    "sample", "uniform", "gauss", "normalvariate", "betavariate",
    "expovariate", "seed", "getrandbits", "triangular",
}

# methods that return a new set from a set receiver
_SET_RETURNING_METHODS = {
    "union", "intersection", "difference", "symmetric_difference", "copy",
}

# repo APIs documented to return sets
_KNOWN_SET_APIS = {"active_keys"}


class _Scope:
    """Flow-insensitive local type marks for one function/module scope."""

    def __init__(self) -> None:
        self.sets: set[str] = set()         # names bound to set values
        self.set_dicts: set[str] = set()    # dicts comprehended over a set

    def is_set(self, node: ast.expr) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Name):
            return node.id in self.sets
        if isinstance(node, ast.BinOp) and isinstance(
            node.op, (ast.BitAnd, ast.BitOr, ast.Sub, ast.BitXor)
        ):
            return self.is_set(node.left) or self.is_set(node.right)
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Name) and fn.id in ("set", "frozenset"):
                return True
            if isinstance(fn, ast.Attribute):
                if fn.attr in _KNOWN_SET_APIS:
                    return True
                if fn.attr in _SET_RETURNING_METHODS and \
                        self.is_set(fn.value):
                    return True
        return False

    def is_set_ordered_dict(self, node: ast.expr) -> bool:
        if isinstance(node, ast.DictComp):
            return any(self.is_set(g.iter) for g in node.generators)
        if isinstance(node, ast.Name):
            return node.id in self.set_dicts
        return False


def _mark_scope(body: list[ast.stmt]) -> _Scope:
    scope = _Scope()
    for stmt in body:
        for node in walk_scope([stmt]):
            targets: list[ast.expr] = []
            value: ast.expr | None = None
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets, value = [node.target], node.value
            if value is None:
                continue
            for tgt in targets:
                if not isinstance(tgt, ast.Name):
                    continue
                if scope.is_set(value):
                    scope.sets.add(tgt.id)
                elif scope.is_set_ordered_dict(value):
                    scope.set_dicts.add(tgt.id)
    return scope


def _iterables(body: list[ast.stmt]) -> Iterator[tuple[ast.expr, str]]:
    """(iterable expression, context word) for every ordering-sensitive
    iteration in the scope body (inner function bodies excluded)."""
    for node in walk_scope(body):
        if isinstance(node, ast.For):
            yield node.iter, "for loop"
        elif isinstance(node, (ast.ListComp, ast.GeneratorExp)):
            for gen in node.generators:
                yield gen.iter, "comprehension"


def _unwrap_sorted(node: ast.expr) -> ast.expr | None:
    """The argument of a ``sorted(...)``/``min``/``max`` wrapper, if any
    (these are order-insensitive consumers of their iterable)."""
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("sorted", "min", "max", "sum", "len"):
        return node.args[0] if node.args else None
    return None


class DeterminismChecker(Checker):
    id = "determinism"
    contract = (
        "plan bytes are a pure function of (tasks, spec, config, seed)"
    )

    def run(self, module: SourceModule, ctx: AnalysisContext
            ) -> Iterable[Finding]:
        imports = _module_imports(module.tree)
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call):
                yield from self._check_call(module, node, imports)
        for _scope_node, body in function_scopes(module.tree):
            scope = _mark_scope(body)
            for it, context in _iterables(body):
                if _unwrap_sorted(it) is not None:
                    continue
                if scope.is_set(it):
                    yield self.finding(
                        module, it.lineno,
                        f"{context} iterates a set — element order is "
                        f"hash-layout order",
                        "iterate sorted(...) (or restructure so order "
                        "cannot reach a placement/tie-break decision); "
                        "if provably deterministic, suppress with a "
                        "justified pragma",
                        key=f"set-iteration:{_key_expr(it)}",
                    )
                elif scope.is_set_ordered_dict(it) or (
                    isinstance(it, ast.Call)
                    and isinstance(it.func, ast.Attribute)
                    and it.func.attr in ("values", "keys", "items")
                    and scope.is_set_ordered_dict(it.func.value)
                ):
                    yield self.finding(
                        module, it.lineno,
                        f"{context} iterates a dict whose insertion "
                        f"order came from a set",
                        "sort the set before building the dict, or "
                        "iterate sorted(d)",
                        key=f"set-ordered-dict:{_key_expr(it)}",
                    )

    def _check_call(self, module: SourceModule, node: ast.Call,
                    imports: set[str]) -> Iterator[Finding]:
        name = dotted_name(node.func)
        if name in _WALL_CLOCK:
            yield self.finding(
                module, node.lineno,
                f"wall-clock read {name}() — differs per run",
                "derive times from the simulated clock / submitted "
                "arrival times; time.perf_counter is allowed for "
                "elapsed_s-style instrumentation only",
                key=f"wall-clock:{name}",
            )
            return
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr == "pop" and not node.args:
            # .pop() with no args on a set pops an arbitrary element;
            # only flag receivers that are syntactically sets
            if isinstance(node.func.value, (ast.Set, ast.SetComp)) or (
                isinstance(node.func.value, ast.Call)
                and isinstance(node.func.value.func, ast.Name)
                and node.func.value.func.id in ("set", "frozenset")
            ):
                yield self.finding(
                    module, node.lineno,
                    "set.pop() removes an arbitrary element",
                    "pop from a sorted list, or min/max the set",
                    key="set-pop",
                )
                return
        if name is None:
            return
        head, _, tail = name.partition(".")
        # unseeded constructors
        if name in ("random.Random", "Random") and not node.args:
            yield self.finding(
                module, node.lineno,
                "random.Random() without a seed — OS-entropy seeded",
                "pass an explicit seed derived from config/spec "
                "(the synth.py / faults.py pattern)",
                key="unseeded:random.Random",
            )
            return
        if name.endswith("random.default_rng") and not node.args:
            yield self.finding(
                module, node.lineno,
                "np.random.default_rng() without a seed",
                "pass an explicit seed (generate_tasks(..., seed=) "
                "style)",
                key="unseeded:default_rng",
            )
            return
        # module-level global-RNG calls
        if head == "random" and "random" in imports \
                and tail in _RANDOM_MODULE_FNS:
            yield self.finding(
                module, node.lineno,
                f"{name}() uses the process-global RNG",
                "construct a seeded random.Random(seed) and call "
                "methods on it",
                key=f"global-rng:{name}",
            )
            return
        if head in ("np", "numpy") and tail.startswith("random.") \
                and not tail.endswith("default_rng"):
            yield self.finding(
                module, node.lineno,
                f"{name}() uses numpy's process-global RNG",
                "construct np.random.default_rng(seed) and call "
                "methods on it",
                key=f"global-rng:{name}",
            )
            return
        if isinstance(node.func, ast.Name) and node.func.id == "id":
            yield self.finding(
                module, node.lineno,
                "id(...) exposes memory layout — as a key it can order "
                "results by allocation history",
                "key on content (or a handed-out monotonic token); an "
                "identity key is only safe when a strong reference "
                "pins the object and a hit/miss cannot change output "
                "bytes — justify with a pragma if so",
                key="id-call",
            )


def _module_imports(tree: ast.Module) -> set[str]:
    names: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                names.add(alias.asname or alias.name.partition(".")[0])
    return names


def _key_expr(node: ast.expr) -> str:
    """Compact, line-free description of an iterable for fingerprints."""
    name = dotted_name(node)
    if name is not None:
        return name
    if isinstance(node, ast.Call):
        fn = dotted_name(node.func)
        return f"{fn or '<call>'}()"
    return type(node).__name__.lower()
