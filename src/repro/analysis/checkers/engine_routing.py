"""Engine-routing check: all timing flows through the TimingEngine API.

ROADMAP standing contract: "route any new timing consumer through the
engine API".  The replay layer (:mod:`repro.core.repartition`) and the
engine internals (duration chains, the undo log, the simulation caches)
are implementation surface — a consumer that folds chain times by hand
or replays per candidate silently forks the timing semantics, and the
bit-identity tests only catch it on the paths they happen to cross.

Rules (outside the blessed modules — ``timing.py`` itself,
``repartition.py`` where ``replay`` lives, and ``family_eval.py`` whose
registered evaluators are the sanctioned phase-2 scorers):

* no *call* to ``replay(...)`` — use ``make_engine`` /
  ``TimingEngine`` / ``chains_makespan`` instead.  The historical
  winner-materialisation call sites are baselined with justifications;
  new ones fail CI.
* no *unused* import of ``replay`` — dead routing surface invites the
  next call.
* no access to engine internals (``.durs``, ``._log``, ``.stretched``,
  simulation caches) on a receiver other than ``self`` — the engine
  exposes ``chain_durations()`` / ``log_length`` / accessor queries for
  every sanctioned need.
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.astutil import dotted_name
from repro.analysis.framework import (
    AnalysisContext, Checker, Finding, SourceModule,
)

__all__ = ["EngineRoutingChecker"]

BLESSED = {"timing.py", "repartition.py", "family_eval.py"}

# attributes of ChainState/TimingEngine that are implementation surface
_ENGINE_INTERNALS = {
    "durs", "stretched", "_log", "_chain_ver", "_task_node",
    "_invalidate", "_simulate", "_chain_folds", "_rc_starts", "_entries",
}

_REPLAY_HINT = (
    "route through make_engine()/TimingEngine accessors or "
    "chains_makespan(); if this site is pinned bit-identical by the "
    "equivalence tests, baseline it with a justification"
)


class EngineRoutingChecker(Checker):
    id = "engine-routing"
    contract = (
        "timing consumers go through the TimingEngine/chains_makespan "
        "API, never the replay layer or engine internals"
    )

    def run(self, module: SourceModule, ctx: AnalysisContext
            ) -> Iterable[Finding]:
        if module.basename in BLESSED:
            return
        replay_imported = False
        replay_import_line = 0
        replay_used = False
        for node in ast.walk(module.tree):
            if isinstance(node, ast.ImportFrom):
                if node.module and node.module.endswith("repartition"):
                    for alias in node.names:
                        if alias.name == "replay" and alias.asname is None:
                            replay_imported = True
                            replay_import_line = node.lineno
            elif isinstance(node, ast.Call):
                name = dotted_name(node.func)
                if name == "replay" or (
                    name is not None and name.endswith(".replay")
                    and "repartition" in name
                ):
                    replay_used = True
                    yield self.finding(
                        module, node.lineno,
                        "direct replay() call outside the timing layer",
                        _REPLAY_HINT,
                        key="call:replay",
                    )
            elif isinstance(node, ast.Attribute):
                if node.attr in _ENGINE_INTERNALS and isinstance(
                    node.value, ast.Name
                ) and node.value.id not in ("self", "cls"):
                    yield self.finding(
                        module, node.lineno,
                        f"access to engine internal "
                        f"`.{node.attr}` on `{node.value.id}`",
                        "use the public engine API (chain_durations(), "
                        "log_length, task_begin_end(), ...) — extend it "
                        "in timing.py if a query is missing",
                        key=f"internal:{node.attr}",
                    )
        # package __init__ re-exports are API surface (the equivalence
        # tests replay() against engines through it), not dead routing
        if replay_imported and not replay_used \
                and module.basename != "__init__.py":
            yield self.finding(
                module, replay_import_line,
                "unused import of replay from the repartition layer",
                "delete the import — unused routing surface invites "
                "bypassing the engine API",
                key="unused-import:replay",
            )
