"""Undo-completeness check: every logged opcode has an exact inverse.

``ChainState`` promises that after any ``apply_*`` sequence, ``undo()``
restores bit-identical state — the speculative search paths (refinement,
seam move/swap, cluster edits) and the serving rollback token depend on
it.  The contract is structural: an ``apply_*`` that appends
``("<op>", ...)`` to ``self._log`` without a matching ``kind == "<op>"``
branch in ``undo()`` (with the same tuple arity) ships a one-way edit
that only fails when a search path happens to roll it back.

Checks, per class that appends to ``self._log``:

* every logged opcode has an ``undo()`` branch (in the class or a base
  in the same module), and the branch's ``..., = entry`` unpack arity
  matches the logged tuple;
* ``undo()`` branches name only opcodes that are actually logged (a
  dead inverse is usually a renamed opcode);
* ``undo()`` ends in an explicit ``raise`` for unknown kinds — silently
  ignoring an unknown entry corrupts the rollback position;
* a subclass that overrides an ``apply_*`` method must keep the
  contract: delegate to ``super()``, log its own entry, or *explicitly
  refuse* with ``raise NotImplementedError`` (the ``ReplayEngine``
  pattern for ops it cannot replay).
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.astutil import class_functions
from repro.analysis.framework import (
    AnalysisContext, Checker, Finding, SourceModule,
)

__all__ = ["UndoCompletenessChecker"]


def _logged_ops(cls: ast.ClassDef) -> dict[str, tuple[int, int]]:
    """opcode -> (tuple arity, line) from ``self._log.append((...))``."""
    ops: dict[str, tuple[int, int]] = {}
    for node in ast.walk(cls):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if not (
            isinstance(fn, ast.Attribute) and fn.attr == "append"
            and isinstance(fn.value, ast.Attribute)
            and fn.value.attr == "_log"
            and isinstance(fn.value.value, ast.Name)
            and fn.value.value.id == "self"
        ):
            continue
        if len(node.args) == 1 and isinstance(node.args[0], ast.Tuple):
            tup = node.args[0]
            if tup.elts and isinstance(tup.elts[0], ast.Constant) \
                    and isinstance(tup.elts[0].value, str):
                ops[tup.elts[0].value] = (len(tup.elts), node.lineno)
    return ops


def _undo_branches(fn: ast.FunctionDef
                   ) -> tuple[dict[str, tuple[int | None, int]], bool]:
    """opcode -> (unpack arity or None, line) plus has-final-raise."""
    branches: dict[str, tuple[int | None, int]] = {}
    has_raise = False
    for node in ast.walk(fn):
        if not isinstance(node, ast.If):
            continue
        test = node.test
        if not (
            isinstance(test, ast.Compare)
            and len(test.ops) == 1 and isinstance(test.ops[0], ast.Eq)
            and isinstance(test.comparators[0], ast.Constant)
            and isinstance(test.comparators[0].value, str)
        ):
            continue
        op = test.comparators[0].value
        arity: int | None = None
        for sub in node.body:
            if isinstance(sub, ast.Assign) and len(sub.targets) == 1 \
                    and isinstance(sub.targets[0], ast.Tuple):
                arity = len(sub.targets[0].elts)
                break
        branches[op] = (arity, node.lineno)
        # the terminal else of the elif chain must raise
        tail = node.orelse
        if tail and not (len(tail) == 1 and isinstance(tail[0], ast.If)):
            if any(isinstance(s, ast.Raise) for s in tail):
                has_raise = True
    return branches, has_raise


def _raises_not_implemented(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Raise) and node.exc is not None:
            exc = node.exc
            name = exc.func if isinstance(exc, ast.Call) else exc
            if isinstance(name, ast.Name) \
                    and name.id == "NotImplementedError":
                return True
    return False


def _calls_super(fn: ast.FunctionDef) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id == "super":
            return True
    return False


class UndoCompletenessChecker(Checker):
    id = "undo-completeness"
    contract = (
        "every self._log opcode has an exact undo() inverse; engines "
        "explicitly refuse ops they cannot honour"
    )

    def run(self, module: SourceModule, ctx: AnalysisContext
            ) -> Iterable[Finding]:
        classes = {
            node.name: node
            for node in ast.walk(module.tree)
            if isinstance(node, ast.ClassDef)
        }
        bases = {
            name: [
                b.id for b in cls.bases if isinstance(b, ast.Name)
            ]
            for name, cls in classes.items()
        }

        def ancestry(name: str) -> list[str]:
            out, todo = [], list(bases.get(name, ()))
            while todo:
                b = todo.pop(0)
                if b in classes and b not in out:
                    out.append(b)
                    todo.extend(bases.get(b, ()))
            return out

        logging_classes = {
            name: _logged_ops(cls) for name, cls in classes.items()
            if _logged_ops(cls)
        }

        for name, ops in logging_classes.items():
            cls = classes[name]
            undo_fn = class_functions(cls).get("undo")
            if undo_fn is None:
                for anc in ancestry(name):
                    undo_fn = class_functions(classes[anc]).get("undo")
                    if undo_fn is not None:
                        break
            if undo_fn is None:
                yield self.finding(
                    module, cls.lineno,
                    f"{name} appends to self._log but defines no undo()",
                    "add an undo() with one exact-inverse branch per "
                    "opcode",
                    key=f"no-undo:{name}",
                )
                continue
            branches, has_raise = _undo_branches(undo_fn)
            for op, (arity, line) in sorted(ops.items()):
                if op not in branches:
                    yield self.finding(
                        module, line,
                        f"opcode \"{op}\" is logged by {name} but "
                        f"undo() has no branch for it",
                        "add an `elif kind == \"" + op + "\"` branch "
                        "restoring the exact pre-edit state",
                        key=f"missing-undo:{op}",
                    )
                elif branches[op][0] is not None \
                        and branches[op][0] != arity:
                    yield self.finding(
                        module, branches[op][1],
                        f"undo() unpacks {branches[op][0]} fields for "
                        f"\"{op}\" but the log entry has {arity}",
                        "make the log tuple and the undo unpack agree",
                        key=f"arity:{op}",
                    )
            for op, (_a, line) in sorted(branches.items()):
                if op not in ops:
                    yield self.finding(
                        module, line,
                        f"undo() handles \"{op}\" but no apply_* in "
                        f"{name} logs it",
                        "delete the dead branch, or restore the "
                        "apply_* that logged it",
                        key=f"orphan-undo:{op}",
                    )
            if not has_raise:
                yield self.finding(
                    module, undo_fn.lineno,
                    f"{name}.undo() has no terminal raise for unknown "
                    f"opcodes",
                    "end the elif chain with `else: raise "
                    "AssertionError(...)` so a new opcode cannot be "
                    "silently skipped",
                    key=f"no-unknown-raise:{name}",
                )

        # subclass overrides of apply_* must keep (or refuse) the contract
        for name, cls in classes.items():
            inherited_ops: dict[str, tuple[int, int]] = {}
            for anc in ancestry(name):
                inherited_ops.update(logging_classes.get(anc, {}))
            if not inherited_ops:
                continue
            own_ops = logging_classes.get(name, {})
            for mname, fn in class_functions(cls).items():
                if not mname.startswith("apply_"):
                    continue
                if _calls_super(fn) or _raises_not_implemented(fn):
                    continue
                if any(line for op, (_n, line) in own_ops.items()
                       if fn.lineno <= line <= (fn.end_lineno or line)):
                    continue  # the override logs its own entry
                yield self.finding(
                    module, fn.lineno,
                    f"{name}.{mname} overrides a logged edit without "
                    f"super(), its own log entry, or an explicit "
                    f"NotImplementedError",
                    "delegate to super(), log an undoable entry, or "
                    "refuse the op outright (the ReplayEngine pattern)",
                    key=f"override:{name}.{mname}",
                )
