"""Static contract analyzer for the scheduler core.

``python -m repro.analysis src/repro/core`` runs every checker over the
given paths and exits nonzero on unsuppressed findings.  See
``docs/api.md`` ("Static contract analysis") for the contract list, the
pragma/baseline suppression workflow, and how to write a checker.
"""

from repro.analysis.baseline import (
    BaselineEntry, BaselineError, apply_baseline, load_baseline,
    write_baseline,
)
from repro.analysis.checkers import all_checkers
from repro.analysis.framework import (
    AnalysisContext, Checker, Finding, SourceModule, run_analysis,
)

__all__ = [
    "AnalysisContext",
    "BaselineEntry",
    "BaselineError",
    "Checker",
    "Finding",
    "SourceModule",
    "all_checkers",
    "apply_baseline",
    "load_baseline",
    "run_analysis",
    "write_baseline",
]
