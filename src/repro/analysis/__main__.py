"""CLI for the scheduler contract analyzer.

Usage (from the repo root)::

    python -m repro.analysis src/repro/core
    python -m repro.analysis --select determinism,engine-routing src/...
    python -m repro.analysis --no-baseline --format json src/repro/core
    python -m repro.analysis --write-baseline src/repro/core

Exit codes: 0 clean, 1 findings / stale baseline entries, 2 usage error.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.baseline import (
    BaselineError, apply_baseline, load_baseline, write_baseline,
)
from repro.analysis.checkers import all_checkers
from repro.analysis.framework import run_analysis

DEFAULT_BASELINE = "tools/contracts_baseline.json"


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static contract analyzer for the scheduler core",
    )
    parser.add_argument("paths", nargs="*", help=".py files or directories")
    parser.add_argument(
        "--baseline", default=DEFAULT_BASELINE,
        help=f"baseline JSON (default: {DEFAULT_BASELINE}; silently "
             f"skipped if absent unless given explicitly)",
    )
    parser.add_argument(
        "--no-baseline", action="store_true",
        help="ignore the baseline file entirely",
    )
    parser.add_argument(
        "--write-baseline", action="store_true",
        help="write all current findings to the baseline file with "
             "FIXME justifications (hand-edit before committing; the "
             "loader rejects empty ones)",
    )
    parser.add_argument(
        "--select", default=None, metavar="IDS",
        help="comma-separated checker ids to run (default: all)",
    )
    parser.add_argument(
        "--list-checkers", action="store_true",
        help="print checker ids + contracts and exit",
    )
    parser.add_argument(
        "--format", choices=("text", "json"), default="text",
    )
    args = parser.parse_args(argv)

    checkers = all_checkers()
    if args.list_checkers:
        for c in checkers:
            print(f"{c.id}: {c.contract}")
        return 0
    if not args.paths:
        parser.error("no paths given")
    known = {c.id for c in checkers}
    select = None
    if args.select is not None:
        select = frozenset(s.strip() for s in args.select.split(","))
        unknown = select - known
        if unknown:
            parser.error(f"unknown checker ids: {', '.join(sorted(unknown))}")

    try:
        findings = run_analysis(args.paths, checkers, select=select)
    except (FileNotFoundError, OSError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2

    if args.write_baseline:
        write_baseline(args.baseline, findings, justification="FIXME")
        print(
            f"wrote {len(findings)} entries to {args.baseline} — replace "
            f"every FIXME with a real one-line justification"
        )
        return 0

    stale = []
    explicit_baseline = any(
        a.startswith("--baseline") for a in (argv or sys.argv[1:])
    )
    if not args.no_baseline:
        try:
            entries = load_baseline(args.baseline)
        except FileNotFoundError:
            if explicit_baseline:
                print(
                    f"error: baseline {args.baseline} not found",
                    file=sys.stderr,
                )
                return 2
            entries = []
        except BaselineError as exc:
            print(f"error: {exc}", file=sys.stderr)
            return 2
        findings, _used, stale = apply_baseline(findings, entries)

    if args.format == "json":
        print(json.dumps(
            {
                "findings": [
                    {
                        "check": f.check, "contract": f.contract,
                        "path": f.path, "line": f.line,
                        "message": f.message, "hint": f.hint, "key": f.key,
                    }
                    for f in findings
                ],
                "stale_baseline": [
                    {"check": e.check, "path": e.path, "key": e.key}
                    for e in stale
                ],
            },
            indent=2,
        ))
    else:
        for f in findings:
            print(f.render())
        for e in stale:
            print(
                f"{e.path}: stale baseline entry [{e.check}] key="
                f"{e.key!r} — the finding is gone; delete the entry"
            )
        if findings or stale:
            print(
                f"\n{len(findings)} finding(s), "
                f"{len(stale)} stale baseline entrie(s)"
            )
        else:
            print("clean: no contract violations")
    return 1 if findings or stale else 0


if __name__ == "__main__":
    sys.exit(main())
