"""Baseline (grandfathered-findings) support for the contract analyzer.

The baseline is a committed JSON file mapping known findings to one-line
justifications.  It exists for violations that are *correct by a
non-local argument* the static pass cannot see — e.g. the phase-3 winner
materialisation calling :func:`repro.core.repartition.replay` directly
(pinned bit-identical by the equivalence tests) — so the analyzer can be
blocking in CI without forcing no-op churn.

Matching is by fingerprint ``(check, path, key)``, not line number, so
unrelated edits don't invalidate entries.  Every entry MUST carry a
non-empty ``justification``; stale entries (matching no current finding)
fail the run — an expired suppression means the violation was fixed and
the baseline must shrink with it.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Sequence

from repro.analysis.framework import Finding

__all__ = ["BaselineEntry", "BaselineError", "load_baseline",
           "apply_baseline", "write_baseline"]

_VERSION = 1


class BaselineError(ValueError):
    """Malformed baseline file (bad shape, missing justification, ...)."""


@dataclasses.dataclass(frozen=True)
class BaselineEntry:
    check: str
    path: str
    key: str
    justification: str

    @property
    def fingerprint(self) -> tuple[str, str, str]:
        return (self.check, self.path, self.key)


def load_baseline(path: str) -> list[BaselineEntry]:
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    if not isinstance(data, dict) or data.get("version") != _VERSION:
        raise BaselineError(
            f"{path}: expected a baseline object with version={_VERSION}"
        )
    entries: list[BaselineEntry] = []
    seen: set[tuple[str, str, str]] = set()
    for i, raw in enumerate(data.get("entries", [])):
        try:
            entry = BaselineEntry(
                check=raw["check"], path=raw["path"], key=raw["key"],
                justification=raw["justification"],
            )
        except (TypeError, KeyError) as exc:
            raise BaselineError(
                f"{path}: entry {i} is missing field {exc}"
            ) from exc
        if not entry.justification.strip():
            raise BaselineError(
                f"{path}: entry {i} ({entry.check} @ {entry.path} "
                f"[{entry.key}]) has an empty justification — every "
                f"baselined finding needs a one-line reason"
            )
        if entry.fingerprint in seen:
            raise BaselineError(
                f"{path}: duplicate entry for {entry.fingerprint}"
            )
        seen.add(entry.fingerprint)
        entries.append(entry)
    return entries


def apply_baseline(
    findings: Sequence[Finding], entries: Sequence[BaselineEntry],
) -> tuple[list[Finding], list[BaselineEntry], list[BaselineEntry]]:
    """Split findings against the baseline.

    Returns ``(unsuppressed findings, used entries, stale entries)``.
    """
    by_fp = {e.fingerprint: e for e in entries}
    used: dict[tuple[str, str, str], BaselineEntry] = {}
    out: list[Finding] = []
    for f in findings:
        entry = by_fp.get(f.fingerprint)
        if entry is None:
            out.append(f)
        else:
            used[entry.fingerprint] = entry
    stale = [e for e in entries if e.fingerprint not in used]
    return out, list(used.values()), stale


def write_baseline(path: str, findings: Sequence[Finding],
                   justification: str) -> None:
    """Emit a baseline covering ``findings``, every entry stamped with
    the same placeholder ``justification`` (meant to be hand-edited —
    the loader rejects empty ones, and review should reject lazy ones).
    """
    data = {
        "version": _VERSION,
        "entries": [
            {
                "check": f.check, "path": f.path, "key": f.key,
                "justification": justification,
            }
            for f in findings
        ],
    }
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(data, fh, indent=2, sort_keys=False)
        fh.write("\n")
