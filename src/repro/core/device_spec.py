"""Device specifications for MIG-style reconfigurable accelerators.

The paper (§1.2) relies on exactly two structural properties of MIG:

  (P1) instances are organised hierarchically (a *repartitioning tree*:
       an instance is split into disjoint child instances);
  (P2) the valid partitions are precisely the combinations of disjoint
       instances (antichains of the tree that tile the device).

``DeviceSpec`` encodes a device as such a tree (or forest, for multi-GPU /
multi-pod setups, paper §3.2 "multiple A30s"), together with the instance
sizes ``C_G`` and the reconfiguration-cost tables (paper Table 1).

Paper-faithful specs: ``A30``, ``A100``, ``H100``.
TPU-adapted specs (DESIGN.md §2): ``TPU_POD_256`` (8 pod-slices of 32 chips,
full binary tree) and ``TPU_SUPERPOD_512`` (two such pods as a forest).
"""

from __future__ import annotations

import dataclasses
import itertools
from functools import cached_property
from typing import Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class InstanceNode:
    """One node of a repartitioning tree.

    Attributes:
      tree: index of the tree in the forest (one tree per GPU/pod).
      start: first slice index covered by the *footprint* of this instance.
      size: the instance size in ``C_G`` terms (what ``t_i`` is indexed by —
        the number of slices whose compute the task may use).
      footprint: number of consecutive slices *blocked* by this instance.
        Usually ``== size``; the A100/H100 "3-slice instance on S0..S2 with
        S3's memory" has size 3 but footprint 4 (S3 sits idle but reserved,
        paper §1.2 / §5.2 case 3).
      children: child nodes the instance repartitions into.
    """

    tree: int
    start: int
    size: int
    footprint: int
    children: tuple["InstanceNode", ...] = ()

    # -- identity ----------------------------------------------------------
    @cached_property
    def key(self) -> tuple[int, int, int, int]:
        """Stable identity of the node inside its spec (cached — the
        scheduler hot paths read it millions of times)."""
        return (self.tree, self.start, self.size, self.footprint)

    @property
    def slices(self) -> tuple[int, ...]:
        """Slice indexes whose *compute* the instance uses."""
        return tuple(range(self.start, self.start + self.size))

    @property
    def blocked(self) -> tuple[int, ...]:
        """Slice indexes reserved by the instance (compute + idle)."""
        return tuple(range(self.start, self.start + self.footprint))

    @cached_property
    def blocked_cells(self) -> frozenset[tuple[int, int]]:
        """``{(tree, slice)}`` cells reserved by the instance, precomputed
        once — the conflict/release checks in replay, the timing engine and
        schedule validation are hot enough that rebuilding this set per call
        measurably dominates."""
        return frozenset((self.tree, s) for s in self.blocked)

    @cached_property
    def compute_cells(self) -> tuple[tuple[int, int], ...]:
        """``(tree, slice)`` cells whose *compute* the instance uses."""
        return tuple((self.tree, s) for s in self.slices)

    def __repr__(self) -> str:  # compact, used in schedule dumps
        tag = f"T{self.tree}[{self.start}:{self.start + self.footprint}]"
        if self.footprint != self.size:
            tag += f"(={self.size})"
        return tag


def _binary_tree(tree: int, start: int, size: int) -> InstanceNode:
    """Full binary repartitioning tree over ``size`` slices (power of two)."""
    if size == 1:
        return InstanceNode(tree, start, 1, 1)
    half = size // 2
    return InstanceNode(
        tree, start, size, size,
        children=(_binary_tree(tree, start, half),
                  _binary_tree(tree, start + half, half)),
    )


def _a100_tree(tree: int = 0) -> InstanceNode:
    """A100/H100 repartitioning tree (paper Fig. 4).

    7 -> (4 on S0..S3, 3 on S4..S6)
    the 4 repartitions into the special 3-with-S3-idle instance, which in
    turn repartitions into 2+2 (re-enabling S3); 3 -> 2+1; 2 -> 1+1.
    """
    ones = [InstanceNode(tree, s, 1, 1) for s in range(7)]
    two_01 = InstanceNode(tree, 0, 2, 2, (ones[0], ones[1]))
    two_23 = InstanceNode(tree, 2, 2, 2, (ones[2], ones[3]))
    two_45 = InstanceNode(tree, 4, 2, 2, (ones[4], ones[5]))
    three_idle = InstanceNode(tree, 0, 3, 4, (two_01, two_23))  # S3 idle
    four = InstanceNode(tree, 0, 4, 4, (three_idle,))
    three_r = InstanceNode(tree, 4, 3, 3, (two_45, ones[6]))
    return InstanceNode(tree, 0, 7, 7, (four, three_r))


def _a30_tree(tree: int = 0) -> InstanceNode:
    """A30 repartitioning tree (paper Fig. 4): 4 -> 2+2 -> (1+1)x2."""
    ones = [InstanceNode(tree, s, 1, 1) for s in range(4)]
    two_01 = InstanceNode(tree, 0, 2, 2, (ones[0], ones[1]))
    two_23 = InstanceNode(tree, 2, 2, 2, (ones[2], ones[3]))
    return InstanceNode(tree, 0, 4, 4, (two_01, two_23))


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """A reconfigurable device (or homogeneous group of them).

    Attributes:
      name: e.g. ``"A100"``.
      roots: one repartitioning tree per physical device (paper §3.2 allows a
        forest for multi-GPU; we use it for multi-pod too).
      sizes: the instance sizes ``C_G`` (sorted ascending).
      t_create / t_destroy: reconfiguration cost per instance size, seconds
        (paper Table 1).
      chips_per_slice: TPU adaptation — how many chips one slice stands for
        (1 for the GPU models).
      kind: the instance *type* this device's profiles are keyed by
        (``Profile[(kind, size)]``).  Defaults to ``name``; derived specs
        (``multi_gpu``, ``degrade``, cluster membership) keep the base
        kind so one profile serves every A100 in a fleet, however the
        forest is arranged.
      reconfig_scope: how reconfiguration windows serialise — ``"tree"``
        (per GPU/driver, paper §2.1: each device has its own driver, so
        trees of a forest reconfigure concurrently) or ``"global"`` (the
        pre-fix behaviour that coupled all trees through one sequence;
        kept selectable so the fidelity delta stays measurable).  The
        two are identical on single-tree specs.
    """

    name: str
    roots: tuple[InstanceNode, ...]
    sizes: tuple[int, ...]
    t_create: Mapping[int, float]
    t_destroy: Mapping[int, float]
    chips_per_slice: int = 1
    kind: str = ""
    reconfig_scope: str = "tree"

    @property
    def device_kind(self) -> str:
        """The profile key for this device (``kind``, or ``name``)."""
        return self.kind or self.name

    # -- structure ---------------------------------------------------------
    @cached_property
    def nodes(self) -> tuple[InstanceNode, ...]:
        """All instance nodes, BFS order, roots first."""
        out: list[InstanceNode] = []
        frontier = list(self.roots)
        while frontier:
            node = frontier.pop(0)
            out.append(node)
            frontier.extend(node.children)
        return tuple(out)

    @cached_property
    def n_slices(self) -> int:
        return sum(r.footprint for r in self.roots)

    @cached_property
    def nodes_by_size(self) -> Mapping[int, tuple[InstanceNode, ...]]:
        by: dict[int, list[InstanceNode]] = {s: [] for s in self.sizes}
        for node in self.nodes:
            by[node.size].append(node)
        return {s: tuple(v) for s, v in by.items()}

    @cached_property
    def node_index(self) -> Mapping[tuple[int, int, int, int], InstanceNode]:
        """O(1) node lookup by key (replay and the timing engine resolve
        alive-instance keys on every evaluation)."""
        return {node.key: node for node in self.nodes}

    def node_by_key(self, key: tuple[int, int, int, int]) -> InstanceNode:
        try:
            return self.node_index[key]
        except KeyError:
            raise KeyError(key) from None

    @cached_property
    def valid_partitions(self) -> tuple[tuple[InstanceNode, ...], ...]:
        """Enumerate valid partitions = antichains of disjoint nodes that
        tile each tree (paper Fig. 1: 5 for A30, 19 for A100/H100).

        A node "tiles" its footprint; the special A100 3-instance tiles
        4 slices (S3 idle). Enumerated per tree and combined.
        """

        def tilings(node: InstanceNode) -> list[tuple[InstanceNode, ...]]:
            options: list[tuple[InstanceNode, ...]] = [(node,)]
            if node.children:
                # children of a node partition its footprint between them
                child_opts = [tilings(c) for c in node.children]
                for combo in itertools.product(*child_opts):
                    merged = tuple(itertools.chain.from_iterable(combo))
                    options.append(merged)
            return options

        per_tree = [tilings(r) for r in self.roots]
        out = []
        for combo in itertools.product(*per_tree):
            out.append(tuple(itertools.chain.from_iterable(combo)))
        # dedupe (chains like 4 -> 3' produce the same multiset never; but
        # keep deterministic order)
        seen = set()
        uniq = []
        for p in out:
            k = tuple(sorted(n.key for n in p))
            if k not in seen:
                seen.add(k)
                uniq.append(p)
        return tuple(uniq)

    def is_feasible_instance_set(self, nodes: Sequence[InstanceNode]) -> bool:
        """(P2): any set of pairwise-disjoint tree nodes is a sub-partition."""
        blocked: set[tuple[int, int]] = set()
        node_keys = self.node_index
        for node in nodes:
            if node.key not in node_keys:
                return False
            cells = node.blocked_cells
            if blocked & cells:
                return False
            blocked |= cells
        return True

    # -- fault tolerance (DESIGN.md §8) -------------------------------------
    def degrade(self, dead_slices: Sequence[tuple[int, int]]) -> "DeviceSpec":
        """Return a spec with every instance touching a dead (tree, slice)
        removed — the subtree rooted at the smallest healthy ancestors
        survives. Used by the elastic runtime on node failure."""
        dead = set(dead_slices)

        def prune(node: InstanceNode) -> list[InstanceNode]:
            """Largest healthy subtrees under ``node`` (forest roots)."""
            hit = any((node.tree, s) in dead for s in node.blocked)
            if not hit:
                return [node]
            out: list[InstanceNode] = []
            for child in node.children:
                out.extend(prune(child))
            return out

        new_roots = [n for root in self.roots for n in prune(root)]
        sizes = tuple(sorted({n.size for r in new_roots
                              for n in _iter_nodes(r)}))
        # the reconfiguration tables must shrink with the sizes: a stale
        # entry for a size no longer in the tree would let timing code
        # charge windows for instances that cannot exist
        return dataclasses.replace(
            self,
            name=f"{self.name}-degraded",
            kind=self.device_kind,
            roots=tuple(new_roots),
            sizes=sizes,
            t_create={s: self.t_create[s] for s in sizes},
            t_destroy={s: self.t_destroy[s] for s in sizes},
        )


def _iter_nodes(root: InstanceNode):
    yield root
    for c in root.children:
        yield from _iter_nodes(c)


# ---------------------------------------------------------------------------
# Paper-faithful GPU specs (reconfig times: paper Table 1, seconds)
# ---------------------------------------------------------------------------

A30 = DeviceSpec(
    name="A30",
    roots=(_a30_tree(),),
    sizes=(1, 2, 4),
    t_create={1: 0.11, 2: 0.12, 4: 0.13},
    t_destroy={1: 0.10, 2: 0.10, 4: 0.10},
)

A100 = DeviceSpec(
    name="A100",
    roots=(_a100_tree(),),
    sizes=(1, 2, 3, 4, 7),
    t_create={1: 0.16, 2: 0.17, 3: 0.20, 4: 0.21, 7: 0.24},
    t_destroy={1: 0.20, 2: 0.20, 3: 0.21, 4: 0.21, 7: 0.22},
)

H100 = DeviceSpec(
    name="H100",
    roots=(_a100_tree(),),
    sizes=(1, 2, 3, 4, 7),
    t_create={1: 0.16, 2: 0.21, 3: 0.33, 4: 0.38, 7: 0.42},
    t_destroy={1: 0.21, 2: 0.23, 3: 0.25, 4: 0.26, 7: 0.26},
)


def retree(node: InstanceNode, tree: int) -> InstanceNode:
    """Copy of ``node``'s subtree re-indexed onto forest tree ``tree`` —
    shared by :func:`multi_gpu` and the heterogeneous cluster builder
    (:mod:`repro.core.cluster`), which needs globally-unique tree ids."""
    return InstanceNode(
        tree, node.start, node.size, node.footprint,
        tuple(retree(c, tree) for c in node.children),
    )


def multi_gpu(spec: DeviceSpec, count: int) -> DeviceSpec:
    """Forest of ``count`` identical devices (paper §3.2)."""
    roots = []
    for g in range(count):
        roots.append(retree(spec.roots[0], g))
    return dataclasses.replace(
        spec, name=f"{spec.name}x{count}", kind=spec.device_kind,
        roots=tuple(roots),
    )


# ---------------------------------------------------------------------------
# TPU-adapted specs (DESIGN.md §2): a v5e pod of 256 chips carved into 8
# pod-slices of 32 chips each ((2,16) blocks of the (16,16) mesh).  Instance
# formation cost models sub-mesh (re)formation: barrier + runtime re-init,
# scaled mildly with size (measured MIG times are the GPU analogue; for TPU
# we budget 1-4 s, dominated by coordination, NOT compile — compile caches
# are warm in steady state).
# ---------------------------------------------------------------------------

TPU_POD_256 = DeviceSpec(
    name="TPU_POD_256",
    roots=(_binary_tree(0, 0, 8),),
    sizes=(1, 2, 4, 8),
    t_create={1: 1.0, 2: 1.2, 4: 1.6, 8: 2.4},
    t_destroy={1: 0.5, 2: 0.6, 4: 0.8, 8: 1.2},
    chips_per_slice=32,
)

TPU_SUPERPOD_512 = dataclasses.replace(
    multi_gpu(TPU_POD_256, 2), name="TPU_SUPERPOD_512"
)

SPECS: dict[str, DeviceSpec] = {
    "A30": A30,
    "A100": A100,
    "H100": H100,
    "TPU_POD_256": TPU_POD_256,
    "TPU_SUPERPOD_512": TPU_SUPERPOD_512,
}
