"""FAR — Family of Allocations and Repartitioning (paper §3).

``schedule_batch`` runs the three phases:

  1. generate the Turek allocation family (``allocations``);
  2. schedule every allocation with Algorithm 1 (``repartition``) and keep
     the one with the smallest makespan;
  3. refine the winner with task moves/swaps (``refine``).

An admissible pruning accelerates phase 2: along the family the per-task
work is non-decreasing (each step re-minimises over strictly larger sizes)
while ``h_max`` is non-increasing, so once ``area / #slices`` alone reaches
the incumbent makespan every later allocation is dominated and the loop can
stop.  This never changes the selected schedule, only skips provably-worse
candidates.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

from repro.core.allocations import Allocation, allocation_family
from repro.core.device_spec import DeviceSpec
from repro.core.problem import EPS, Schedule, Task, area_lower_bound
from repro.core.refine import RefineStats, refine_assignment
from repro.core.repartition import (
    Assignment,
    list_schedule_allocation,
    replay,
)


@dataclasses.dataclass
class FARResult:
    schedule: Schedule
    assignment: Assignment
    allocation: Allocation
    family_size: int
    evaluated: int              # allocations actually scheduled (post-pruning)
    winner_index: int
    refine_stats: RefineStats | None
    makespan_before_refine: float
    elapsed_s: float

    @property
    def makespan(self) -> float:
        return self.schedule.makespan


def schedule_batch(
    tasks: Sequence[Task],
    spec: DeviceSpec,
    refine: bool = True,
    max_refine_iterations: int = 64,
    prune: bool = True,
    deep_refine: bool = False,
) -> FARResult:
    """Run FAR on one batch of tasks.

    ``deep_refine`` (beyond-paper) follows phase 3 with an exact-evaluation
    greedy move/swap search (the §4.3 seam engine against an empty tail):
    each candidate edit is scored by a full replay, so it monotonically
    improves and tends to pick up the last few percent on small batches
    where the paper's margin heuristics run out."""
    t0 = time.perf_counter()
    if not tasks:
        empty = Assignment(spec, {}, {})
        return FARResult(
            replay(empty), empty, (), 1, 0, 0, None, 0.0,
            time.perf_counter() - t0,
        )
    for task in tasks:
        missing = [s for s in spec.sizes if s not in task.times]
        if missing:
            raise ValueError(
                f"task {task.id} lacks times for sizes {missing} on {spec.name}"
            )

    family = allocation_family(tasks, spec)

    best: tuple[float, int, Assignment, Schedule, Allocation] | None = None
    evaluated = 0
    for idx, alloc in enumerate(family):
        if prune and best is not None:
            area = sum(
                s * t.times[s] for t, s in zip(tasks, alloc)
            )
            if area / spec.n_slices >= best[0] - EPS:
                break  # all later allocations have >= area -> dominated
        assignment = list_schedule_allocation(tasks, alloc, spec)
        schedule = replay(assignment)
        evaluated += 1
        if best is None or schedule.makespan < best[0] - EPS:
            best = (schedule.makespan, idx, assignment, schedule, alloc)

    assert best is not None
    makespan_p2, win_idx, assignment, schedule, alloc = best

    stats: RefineStats | None = None
    if refine:
        assignment, schedule, stats = refine_assignment(
            assignment, max_iterations=max_refine_iterations
        )
    if deep_refine:
        from repro.core.multibatch import Tail, seam_refine

        assignment2, schedule2, mv, sw = seam_refine(
            assignment, Tail.empty(spec), "forward"
        )
        if schedule2.makespan < schedule.makespan - EPS:
            assignment, schedule = assignment2, schedule2
            if stats is not None:
                stats.moves += mv
                stats.swaps += sw

    return FARResult(
        schedule=schedule,
        assignment=assignment,
        allocation=alloc,
        family_size=len(family),
        evaluated=evaluated,
        winner_index=win_idx,
        refine_stats=stats,
        makespan_before_refine=makespan_p2,
        elapsed_s=time.perf_counter() - t0,
    )


def rho(result: FARResult, tasks: Sequence[Task]) -> float:
    """Paper §6.4 error-vs-optimum proxy: makespan / area lower bound."""
    return result.makespan / area_lower_bound(tasks, result.schedule.spec)
