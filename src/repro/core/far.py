"""FAR — Family of Allocations and Repartitioning (paper §3).

``schedule_batch`` runs the three phases:

  1. generate the Turek allocation family (``allocations``);
  2. schedule every allocation with Algorithm 1 (``repartition``) and keep
     the one with the smallest makespan;
  3. refine the winner with task moves/swaps (``refine``).

An admissible pruning accelerates phase 2: along the family the per-task
work is non-decreasing (each step re-minimises over strictly larger sizes)
while ``h_max`` is non-increasing, so once ``area / #slices`` alone reaches
the incumbent makespan every later allocation is dominated and the loop can
stop.  This never changes the selected schedule, only skips provably-worse
candidates.

All knobs live in :class:`~repro.core.policy.SchedulerConfig`;
``schedule_batch(tasks, spec, config=...)`` is the direct entry point and
``get_policy("far").plan(...)`` the registry one.  The legacy boolean
kwargs (``refine=``/``prune=``/``deep_refine=``/``use_engine=``) still
work through a deprecation shim that names the config field to use.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Sequence

from repro.core.allocations import Allocation, allocation_family_deltas
from repro.core.device_spec import DeviceSpec
from repro.core.family_eval import get_evaluator, resolve_evaluator
from repro.core.policy import (
    LEGACY_KWARGS,
    BasePolicy,
    PlanResult,
    SchedulerConfig,
    register_policy,
)
from repro.core.problem import Schedule, Task, area_lower_bound, bind_tasks
from repro.core.refine import RefineStats, refine_assignment
from repro.core.repartition import Assignment, replay


@dataclasses.dataclass
class FARResult:
    schedule: Schedule
    assignment: Assignment
    allocation: Allocation
    family_size: int
    evaluated: int              # allocations actually scheduled (post-pruning)
    winner_index: int
    refine_stats: RefineStats | None
    makespan_before_refine: float
    elapsed_s: float
    phase_s: dict | None = None  # wall time per phase (family/evaluate/refine)

    @property
    def makespan(self) -> float:
        return self.schedule.makespan


def schedule_batch(
    tasks: Sequence[Task],
    spec: DeviceSpec,
    config: SchedulerConfig | None = None,
    **legacy,
) -> FARResult:
    """Run FAR on one batch of tasks (back-compat wrapper).

    Builds a :class:`SchedulerConfig` from the legacy boolean kwargs (each
    emits a :class:`DeprecationWarning` naming the config field to use)
    and delegates to the config-driven implementation.
    """
    if config is not None and not isinstance(config, SchedulerConfig):
        # the pre-config signature took refine positionally third; reject
        # loudly instead of silently binding a boolean to `config`
        raise TypeError(
            f"schedule_batch() third argument must be a SchedulerConfig, "
            f"got {type(config).__name__}; legacy positional booleans "
            f"moved to SchedulerConfig fields (e.g. SchedulerConfig("
            f"refine=...))"
        )
    if legacy:
        changes = {}
        for name, value in legacy.items():
            field = LEGACY_KWARGS.get(name)
            if field is None:
                raise TypeError(
                    f"schedule_batch() got an unexpected keyword argument "
                    f"{name!r}"
                )
            warnings.warn(
                f"schedule_batch({name}=...) is deprecated; pass "
                f"config=SchedulerConfig({field}=...) instead",
                DeprecationWarning,
                stacklevel=2,
            )
            changes[field] = value
        config = (config or SchedulerConfig()).replace(**changes)
    return far_schedule(tasks, spec, config or SchedulerConfig())


def far_schedule(
    tasks: Sequence[Task],
    spec: DeviceSpec,
    config: SchedulerConfig,
) -> FARResult:
    """The three FAR phases, driven entirely by ``config``.

    ``config.deep_refine`` (beyond-paper) follows phase 3 with an
    exact-evaluation greedy move/swap search (the §4.3 seam engine against
    an empty tail): each candidate edit is scored exactly, so it
    monotonically improves and tends to pick up the last few percent on
    small batches where the paper's margin heuristics run out.

    ``config.use_engine`` selects the incremental timing path (warm-started
    family evaluation + engine-scored refinement, default) or the cold
    replay-per-candidate reference path.  Both produce identical schedules;
    the flag exists for the equivalence tests and perf baselines.

    ``config.evaluator`` selects the phase-2 family evaluator —
    ``"sequential"``, ``"vectorized"`` (chunked array-program scoring) or
    ``"auto"`` — all bit-identical in output; see
    :mod:`repro.core.family_eval`."""
    eps = config.eps
    t0 = time.perf_counter()
    if not tasks:
        empty = Assignment(spec, {}, {})
        return FARResult(
            replay(empty), empty, (), 1, 0, 0, None, 0.0,
            time.perf_counter() - t0,
        )
    # heterogeneous profiles are lowered onto this device's kind here;
    # size-keyed tasks pass through untouched (the back-compat shim)
    tasks = bind_tasks(tasks, spec)
    sizes_needed = set(spec.sizes)
    for task in tasks:
        if not sizes_needed <= task.times.keys():
            missing = [s for s in spec.sizes if s not in task.times]
            raise ValueError(
                f"task {task.id} lacks times for sizes {missing} on {spec.name}"
            )

    first, deltas = allocation_family_deltas(tasks, spec)
    family_size = len(deltas) + 1
    t1 = time.perf_counter()

    # Phase 2: score the family through the configured evaluator
    # (family_eval.py).  "sequential" warm-starts per-size LPT groups
    # across the one-task deltas and scores each candidate with the lean
    # chains_makespan; "vectorized" lowers the same simulation into a
    # chunked array program; both select the identical EPS-ordered winner
    # and only the winner is ever replayed into a Schedule.
    evaluator = get_evaluator(
        resolve_evaluator(config, len(tasks), family_size)
    )
    winner = evaluator.evaluate(tasks, spec, first, deltas, config)
    makespan_p2 = winner.makespan
    win_idx = winner.index
    assignment = winner.assignment
    winner_alloc = winner.allocation
    evaluated = winner.evaluated
    t2 = time.perf_counter()

    stats: RefineStats | None = None
    schedule: Schedule
    if config.refine:
        # the winner's un-refined Schedule is never consumed when phase 3
        # runs (it re-derives the final one), so skip that replay entirely
        assignment, schedule, stats = refine_assignment(
            assignment, max_iterations=config.max_refine_iterations,
            use_engine=config.use_engine,
        )
    else:
        schedule = replay(assignment)
    if config.deep_refine:
        from repro.core.multibatch import Tail, seam_refine

        assignment2, schedule2, mv, sw = seam_refine(
            assignment, Tail.empty(spec), "forward",
            use_engine=config.use_engine,
        )
        if schedule2.makespan < schedule.makespan - eps:
            assignment, schedule = assignment2, schedule2
            if stats is not None:
                stats.moves += mv
                stats.swaps += sw
    t3 = time.perf_counter()

    return FARResult(
        schedule=schedule,
        assignment=assignment,
        allocation=winner_alloc,
        family_size=family_size,
        evaluated=evaluated,
        winner_index=win_idx,
        refine_stats=stats,
        makespan_before_refine=makespan_p2,
        elapsed_s=time.perf_counter() - t0,
        phase_s={"family": t1 - t0, "evaluate": t2 - t1, "refine": t3 - t2},
    )


@register_policy("far")
class FARPolicy(BasePolicy):
    """The paper's FAR scheduler as a registry policy."""

    def _plan_fresh(
        self, tasks: Sequence[Task], spec: DeviceSpec, config: SchedulerConfig
    ) -> PlanResult:
        far = far_schedule(tasks, spec, config)
        return PlanResult(
            policy=self.name,
            schedule=far.schedule,
            makespan=far.makespan,
            assignment=far.assignment,
            elapsed_s=far.elapsed_s,
            phase_s=far.phase_s,
            extras={"far": far},
        )


def rho(result: FARResult | PlanResult, tasks: Sequence[Task]) -> float:
    """Paper §6.4 error-vs-optimum proxy: makespan / area lower bound."""
    return result.makespan / area_lower_bound(tasks, result.schedule.spec)
