"""Problem model: tasks, profiles, schedules and feasibility validation
(paper §2.2).

A :class:`Task` carries its execution-time profile ``t_i : C_G -> R+`` —
either a plain size-keyed mapping (one device model, the paper's setting)
or a :class:`Profile` keyed by *instance type* ``(device_kind, size)`` so
one task can be scheduled anywhere in a heterogeneous fleet (cf.
MIG-Serving, arXiv:2109.11067).  The scheduler core always works on
size-keyed mappings: :meth:`Task.bind` lowers a Profile task onto one
device kind at the scheduling boundary, and is the *identity* for plain
size-keyed tasks — which is exactly the back-compat shim: existing
single-device callers run bit-identical code on the very same objects.

A :class:`Schedule` assigns each task an instance (a repartitioning-tree
node) and a begin time, plus the reconfiguration windows implied by the
tree.  :func:`validate_schedule` checks the paper's three constraints:

  1. tasks whose instances share slices do not overlap in time;
  2. at any instant the running instances are a subset of a valid partition
     (equivalent, by MIG property P2, to: all instances are tree nodes and
     pairwise-disjoint instances whenever they co-run — implied by 1);
  3. reconfigurations are sequential *per driver*: creation/destruction
     windows never overlap within one tree's sequence (the NVIDIA driver
     serialises per GPU, paper §2.1 — trees of a forest reconfigure
     concurrently unless the spec pins ``reconfig_scope="global"``), and
     an instance's first task starts only after its creation window, which
     itself follows the destruction of its parent.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence

from repro.core.device_spec import DeviceSpec, InstanceNode

EPS = 1e-9  # float tolerance for feasibility checks


class ProfileCoverageError(KeyError, ValueError):
    """A task's profile has no entry for an instance type it is asked to
    run on.  Subclasses both :class:`KeyError` and :class:`ValueError`
    so pre-existing guards (``except KeyError`` around profile lookups,
    ``except ValueError`` / ``pytest.raises(ValueError)`` around
    ``partition_batch``) keep working, but carries the task and the
    missing ``(device_kind, size)`` key so the failure is actionable at
    the API boundary instead of a bare ``KeyError: 'h100'`` deep inside
    ``partition_batch``/``Task.bind``."""

    def __init__(self, task_id: int | None, kind: str, size: int | None = None,
                 detail: str = ""):
        self.task_id = task_id
        self.kind = kind
        self.size = size
        key = f"({kind!r}, {size})" if size is not None else f"{kind!r}"
        who = f"task {task_id}" if task_id is not None else "task"
        msg = f"{who} has no profile entry for instance type {key}"
        if detail:
            msg += f" ({detail})"
        super().__init__(msg)

    def __str__(self) -> str:  # KeyError would repr() the message
        return self.args[0]


class Profile(Mapping):
    """Instance-type-keyed execution times: ``(device_kind, size) -> s``.

    Accepts either a nested ``{kind: {size: t}}`` table or a flat
    ``{(kind, size): t}`` one.  Iteration/lookup follow the flat form, so
    a Profile is a ``Mapping[tuple[str, int], float]`` — indexing it with
    a bare size raises, which is deliberate: code that still assumes
    size-keyed times must go through :meth:`Task.bind` /
    :meth:`Task.times_for` and name the device kind it schedules for.
    """

    __slots__ = ("_by_kind",)

    def __init__(self, table: Mapping):
        by_kind: dict[str, dict[int, float]] = {}
        for key, value in table.items():
            if isinstance(key, tuple):
                kind, size = key
                by_kind.setdefault(kind, {})[int(size)] = float(value)
            else:
                if not isinstance(value, Mapping):
                    raise TypeError(
                        f"Profile entry {key!r} must map sizes to times; "
                        f"got {type(value).__name__}"
                    )
                by_kind.setdefault(key, {}).update(
                    {int(s): float(t) for s, t in value.items()}
                )
        self._by_kind = by_kind

    # -- Mapping over flat (kind, size) keys --------------------------------
    def __getitem__(self, key):
        if not isinstance(key, tuple):
            raise KeyError(
                f"Profile is keyed by (device_kind, size); bare key "
                f"{key!r} — bind the task to a device first "
                f"(Task.bind(spec) / Task.times_for(kind))"
            )
        kind, size = key
        return self._by_kind[kind][size]

    def __iter__(self):
        for kind, sizes in self._by_kind.items():
            for s in sizes:
                yield (kind, s)

    def __len__(self):
        return sum(len(v) for v in self._by_kind.values())

    @property
    def kinds(self) -> tuple[str, ...]:
        return tuple(self._by_kind)

    def for_kind(self, kind: str) -> dict[int, float]:
        """The size-keyed sub-profile of one device kind."""
        try:
            return self._by_kind[kind]
        except KeyError:
            raise KeyError(
                f"profile has no times for device kind {kind!r} "
                f"(kinds: {sorted(self._by_kind)})"
            ) from None

    def supports(self, kind: str) -> bool:
        return kind in self._by_kind

    def __repr__(self) -> str:
        return f"Profile({self._by_kind!r})"

    def __eq__(self, other) -> bool:
        if isinstance(other, Profile):
            return self._by_kind == other._by_kind
        return NotImplemented

    def __hash__(self):  # consistent with frozen Task usage
        return hash(
            tuple(sorted(
                (k, tuple(sorted(v.items())))
                for k, v in self._by_kind.items()
            ))
        )


def min_work_size(times: Mapping[int, float], sizes: Sequence[int]) -> int:
    """argmin_s s*times[s], ties toward fewer slices — THE molding rule
    (paper phase 1).  Plain function so the phase-1 hot loop can call it
    without method dispatch while sharing one implementation."""
    best_s = sizes[0]
    best_w = best_s * times[best_s]
    for s in sizes[1:]:
        w = s * times[s]
        if w < best_w or (w == best_w and s < best_s):
            best_w, best_s = w, s
    return best_s


@dataclasses.dataclass(frozen=True)
class Task:
    """An independent task with a per-instance-size time profile.

    ``times`` is either a size-keyed mapping (single device model) or a
    :class:`Profile` keyed by ``(device_kind, size)``.  The scheduler
    internals only ever see size-keyed mappings: heterogeneous callers
    lower a Profile task with :meth:`bind` at the device boundary.
    """

    id: int
    times: Mapping  # size -> seconds, or a Profile ((kind, size) -> s)
    name: str = ""
    # Optional checkpoint cadence (seconds of *work on the placed size*).
    # When set, a failed or speculation-preempted attempt earns credit for
    # every completed checkpoint period, and retries resume from the last
    # checkpoint boundary via :func:`remainder_task`.  ``None`` (default)
    # keeps the PR 6 restart-from-zero semantics bit-identically.
    checkpoint_period_s: float | None = None

    def time(self, size: int) -> float:
        return self.times[size]

    # -- heterogeneous profiles ---------------------------------------------
    def times_for(self, kind: str) -> Mapping[int, float]:
        """Size-keyed times on device kind ``kind``.  For a plain
        size-keyed task this is ``self.times`` itself (the back-compat
        shim: one profile serves any device, bit-identically).  Raises
        :class:`ProfileCoverageError` (naming this task and the missing
        kind) when a heterogeneous profile has no times for ``kind``."""
        if isinstance(self.times, Profile):
            if not self.times.supports(kind):
                raise ProfileCoverageError(
                    self.id, kind,
                    detail=f"profile kinds: {sorted(self.times.kinds)}",
                )
            return self.times.for_kind(kind)
        return self.times

    def supports(self, kind: str) -> bool:
        """Whether the task can run on devices of ``kind`` at all."""
        if isinstance(self.times, Profile):
            return self.times.supports(kind)
        return True

    def bind(self, spec: DeviceSpec) -> "Task":
        """The task lowered onto ``spec``'s device kind: ``times`` becomes
        the plain size-keyed sub-profile.  Identity for already-plain
        tasks — existing single-device pipelines schedule the exact same
        objects they always did."""
        if isinstance(self.times, Profile):
            return dataclasses.replace(
                self, times=self.times_for(spec.device_kind)
            )
        return self

    def min_work_size(self, sizes: Sequence[int]) -> int:
        """argmin_s s*t(s) — breaking ties toward fewer slices (paper picks
        the *minimum* number of slices that minimises the work)."""
        return min_work_size(self.times, sizes)

    def check_time_monotone(self) -> bool:
        """Paper monotony point 1: t(s) non-increasing in s (per device
        kind when the task carries a heterogeneous Profile)."""
        if isinstance(self.times, Profile):
            tables = [self.times.for_kind(k) for k in self.times.kinds]
        else:
            tables = [self.times]
        for table in tables:
            sizes = sorted(table)
            if not all(
                table[a] >= table[b] - EPS
                for a, b in zip(sizes, sizes[1:])
            ):
                return False
        return True


def bind_tasks(tasks: Sequence[Task], spec: DeviceSpec) -> Sequence[Task]:
    """Lower a batch onto one device's kind.  When every task already has
    plain size-keyed times the input sequence is returned unchanged —
    the differential back-compat guarantee for existing callers."""
    if all(not isinstance(t.times, Profile) for t in tasks):
        return tasks
    return [t.bind(spec) for t in tasks]


def _scale_times(times: Mapping, factor: float) -> Mapping:
    """Every profile entry multiplied by ``factor``, preserving the
    representation (Profile stays a Profile, plain dict stays a dict)."""
    if isinstance(times, Profile):
        return Profile({
            (kind, s): t * factor
            for kind in times.kinds
            for s, t in times.for_kind(kind).items()
        })
    return {s: t * factor for s, t in times.items()}


def remainder_task(task: Task, remaining: float) -> Task:
    """``task`` shrunk to its un-finished fraction — the checkpoint-credit
    retry transform.  ``remaining`` is the fraction of the *current*
    profile still to run (``0 < remaining <= 1``); every profile entry is
    scaled by it, which is exact for checkpoint credit expressed as a
    fraction of the planned duration on the failed placement (the fraction
    is size- and kind-independent by the proportional-progress model, the
    same modelling move as :func:`demote_shrink <repro.core.faults.demote_shrink>`
    for size demotion).  Identity at ``remaining == 1``."""
    if not 0.0 < remaining <= 1.0:
        raise ValueError(
            f"remaining fraction must be in (0, 1]; got {remaining!r}"
        )
    if remaining == 1.0:
        return task
    return dataclasses.replace(task, times=_scale_times(task.times, remaining))


def transfer_profile(
    task: Task,
    kind_sizes: Mapping[str, Sequence[int]],
    speed: Mapping[str, float] | None = None,
) -> Task:
    """``task`` with missing ``(device_kind, size)`` profile entries derived
    from its nearest measured ones — the profile-transfer fallback behind
    ``SchedulerConfig(profile_transfer=...)``.

    ``kind_sizes`` names the instance types the fleet can offer
    (``{device_kind: sizes}``).  Derivation, per target kind:

    * a kind with *some* measured sizes fills the missing ones from the
      nearest measured size ``s0``: for ``s > s0`` keep ``t(s0)``
      (conservative — monotone profiles never get slower with more
      slices), for ``s < s0`` use ``t(s0) * s0 / s`` (the work-conserving
      upper estimate under linear speedup);
    * a wholly-unmeasured kind first copies the donor kind with the
      widest measured coverage (ties broken lexicographically for
      determinism), scaled by the per-kind speed factor
      ``speed[donor] / speed[target]`` (missing entries count as 1.0),
      then fills sizes as above.

    Measured entries are never altered, so transfer is the identity for a
    task that already covers the fleet, and the calibration layer refines
    transferred estimates exactly like measured ones.  Raises
    :class:`ProfileCoverageError` only when nothing is derivable (the
    task has no measured entries at all)."""
    times = task.times
    if isinstance(times, Profile):
        measured = {k: dict(times.for_kind(k)) for k in times.kinds}
    else:
        # a plain size-keyed task supports every kind by definition; the
        # only derivable gap is a missing size within that shared table.
        measured = {None: dict(times)}
    measured = {k: v for k, v in measured.items() if v}
    if not measured:
        any_kind = next(iter(kind_sizes), "?")
        raise ProfileCoverageError(
            task.id, str(any_kind),
            detail="profile has no measured entries to transfer from",
        )

    def fill_sizes(table: dict[int, float], sizes: Sequence[int]) -> bool:
        grew = False
        base = sorted(table)
        for s in sizes:
            s = int(s)
            if s in table:
                continue
            s0 = min(base, key=lambda b: (abs(b - s), b))
            t0 = table[s0]
            table[s] = t0 if s > s0 else t0 * (s0 / s)
            grew = True
        return grew

    speed = dict(speed or {})

    def rate(kind) -> float:
        return float(speed.get(kind, 1.0))

    if None in measured:  # plain task: only within-table size fill
        table = measured[None]
        needed = sorted({int(s) for sizes in kind_sizes.values() for s in sizes})
        if not fill_sizes(table, needed):
            return task
        return dataclasses.replace(task, times=table)

    derived: dict[tuple[str, int], float] = {}
    changed = False
    for kind, sizes in sorted(kind_sizes.items()):
        table = dict(measured.get(kind, {}))
        if not table:
            donor = max(sorted(measured), key=lambda k: len(measured[k]))
            factor = rate(donor) / rate(kind)
            table = {s: t * factor for s, t in measured[donor].items()}
            changed = True
        changed |= fill_sizes(table, sizes)
        for s, t in table.items():
            derived[(kind, s)] = t
    if not changed:
        return task
    for kind, tab in measured.items():  # measured entries always win, verbatim
        for s, t in tab.items():
            derived[(kind, s)] = t
    return dataclasses.replace(task, times=Profile(derived))


@dataclasses.dataclass(frozen=True)
class ScheduledTask:
    task: Task
    node: InstanceNode
    begin: float
    size: int  # size the task was molded to == node.size
    # -- runtime corrections (closed-loop serving) --------------------------
    # ``end_override`` replaces the profiled end with runtime truth: the
    # actual completion reported by the executor, a straggler projection,
    # or the failure instant.  ``failed`` marks the item as an occupancy
    # record of a failed attempt: the slice was busy [begin, end) but the
    # task did NOT complete here (it may appear again as a retry).
    end_override: float | None = None
    failed: bool = False

    @property
    def planned_duration(self) -> float:
        """The profiled duration, ignoring any runtime correction."""
        return self.task.time(self.size)

    @property
    def duration(self) -> float:
        if self.end_override is not None:
            return self.end_override - self.begin
        return self.task.time(self.size)

    @property
    def end(self) -> float:
        if self.end_override is not None:
            return self.end_override
        return self.begin + self.duration

    @property
    def corrected(self) -> bool:
        """Whether runtime feedback replaced the profiled end."""
        return self.end_override is not None


@dataclasses.dataclass(frozen=True)
class ReconfigEvent:
    """One sequentialised instance creation/destruction window."""

    kind: str  # "create" | "destroy"
    node: InstanceNode
    begin: float
    end: float


@dataclasses.dataclass
class Schedule:
    """A complete schedule of one batch on one DeviceSpec."""

    spec: DeviceSpec
    items: list[ScheduledTask]
    reconfigs: list[ReconfigEvent]

    @property
    def makespan(self) -> float:
        return max((it.end for it in self.items), default=0.0)

    @property
    def total_span(self) -> float:
        """Makespan including any trailing reconfiguration."""
        last_rc = max((rc.end for rc in self.reconfigs), default=0.0)
        return max(self.makespan, last_rc)

    def slice_end_times(self) -> dict[tuple[int, int], float]:
        """Last busy time per (tree, slice) — *compute* occupancy only."""
        ends: dict[tuple[int, int], float] = {
            (r.tree, s): 0.0
            for r in self.spec.roots
            for s in r.blocked
        }
        for it in self.items:
            for s in it.node.slices:
                key = (it.node.tree, s)
                ends[key] = max(ends[key], it.end)
        return ends

    def work_area(self) -> float:
        return sum(it.size * it.duration for it in self.items)

    def by_node(self) -> dict[tuple, list[ScheduledTask]]:
        out: dict[tuple, list[ScheduledTask]] = {}
        for it in self.items:
            out.setdefault(it.node.key, []).append(it)
        for lst in out.values():
            lst.sort(key=lambda it: it.begin)
        return out


def area_lower_bound(tasks: Iterable[Task], spec: DeviceSpec) -> float:
    """Paper §6.4 ``baseline``: minimum total work spread over all slices.

    baseline = sum_i min_s (s * t_i(s)) / #slices_G  <=  omega*
    """
    tasks = bind_tasks(list(tasks), spec)
    total = sum(
        min(s * t.times[s] for s in spec.sizes if s in t.times)
        for t in tasks
    )
    return total / spec.n_slices


def lower_bound(tasks: Sequence[Task], spec: DeviceSpec) -> float:
    """Tighter-than-paper bound: also no task can beat its best time."""
    if not tasks:
        return 0.0
    tasks = bind_tasks(tasks, spec)
    tallest = max(min(t.times[s] for s in spec.sizes) for t in tasks)
    return max(area_lower_bound(tasks, spec), tallest)


class InfeasibleScheduleError(AssertionError):
    pass


def validate_schedule(
    schedule: Schedule,
    tasks: Sequence[Task] | None = None,
    check_reconfig: bool = True,
) -> None:
    """Raise :class:`InfeasibleScheduleError` on any constraint violation."""
    spec = schedule.spec
    node_keys = spec.node_index

    # every instance is a tree node and every task molded to its size
    for it in schedule.items:
        if it.node.key not in node_keys:
            raise InfeasibleScheduleError(f"{it.node} is not a tree node")
        if it.size != it.node.size:
            raise InfeasibleScheduleError(
                f"task {it.task.id} molded to {it.size} but placed on "
                f"{it.node}"
            )
        if it.begin < -EPS:
            raise InfeasibleScheduleError(f"task {it.task.id} begins < 0")

    # constraint 1 (+2 via P2): footprint-overlapping instances never co-run
    per_cell: dict[tuple[int, int], list[ScheduledTask]] = {}
    for it in schedule.items:
        for cell in it.node.blocked_cells:
            per_cell.setdefault(cell, []).append(it)
    for cell, lst in per_cell.items():
        lst.sort(key=lambda it: it.begin)
        for a, b in zip(lst, lst[1:]):
            if a.end > b.begin + EPS:
                raise InfeasibleScheduleError(
                    f"tasks {a.task.id} and {b.task.id} overlap on "
                    f"slice {cell}: [{a.begin:.3f},{a.end:.3f}) vs "
                    f"[{b.begin:.3f},{b.end:.3f})"
                )

    # all tasks scheduled exactly once (failed attempts are occupancy
    # records, not completions — a retried task may leave several)
    if tasks is not None:
        want = sorted(t.id for t in tasks)
        got = sorted(it.task.id for it in schedule.items if not it.failed)
        if want != got:
            raise InfeasibleScheduleError(
                f"scheduled task ids {got} != batch ids {want}"
            )

    if not check_reconfig:
        return

    # constraint 3: reconfiguration windows are sequential per driver —
    # one sequence per tree (paper §2.1: each GPU has its own driver),
    # or one global sequence when the spec pins reconfig_scope="global".
    # Identical on single-tree specs.
    rcs = sorted(schedule.reconfigs, key=lambda rc: (rc.begin, rc.end))
    per_scope: dict[object, list[ReconfigEvent]] = {}
    per_tree = getattr(spec, "reconfig_scope", "tree") != "global"
    for rc in rcs:
        per_scope.setdefault(rc.node.tree if per_tree else None, []).append(rc)
    for seq in per_scope.values():
        for a, b in zip(seq, seq[1:]):
            if a.end > b.begin + EPS:
                raise InfeasibleScheduleError(
                    f"reconfig windows overlap in one driver sequence: "
                    f"{a} vs {b}"
                )
    for rc in rcs:
        dur = (
            spec.t_create[rc.node.size]
            if rc.kind == "create"
            else spec.t_destroy[rc.node.size]
        )
        if abs((rc.end - rc.begin) - dur) > 1e-6:
            raise InfeasibleScheduleError(f"reconfig window wrong length: {rc}")

    # ... and each used instance is created before its first task; instances
    # with overlapping footprints have disjoint *existence windows*
    # [creation begin, destruction end | last task end].
    by_node = schedule.by_node()
    creates: dict[tuple, ReconfigEvent] = {}
    destroys: dict[tuple, ReconfigEvent] = {}
    for rc in rcs:
        # multi-batch concatenation may create/destroy the same node several
        # times; keep windows as lists in that case.
        bucket = creates if rc.kind == "create" else destroys
        bucket.setdefault(rc.node.key, []).append(rc)  # type: ignore[arg-type]

    windows: list[tuple[InstanceNode, float, float]] = []
    node_index = spec.node_index
    for key, lst in by_node.items():
        node = node_index[key]
        cs = creates.get(key, [])
        if not cs:
            raise InfeasibleScheduleError(f"instance {key} never created")
        cs = sorted(cs, key=lambda rc: rc.begin)
        ds = sorted(destroys.get(key, []), key=lambda rc: rc.begin)
        # pair tasks to the creation window preceding them
        for i, c in enumerate(cs):
            upper = cs[i + 1].begin if i + 1 < len(cs) else float("inf")
            span_tasks = [it for it in lst if c.end - EPS <= it.begin < upper]
            d_end = next((d.end for d in ds if d.begin + EPS >= c.end), None)
            last = max((it.end for it in span_tasks), default=c.end)
            windows.append((node, c.begin, d_end if d_end is not None else last))
        uncovered = [
            it for it in lst if not any(
                c.end - EPS <= it.begin for c in cs
            )
        ]
        if uncovered:
            raise InfeasibleScheduleError(
                f"task {uncovered[0].task.id} on {key} begins before any "
                f"creation of its instance completes"
            )
    for i, (na, ba, ea) in enumerate(windows):
        ca = na.blocked_cells
        for nb, bb, eb in windows[i + 1:]:
            if na.key == nb.key:
                continue
            if not (ca & nb.blocked_cells):
                continue
            if ba < eb - EPS and bb < ea - EPS:
                raise InfeasibleScheduleError(
                    f"existence windows of {na} [{ba:.3f},{ea:.3f}) and "
                    f"{nb} [{bb:.3f},{eb:.3f}) overlap"
                )
