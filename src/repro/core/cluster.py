"""Heterogeneous cluster scheduling: instance-typed device pools and the
``far-cluster`` policy (beyond-paper; cf. MIG-Serving, arXiv:2109.11067,
and the fragmentation-aware cluster scheduler of arXiv:2512.16099).

The paper's multi-GPU story (§3.2) stops at forests of *identical*
devices — one ``DeviceSpec``, one reconfiguration-cost table, one profile
per task.  A :class:`ClusterSpec` is an ordered pool of heterogeneous
``DeviceSpec``s (mixed A30/A100/H100, TPU pods, degraded devices), each
keeping its own repartitioning forest, reconfiguration tables and
per-driver reconfiguration sequences; tasks carry instance-type-keyed
:class:`~repro.core.problem.Profile`s and are lowered onto one device's
kind at the scheduling boundary (``Task.bind``).

``far-cluster`` plans a batch in three stages:

1. **phase 0 — moldable device partitioning** (:func:`partition_batch`):
   LPT / dual-approximation over per-device area lower bounds.  Tasks
   descend by best-case work density; each goes to the device whose
   projected bound ``load + max(area/#slices, tallest)`` grows least.
2. **per-device FAR**: phases 1–3 run unchanged on each device's
   sub-batch through the registered ``"far"`` policy — the cluster layer
   composes existing policy objects rather than reimplementing them.
3. **cross-device local search** (:func:`cluster_refine`): the phase-3
   move/swap heuristics (``refine.best_move_from`` / ``best_swap_from``)
   extended to inter-device candidates — durations are evaluated under
   the *destination* device's profile kind, every candidate edit is
   scored exactly on the per-device timing engines (speculative
   extract/place + undo), and only strict cluster-makespan improvements
   are kept.

The final plan is compared against scheduling the whole batch on each
single device (skipped when the partitioned makespan already beats that
device's admissible lower bound), so **the cluster never does worse than
the best single device** — by construction, which the hypothesis suite
pins (``tests/test_cluster.py``).

Serving: :class:`ClusterMultiBatchScheduler` gives
:class:`~repro.core.service.SchedulingService` the same driver surface a
single-device ``MultiBatchScheduler`` has (``add_batch`` / ``clone`` /
``withdraw_uncommitted`` / ``makespan`` / ``combined_schedule``), backed
by one per-device scheduler each carrying its own §4 seam
:class:`~repro.core.multibatch.Tail` — so deadlines, admission control
and tail re-planning work on heterogeneous pools for free
(``SchedulingService(pool=ClusterSpec(...))``).
"""

from __future__ import annotations

import dataclasses
import math
import time
from functools import cached_property
from typing import Sequence

from repro.core.device_spec import DeviceSpec, retree
from repro.core.multibatch import MultiBatchScheduler
from repro.core.policy import (
    BasePolicy,
    PlanResult,
    SchedulerConfig,
    get_policy,
    register_policy,
)
from repro.core.problem import (
    EPS,
    InfeasibleScheduleError,
    ProfileCoverageError,
    Schedule,
    ScheduledTask,
    Task,
    lower_bound,
    validate_schedule,
)
from repro.core.refine import best_move_from, best_swap_from
from repro.core.repartition import Assignment, NodeKey
from repro.core.timing import TimingEngine


# ---------------------------------------------------------------------------
# ClusterSpec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """An ordered pool of heterogeneous devices.

    Built with :func:`cluster`, which re-indexes each device's forest so
    tree ids are *globally unique across the pool* — ``(tree, slice)``
    cells, and therefore merged cluster-wide schedule views, never
    collide between devices.
    """

    name: str
    devices: tuple[DeviceSpec, ...]

    @cached_property
    def n_slices(self) -> int:
        return sum(d.n_slices for d in self.devices)

    @cached_property
    def device_kinds(self) -> tuple[str, ...]:
        return tuple(d.device_kind for d in self.devices)

    @cached_property
    def nodes(self) -> tuple:
        """All instance nodes of the pool (device order, BFS per device)."""
        return tuple(n for d in self.devices for n in d.nodes)

    @cached_property
    def tree_device(self) -> dict[int, int]:
        """tree id -> index of the owning device."""
        out: dict[int, int] = {}
        for i, d in enumerate(self.devices):
            for r in d.roots:
                out[r.tree] = i
        return out

    def device_of_tree(self, tree: int) -> DeviceSpec:
        return self.devices[self.tree_device[tree]]

    def supports(self, task: Task) -> bool:
        """Whether at least one device of the pool can host the task
        under the same predicate :func:`partition_batch` uses (the
        profile covers EVERY size of that device — FAR molds over the
        whole C_G), so a True here guarantees partitioning will not
        reject the task mid-flush."""
        return any(
            task.supports(d.device_kind)
            and all(s in task.times_for(d.device_kind) for s in d.sizes)
            for d in self.devices
        )

    def split_schedule(self, schedule) -> list[Schedule]:
        """Split a merged cluster-wide schedule view back into one
        absolute-timed :class:`Schedule` per device (by tree id), e.g.
        to validate a serving facade's combined schedule per device."""
        items: list[list] = [[] for _ in self.devices]
        rcs: list[list] = [[] for _ in self.devices]
        for it in schedule.items:
            items[self.tree_device[it.node.tree]].append(it)
        for rc in schedule.reconfigs:
            rcs[self.tree_device[rc.node.tree]].append(rc)
        return [
            Schedule(spec=d, items=its, reconfigs=rc)
            for d, its, rc in zip(self.devices, items, rcs)
        ]

    # -- fault tolerance ----------------------------------------------------
    def quarantine(self, device: int) -> "ClusterSpec":
        """The pool without device ``device`` — the *capacity view* of a
        device loss, e.g. for recomputing admission floors against the
        degraded pool.  The serving-side lifecycle (withdrawing committed
        placements at the loss time, re-partitioning, re-admission on
        recovery) lives on :meth:`SchedulingService.quarantine` /
        :meth:`ClusterMultiBatchScheduler.quarantine_device`, which keep
        the full spec and mask the device instead — tree ids stay stable
        across the outage."""
        if not 0 <= device < len(self.devices):
            raise ValueError(
                f"cluster {self.name!r} has no device {device} "
                f"(devices 0..{len(self.devices) - 1})"
            )
        keep = tuple(
            d for i, d in enumerate(self.devices) if i != device
        )
        if not keep:
            raise ValueError(
                f"cannot quarantine device {device}: it is the last "
                f"device of cluster {self.name!r}"
            )
        return ClusterSpec(name=f"{self.name}-q{device}", devices=keep)

    def degrade(self, dead_slices: Sequence[tuple[int, int]]) -> "ClusterSpec":
        """Cluster with dead ``(tree, slice)`` cells pruned per owning
        device (``DeviceSpec.degrade``); devices left with no healthy
        instances drop out of the pool."""
        dead = list(dead_slices)
        new_devices = []
        for i, d in enumerate(self.devices):
            mine = [c for c in dead if self.tree_device.get(c[0]) == i]
            nd = d.degrade(mine) if mine else d
            if nd.roots:
                new_devices.append(nd)
        return ClusterSpec(
            name=f"{self.name}-degraded", devices=tuple(new_devices)
        )


def cluster(*specs: DeviceSpec, name: str | None = None) -> ClusterSpec:
    """Build a :class:`ClusterSpec` from device specs, re-treeing each so
    tree ids are globally unique across the pool.  Each device keeps its
    own kind, sizes, reconfiguration tables and ``reconfig_scope``."""
    if not specs:
        raise ValueError("a cluster needs at least one device")
    devices = []
    tree = 0
    for spec in specs:
        roots = tuple(retree(r, tree + i) for i, r in enumerate(spec.roots))
        tree += len(spec.roots)
        devices.append(dataclasses.replace(
            spec, kind=spec.device_kind, roots=roots
        ))
    return ClusterSpec(
        name=name or "+".join(s.name for s in specs),
        devices=tuple(devices),
    )


# ---------------------------------------------------------------------------
# ClusterSchedule
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ClusterSchedule:
    """One absolute-timed schedule per device, devices independent in
    time (each starts at 0 — there is no cross-device resource, so the
    cluster makespan is the max over devices)."""

    cluster: ClusterSpec
    schedules: tuple[Schedule, ...]  # aligned with cluster.devices

    @property
    def spec(self) -> ClusterSpec:
        return self.cluster

    @property
    def items(self) -> list:
        return [it for s in self.schedules for it in s.items]

    @property
    def reconfigs(self) -> list:
        return [rc for s in self.schedules for rc in s.reconfigs]

    @property
    def makespan(self) -> float:
        return max((s.makespan for s in self.schedules), default=0.0)

    def device_makespans(self) -> list[float]:
        return [s.makespan for s in self.schedules]

    def utilization(self) -> list[float]:
        """Busy compute share per device against the cluster makespan."""
        omega = self.makespan
        if omega <= 0.0:
            return [0.0 for _ in self.schedules]
        return [
            s.work_area() / (d.n_slices * omega)
            for d, s in zip(self.cluster.devices, self.schedules)
        ]


def validate_cluster_schedule(
    cs: ClusterSchedule, tasks: Sequence[Task] | None = None
) -> None:
    """Validate each device's schedule under its own spec (full paper
    constraints incl. per-driver reconfiguration sequencing), and — when
    ``tasks`` is given — that the pool covers the batch exactly once."""
    for sched in cs.schedules:
        validate_schedule(sched, None, check_reconfig=True)
    if tasks is not None:
        want = sorted(t.id for t in tasks)
        got = sorted(it.task.id for it in cs.items)
        if want != got:
            raise InfeasibleScheduleError(
                f"cluster scheduled ids {got} != batch ids {want}"
            )


# ---------------------------------------------------------------------------
# Phase 0: moldable device partitioning
# ---------------------------------------------------------------------------


def partition_batch(
    tasks: Sequence[Task],
    cspec: ClusterSpec,
    loads: Sequence[float] | None = None,
    active: Sequence[bool] | None = None,
) -> list[list[Task]]:
    """Split one batch across the cluster's devices.

    LPT / dual-approximation over per-device area lower bounds: tasks
    descend by best-case work density; each is assigned to the supported
    device whose projected admissible bound
    ``load + max(area / #slices, tallest)`` grows least (ties to the
    earlier device).  ``loads`` are per-device start pressures in seconds
    (e.g. serving tail releases); default 0.  ``active`` masks devices
    out of the candidate set (a quarantined device still owns its slot in
    the returned list — it just receives no tasks).

    Returns one list per device, each in the original batch order, with
    the *original* task objects (binding to device kinds happens inside
    the per-device planners).  Raises :class:`ProfileCoverageError`
    (naming the task and the missing ``(device_kind, size)``) when a
    task's profile covers no device of the pool.
    """
    devices = cspec.devices
    start = list(loads) if loads is not None else [0.0] * len(devices)
    if len(start) != len(devices):
        raise ValueError("loads must have one entry per device")
    up = list(active) if active is not None else [True] * len(devices)
    if len(up) != len(devices):
        raise ValueError("active must have one entry per device")

    entries = []  # (orig_index, task, {device: (min_work, best_time)})
    for idx, t in enumerate(tasks):
        per_dev: dict[int, tuple[float, float]] = {}
        # the first (kind, size) hole found, for the typed error below
        missing: tuple[str, int | None] | None = None
        for i, d in enumerate(devices):
            if not up[i]:
                continue
            if not t.supports(d.device_kind):
                if missing is None:
                    missing = (d.device_kind, None)
                continue
            times = t.times_for(d.device_kind)
            # FAR molds over the device's whole C_G, so a device counts
            # only when the profile covers every one of its sizes
            hole = next((s for s in d.sizes if s not in times), None)
            if hole is not None:
                if missing is None:
                    missing = (d.device_kind, hole)
                continue
            w = min(s * times[s] for s in d.sizes)
            h = min(times[s] for s in d.sizes)
            per_dev[i] = (w, h)
        if not per_dev:
            kind, size = missing if missing is not None \
                else (devices[0].device_kind, None)
            quarantined = "" if all(up) else "; some devices quarantined"
            raise ProfileCoverageError(
                t.id, kind, size,
                detail=f"fits no device of cluster {cspec.name!r}, "
                       f"kinds: {list(cspec.device_kinds)}{quarantined}",
            )
        entries.append((idx, t, per_dev))

    # LPT: heaviest best-case work density first (ties by batch position)
    entries.sort(key=lambda e: (
        -min(w / devices[i].n_slices for i, (w, _) in e[2].items()),
        e[0],
    ))

    area = [0.0] * len(devices)
    tall = [0.0] * len(devices)
    parts: list[list[tuple[int, Task]]] = [[] for _ in devices]
    for idx, t, per_dev in entries:
        best_i, best_bound = None, math.inf
        for i in sorted(per_dev):
            w, h = per_dev[i]
            bound = start[i] + max(
                (area[i] + w) / devices[i].n_slices, max(tall[i], h)
            )
            if bound < best_bound - EPS:
                best_i, best_bound = i, bound
        assert best_i is not None
        w, h = per_dev[best_i]
        area[best_i] += w
        tall[best_i] = max(tall[best_i], h)
        parts[best_i].append((idx, t))

    for lst in parts:
        lst.sort()  # restore original batch order per device
    return [[t for _, t in lst] for lst in parts]


# ---------------------------------------------------------------------------
# Cross-device local search (phase 3 across the pool)
# ---------------------------------------------------------------------------


def _cluster_score(engines: Sequence[TimingEngine]) -> tuple[float, float]:
    """(cluster makespan, exact total of device makespans) — the total is
    the compaction tie-break, fsum'd so it is order-independent."""
    mks = [eng.makespan() for eng in engines]
    return (max(mks, default=0.0), math.fsum(mks))


def cluster_refine(
    cspec: ClusterSpec,
    engines: Sequence[TimingEngine],
    originals: dict[int, Task],
    max_edits: int = 24,
    eps: float = EPS,
) -> tuple[int, int]:
    """Inter-device move/swap local search over per-device timing engines.

    Each round takes the critical device (its makespan is the cluster
    makespan) and proposes, per destination node on every other device,
    the phase-3 candidates — the transferred duration closest to half the
    margin ``omega - end(target chain)``, with durations evaluated under
    the *destination* kind (``refine.best_move_from`` /
    ``best_swap_from`` on cross-device views).  Every proposal is scored
    exactly by applying extract/place on both engines, reading the
    cluster makespan, and undoing; the best strictly-improving edit is
    kept.  Mutates the engines in place; returns (moves, swaps).
    """
    devices = cspec.devices
    moves = swaps = 0
    if len(engines) < 2:
        return 0, 0

    def dst_dur(task: Task, dev: DeviceSpec, size: int) -> float | None:
        if not task.supports(dev.device_kind):
            return None
        return task.times_for(dev.device_kind).get(size)

    for _ in range(max_edits):
        score0 = _cluster_score(engines)
        omega = score0[0]
        if omega <= eps:
            break
        mks = [eng.makespan() for eng in engines]
        crit = mks.index(omega)
        src_eng = engines[crit]
        src_dev = devices[crit]
        src_ends = src_eng.node_end_times()
        crit_chains = [
            k for k, end in sorted(src_ends.items())
            if end >= omega - eps and src_eng.chains.get(k)
        ]
        if not crit_chains:
            break
        src_tasks = [
            (k, tid) for k in crit_chains for tid in src_eng.chains[k]
        ]

        # per (destination device, size): ascending (duration, tid) views
        # of the critical device's tasks under the destination kind
        view_cache: dict[tuple[int, int], list[tuple[float, int]]] = {}

        def src_view(a: int, size: int) -> list[tuple[float, int]]:
            hit = view_cache.get((a, size))
            if hit is None:
                hit = sorted(
                    (d, tid)
                    for _, tid in src_tasks
                    for d in (dst_dur(originals[tid], devices[a], size),)
                    if d is not None
                )
                view_cache[(a, size)] = hit
            return hit

        best_edit = None  # (score, kind, payload)
        for a, dst_eng in enumerate(engines):
            if a == crit:
                continue
            dst_dev = devices[a]
            dst_ends = dst_eng.node_end_times()
            proposals = []
            for node in dst_dev.nodes:
                margin = omega - dst_ends.get(node.key, 0.0)
                if margin <= eps:
                    continue
                view = src_view(a, node.size)
                if not view:
                    continue
                durs = [d for d, _ in view]
                ids = [tid for _, tid in view]
                tid = best_move_from(ids, durs, margin)
                if tid is not None:
                    proposals.append(("move", tid, node.key))
                # swap: a critical-device task against one of the target
                # chain's tasks (net growth of the target closest to
                # margin/2), provided the displaced task fits back onto
                # the critical chain it frees
                chain = dst_eng.chains.get(node.key)
                if chain:
                    dst_durs = dst_eng.chain_durations(node.key)
                    da = sorted(
                        (dst_durs[i], tj) for i, tj in enumerate(chain)
                    )
                    pair = best_swap_from(view, da, margin)
                    if pair is not None:
                        tk, tj = pair
                        ki = src_eng.task_node[tk]
                        if dst_dur(originals[tj], src_dev, ki[2]) is not None:
                            proposals.append(("swap", tk, tj, node.key))
            for prop in proposals:
                n_src, n_dst = _apply_edit(
                    prop, src_eng, src_dev, dst_eng, dst_dev, originals
                )
                score = _cluster_score(engines)
                for _ in range(n_dst):
                    dst_eng.undo()
                for _ in range(n_src):
                    src_eng.undo()
                improves = score[0] < score0[0] - eps or (
                    score[0] < score0[0] + eps and score[1] < score0[1] - eps
                )
                if improves and (best_edit is None or score < best_edit[0]):
                    best_edit = (score, a, prop)
        if best_edit is None:
            break
        _, a, prop = best_edit
        _apply_edit(prop, src_eng, src_dev, engines[a], devices[a], originals)
        if prop[0] == "move":
            moves += 1
        else:
            swaps += 1
    return moves, swaps


def _apply_edit(prop, src_eng, src_dev, dst_eng, dst_dev, originals
                ) -> tuple[int, int]:
    """Apply one proposed cross-device edit to the engines — the ONE
    sequence both speculative scoring and the commit use, so what gets
    committed is exactly what was scored.  Returns the per-engine edit
    counts (src, dst) for the caller's undo loop."""
    if prop[0] == "move":
        _, tid, dst_key = prop
        src_eng.apply_extract(tid, src_eng.task_node[tid])
        dst_eng.tasks[tid] = originals[tid].bind(dst_dev)
        dst_eng.apply_place(tid, dst_key)
        return 1, 1
    _, tk, tj, dst_key = prop
    ki = src_eng.task_node[tk]
    src_eng.apply_extract(tk, ki)
    dst_eng.apply_extract(tj, dst_key)
    dst_eng.tasks[tk] = originals[tk].bind(dst_dev)
    dst_eng.apply_place(tk, dst_key)
    src_eng.tasks[tj] = originals[tj].bind(src_dev)
    src_eng.apply_place(tj, ki)
    return 2, 2


# ---------------------------------------------------------------------------
# The far-cluster policy
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ClusterPlan:
    """Policy-specific payload of a ``far-cluster`` plan."""

    cluster: ClusterSpec
    partition: tuple[tuple[int, ...], ...]  # task ids per device
    device_makespans: tuple[float, ...]
    mode: str                   # "partitioned" | "single:<device index>"
    moves: int
    swaps: int
    assignments: tuple[Assignment | None, ...]
    single_makespans: dict[int, float]  # evaluated single-device fallbacks


@register_policy("far-cluster")
class FARClusterPolicy(BasePolicy):
    """FAR lifted to a heterogeneous pool.

    On a plain :class:`DeviceSpec` this is exactly the registered
    ``"far"`` policy (a one-device cluster), so existing single-device
    surfaces — seam concatenation, the invariant harness, serving — get
    the policy for free.  On a :class:`ClusterSpec` it runs phase-0
    partitioning, per-device FAR and the cross-device local search, then
    keeps whichever of {partitioned plan, whole batch on one device}
    wins — so the cluster plan never loses to the best single device.
    """

    def plan(
        self,
        tasks: Sequence[Task],
        spec,
        config: SchedulerConfig | None = None,
        tail: object | None = None,
    ) -> PlanResult:
        if not isinstance(spec, ClusterSpec):
            res = get_policy("far").plan(tasks, spec, config, tail)
            return dataclasses.replace(res, policy=self.name)
        if tail is not None:
            raise ValueError(
                "far-cluster carries per-device tails through "
                "ClusterMultiBatchScheduler; a single seam Tail does not "
                "apply to a heterogeneous pool"
            )
        return self._plan_cluster(tasks, spec, config or SchedulerConfig())

    def _plan_cluster(
        self, tasks: Sequence[Task], cspec: ClusterSpec,
        config: SchedulerConfig,
    ) -> PlanResult:
        t0 = time.perf_counter()
        devices = cspec.devices
        if not tasks:
            empty = ClusterSchedule(
                cspec,
                tuple(Schedule(spec=d, items=[], reconfigs=[])
                      for d in devices),
            )
            return PlanResult(
                policy=self.name, schedule=empty, makespan=0.0,
                elapsed_s=time.perf_counter() - t0,
            )
        originals = {t.id: t for t in tasks}
        far = get_policy("far")

        parts = partition_batch(tasks, cspec)
        t1 = time.perf_counter()
        engines: list[TimingEngine] = []
        assignments: list[Assignment] = []
        for dev, part in zip(devices, parts):
            if part:
                asgn = far.plan(part, dev, config).assignment
            else:
                asgn = Assignment(dev, {}, {})
            assignments.append(asgn)
            engines.append(TimingEngine(asgn))
        t2 = time.perf_counter()
        moves, swaps = cluster_refine(cspec, engines, originals, eps=config.eps)
        schedules = [eng.schedule() for eng in engines]
        mk_part = max(s.makespan for s in schedules)
        # the exposed assignments/partition must reflect the POST-refine
        # chains (the engines edit copies), with the tasks dict pruned to
        # what each device actually hosts — speculative cross-device
        # probes register foreign bindings that must not leak out
        assignments = []
        part_ids: list[tuple[int, ...]] = []
        for eng in engines:
            asgn = eng.export_assignment()
            hosted = {tid for lst in asgn.node_tasks.values() for tid in lst}
            asgn.tasks = {tid: asgn.tasks[tid] for tid in sorted(hosted)}
            assignments.append(asgn)
            part_ids.append(tuple(sorted(hosted)))
        t3 = time.perf_counter()

        # single-device fallbacks: evaluated only where the partitioned
        # plan does not already beat the device's admissible lower bound
        # (single_d >= lower_bound_d >= mk_part there, so skipping keeps
        # the never-worse-than-best-single guarantee intact)
        single_mks: dict[int, float] = {}
        best_single = None  # (makespan, index, PlanResult)
        for i, dev in enumerate(devices):
            if not all(t.supports(dev.device_kind) for t in tasks):
                continue
            try:
                lb = lower_bound(tasks, dev)
            except (KeyError, ValueError):
                continue
            if mk_part <= lb + config.eps:
                continue
            try:
                plan = far.plan(tasks, dev, config)
            except (KeyError, ValueError):
                continue
            single_mks[i] = plan.makespan
            if best_single is None or plan.makespan < best_single[0] - config.eps:
                best_single = (plan.makespan, i, plan)

        if best_single is not None and best_single[0] < mk_part - config.eps:
            mk, idx, plan = best_single
            schedules = [
                plan.schedule if i == idx
                else Schedule(spec=d, items=[], reconfigs=[])
                for i, d in enumerate(devices)
            ]
            out_assignments: list[Assignment | None] = [
                plan.assignment if i == idx else None
                for i in range(len(devices))
            ]
            partition = tuple(
                tuple(t.id for t in tasks) if i == idx else ()
                for i in range(len(devices))
            )
            mode, makespan, moves, swaps = f"single:{idx}", mk, 0, 0
        else:
            out_assignments = list(assignments)
            partition = tuple(part_ids)
            mode, makespan = "partitioned", mk_part

        cs = ClusterSchedule(cspec, tuple(schedules))
        return PlanResult(
            policy=self.name,
            schedule=cs,
            makespan=makespan,
            assignment=None,
            elapsed_s=time.perf_counter() - t0,
            phase_s={
                "partition": t1 - t0,
                "per_device_far": t2 - t1,
                "cluster_refine": t3 - t2,
            },
            extras={"cluster": ClusterPlan(
                cluster=cspec,
                partition=partition,
                device_makespans=tuple(s.makespan for s in schedules),
                mode=mode,
                moves=moves,
                swaps=swaps,
                assignments=tuple(out_assignments),
                single_makespans=single_mks,
            )},
        )


# ---------------------------------------------------------------------------
# Serving driver: per-device tails behind the MultiBatchScheduler surface
# ---------------------------------------------------------------------------


class ClusterMultiBatchScheduler:
    """The serving-side cluster driver.

    Presents the :class:`~repro.core.multibatch.MultiBatchScheduler`
    surface the :class:`~repro.core.service.SchedulingService` consumes —
    ``add_batch`` / ``adopt`` / ``clone`` / ``withdraw_uncommitted`` /
    ``makespan`` / ``segments`` / ``results`` / ``combined_schedule`` —
    while internally running one per-device ``MultiBatchScheduler``, each
    with its own §4 seam tail and per-driver reconfiguration sequences.
    Every flush is phase-0-partitioned across the pool using the current
    per-device tail pressures as start loads.
    """

    def __init__(
        self,
        cspec: ClusterSpec,
        policy: str = "far",
        config: SchedulerConfig | None = None,
    ):
        self.cluster = cspec
        self.config = config or SchedulerConfig()
        self.policy = policy
        self.mbs = [
            MultiBatchScheduler(d, policy=policy, config=self.config)
            for d in cspec.devices
        ]
        self.results: list[PlanResult] = []
        self.originals: dict[int, Task] = {}
        # quarantine mask: inactive devices receive no placements until
        # recovery (their committed history stays — tree ids are stable)
        self.active: list[bool] = [True] * len(cspec.devices)

    # -- MultiBatchScheduler surface ----------------------------------------
    @property
    def spec(self) -> ClusterSpec:
        return self.cluster

    @property
    def segments(self) -> list[Schedule]:
        return [s for mb in self.mbs for s in mb.segments]

    @property
    def makespan(self) -> float:
        return max((mb.makespan for mb in self.mbs), default=0.0)

    @property
    def tail(self) -> tuple:
        """Per-device seam tails (device order)."""
        return tuple(mb.tail for mb in self.mbs)

    def device_pressures(self) -> list[float]:
        """Per-device start load for the partitioner: the latest slice
        release of each device's committed tail."""
        from repro.core.repartition import is_reconfig_key

        out = []
        for mb in self.mbs:
            slice_rel = [
                float(v) for k, v in mb.tail.release.items()
                if not is_reconfig_key(k)
            ]
            out.append(max(slice_rel) if slice_rel else 0.0)
        return out

    def add_batch(self, tasks: Sequence[Task], not_before: float = 0.0,
                  deadlines: dict[int, float] | None = None) -> Schedule:
        """Partition one flush across the pool and splice each part after
        its device's tail; returns the merged absolute-timed segment."""
        return self.commit_parts(
            self.plan_parts(tasks), not_before, deadlines=deadlines
        )

    def plan_parts(self, tasks: Sequence[Task]) -> list[tuple]:
        """Stage 1 of a cluster flush: phase-0-partition the batch across
        the active pool and plan every device's part cold.  The per-device
        plans only depend on the partition (itself a function of the
        committed tail pressures at call time), not on each other's
        commits, so all of them run before any tail moves — the pipelined
        form of the old plan-one-commit-one loop, bit-identical because
        per-device plans never read other devices' tails."""
        parts = partition_batch(
            tasks, self.cluster, self.device_pressures(), active=self.active
        )
        return [
            (mb, part, mb.plan_batch(part) if part else None)
            for mb, part in zip(self.mbs, parts)
        ]

    def commit_parts(self, planned: list[tuple], not_before: float = 0.0,
                     deadlines: dict[int, float] | None = None) -> Schedule:
        """Stage 2 of a cluster flush: splice every planned part after its
        device's tail and merge the absolute-timed segments."""
        items: list = []
        reconfigs: list = []
        for mb, part, plan in planned:
            if not part:
                continue
            for t in part:
                self.originals[t.id] = t
            out = mb.commit_plan(plan, not_before=not_before,
                                 deadlines=deadlines)
            items.extend(out.schedule.items)
            reconfigs.extend(out.schedule.reconfigs)
        merged = Schedule(spec=self.cluster, items=items, reconfigs=reconfigs)
        self.results.append(PlanResult(
            policy=f"{self.policy}-cluster",
            schedule=merged,
            makespan=merged.makespan,
            extras={"partition": tuple(
                tuple(t.id for t in part) for _, part, _ in planned
            )},
        ))
        return merged

    def online_place(
        self,
        batch: Sequence[tuple[Task, float, object]],
        decided_at: float,
    ) -> Schedule:
        """Greedy per-arrival placement across the pool (the service's
        trickle/urgent fallback): each task goes to the device whose own
        online greedy yields the best score, evaluated speculatively with
        :meth:`OnlineScheduler.best_placement` against the device's
        floored tail; chosen placements commit into that device's
        timeline via ``adopt_segment``."""
        from repro.core.online import OnlineScheduler

        onlines: list[OnlineScheduler] = []
        for mb in self.mbs:
            fl = mb.tail.floored(decided_at)
            onlines.append(
                OnlineScheduler(mb.spec, release=fl.release, alive=fl.alive)
            )
        for task, arrival, _ in batch:
            self.originals[task.id] = task
            best = None  # ((rank, score..., device), index, bound task)
            for i, (dev, ol) in enumerate(zip(self.cluster.devices, onlines)):
                if not self.active[i]:
                    continue
                if not task.supports(dev.device_kind):
                    continue
                bt = task.bind(dev)
                cand = ol.best_placement(bt, arrival=arrival)
                if cand is None:
                    continue
                key = cand + (i,)
                if best is None or key < best[0]:
                    best = (key, i, bt)
            if best is None:
                raise ValueError(
                    f"task {task.id} fits no device of {self.cluster.name!r}"
                )
            key, i, bt = best
            # commit the previewed choice directly (key[3] is the node):
            # re-probing the winning device would double its node scan
            onlines[i].submit(bt, arrival=arrival, node_key=key[3])
        items: list = []
        reconfigs: list = []
        for mb, ol in zip(self.mbs, onlines):
            if not ol.placements:
                continue
            sched = ol.schedule()
            mb.adopt_segment(sched)
            items.extend(sched.items)
            reconfigs.extend(sched.reconfigs)
        merged = Schedule(spec=self.cluster, items=items, reconfigs=reconfigs)
        self.results.append(PlanResult(
            policy="online-cluster", schedule=merged,
            makespan=merged.makespan,
        ))
        return merged

    def clone(self) -> "ClusterMultiBatchScheduler":
        # bypass __init__: it would build per-device schedulers only for
        # them to be replaced — replan flushes clone twice per flush
        new = ClusterMultiBatchScheduler.__new__(ClusterMultiBatchScheduler)
        new.cluster = self.cluster
        new.config = self.config
        new.policy = self.policy
        new.mbs = [mb.clone() for mb in self.mbs]
        new.results = list(self.results)
        new.originals = dict(self.originals)
        new.active = list(self.active)
        return new

    def last_flush_items(self) -> list:
        """Absolute-timed placements of the most recent flush — the
        merged schedule the flush's synthetic PlanResult carries (a
        cluster flush spans several per-device segments)."""
        return list(self.results[-1].schedule.items) if self.results else []

    def withdraw_uncommitted(self, t: float, eps: float = 1e-9) -> list[Task]:
        """Pull every not-yet-started placement back across all devices;
        returns the *original* (profile-keyed) tasks so the re-plan can
        re-partition them onto different devices, ordered by their old
        begin times (ties by id) like the single-device driver."""
        begins: dict[int, float] = {}
        for mb in self.mbs:
            for seg in mb.segments:
                for it in seg.items:
                    if it.begin > t + eps:
                        begins[it.task.id] = it.begin
        withdrawn: list[Task] = []
        for mb in self.mbs:
            withdrawn.extend(mb.withdraw_uncommitted(t, eps=eps))
        out = [self.originals.get(w.id, w) for w in withdrawn]
        out.sort(key=lambda task: (begins.get(task.id, t), task.id))
        return out

    # -- fault tolerance ----------------------------------------------------
    def supports_active(self, task: Task) -> bool:
        """Whether some *non-quarantined* device can host the task (the
        ``ClusterSpec.supports`` predicate over the active mask)."""
        return any(
            up and task.supports(d.device_kind)
            and all(s in task.times_for(d.device_kind) for s in d.sizes)
            for up, d in zip(self.active, self.cluster.devices)
        )

    def quarantine_device(
        self, device: int, t: float
    ) -> tuple[list[Task], list[int]]:
        """Take ``device`` out of service at time ``t``.

        The device stops receiving placements (partitioning and online
        previews skip it) and every committed placement on it that has
        not started by ``t`` is withdrawn.  Returns ``(withdrawn,
        running)``: the withdrawn *original* tasks (old-begin order) and
        the ids of attempts that were RUNNING on the device at ``t`` —
        those died with it; the caller routes them through its failure
        path (the driver cannot: retries are a service-level policy).
        """
        if not 0 <= device < len(self.mbs):
            raise ValueError(
                f"cluster {self.cluster.name!r} has no device {device}"
            )
        if not self.active[device]:
            raise ValueError(f"device {device} is already quarantined")
        self.active[device] = False
        mb = self.mbs[device]
        running = sorted(
            it.task.id
            for seg in mb.segments for it in seg.items
            if not it.failed and it.begin <= t + EPS and it.end > t + EPS
        )
        withdrawn = mb.withdraw_uncommitted(t)
        return [self.originals.get(w.id, w) for w in withdrawn], running

    def recover_device(self, device: int, t: float) -> None:
        """Return a quarantined device to service at time ``t``: its
        seam tail is floored at ``t`` and its alive-instance set cleared
        (an outage resets the MIG partition — every instance must be
        re-created; safe because quarantine ended all work on the device
        no later than the loss time, so no existence window reaches
        ``t``).  The reset is persistent (``mb.reset_at``): later
        withdrawals or corrections that rebuild the device tail keep
        honouring the floor — work decided before recovery can never be
        re-planned into the outage window."""
        if not 0 <= device < len(self.mbs):
            raise ValueError(
                f"cluster {self.cluster.name!r} has no device {device}"
            )
        if self.active[device]:
            raise ValueError(f"device {device} is not quarantined")
        self.active[device] = True
        mb = self.mbs[device]
        mb.reset_at = max(mb.reset_at, float(t))
        mb.rebuild_tail()

    # -- runtime corrections (closed-loop serving) --------------------------
    def _mb_of_task(self, task_id: int) -> MultiBatchScheduler | None:
        for mb in self.mbs:
            if mb.find_item(task_id) is not None:
                return mb
        return None

    def find_item(self, task_id: int) -> ScheduledTask | None:
        """The live committed placement of ``task_id`` on any device."""
        mb = self._mb_of_task(task_id)
        return mb.find_item(task_id) if mb is not None else None

    def replace_item(
        self,
        task_id: int,
        end_override: float | None,
        failed: bool = False,
    ) -> ScheduledTask:
        """Correct the live placement on its owning device's timeline."""
        mb = self._mb_of_task(task_id)
        if mb is None:
            raise KeyError(f"task {task_id} has no live committed placement")
        return mb.replace_item(task_id, end_override, failed=failed)

    def relabel_item(
        self,
        task_id: int,
        task: Task,
        end_override: float | None = None,
        failed: bool = False,
    ) -> ScheduledTask:
        """Re-key the live placement of ``task_id`` to carry ``task`` on
        its owning device's timeline (speculation resolution: the winning
        backup attempt's record takes over the logical task id)."""
        mb = self._mb_of_task(task_id)
        if mb is None:
            raise KeyError(f"task {task_id} has no live committed placement")
        new = mb.relabel_item(
            task_id, task, end_override=end_override, failed=failed
        )
        self.originals.setdefault(task.id, self.originals.get(task_id, task))
        return new

    def remove_items(self, task_ids: set[int]) -> list[Task]:
        """Drop live placements across all devices; returns the removed
        *original* tasks ordered by old begin (ties by id)."""
        begins: dict[int, float] = {}
        for mb in self.mbs:
            for seg in mb.segments:
                for it in seg.items:
                    if not it.failed and it.task.id in task_ids:
                        begins[it.task.id] = it.begin
        removed: list[Task] = []
        for mb in self.mbs:
            removed.extend(mb.remove_items(task_ids))
        out = [self.originals.get(w.id, w) for w in removed]
        out.sort(key=lambda task: (begins.get(task.id, 0.0), task.id))
        return out

    def combined_schedule(self) -> Schedule:
        """All devices' segments merged into one absolute-timed view
        (tree ids are globally unique, so items never collide); split it
        back per device with ``ClusterSpec.split_schedule`` to validate."""
        items = [it for mb in self.mbs for s in mb.segments for it in s.items]
        reconfigs = [
            rc for mb in self.mbs for s in mb.segments for rc in s.reconfigs
        ]
        return Schedule(spec=self.cluster, items=items, reconfigs=reconfigs)


__all__ = [
    "ClusterSpec",
    "ClusterSchedule",
    "ClusterPlan",
    "ClusterMultiBatchScheduler",
    "FARClusterPolicy",
    "cluster",
    "cluster_refine",
    "partition_batch",
    "validate_cluster_schedule",
]
