"""FAR phase-2 family evaluation behind a pluggable evaluator.

Phase 2 scores every Turek-family candidate with Algorithm 1 and keeps the
EPS-ordered winner (ties broken by family index).  This module owns that
loop behind a small registry so the scoring engine is swappable through
``SchedulerConfig(evaluator=...)`` while the *selection semantics* stay in
exactly one place (:func:`_winner_scan`):

* ``"sequential"`` — the reference path: one warm-started
  :class:`~repro.core.repartition.LPTGroups` simulation per candidate
  (or cold ``list_schedule_allocation`` + ``replay`` when
  ``config.use_engine`` is off).  The admissible prune area is maintained
  incrementally from the one-task family deltas (O(1) per candidate)
  instead of re-summing all tasks each iteration.
* ``"vectorized"`` — an array program that scores *chunks of candidates at
  once*.  Algorithm 1's heap is replaced by a ``(chunk, nodes)`` tensor
  lockstep: the device tree is tiny and fixed, so the event queue holds at
  most one entry per tree node and the pop becomes a masked argmin over
  the node axis, identical across all candidates of the chunk.  The
  per-size LPT groups come from one set of
  :func:`~repro.core.repartition.size_sorted_orders` total orders —
  consecutive candidates differ in exactly one task
  (``allocation_family_deltas``), so a chunk is a boolean membership
  tensor over those fixed orders, built by two column flips per candidate.
  The simulation itself is a jax-jitted ``lax.scan`` in float64 (the
  repo's accelerator toolchain; compiled once per shape bucket and cached)
  and the resulting per-node duration chains are scored with the batched
  :func:`~repro.core.timing.chains_makespan_batch`.  Without jax the
  evaluator transparently falls back to sequential scoring — same
  results, no speedup.
* ``"auto"`` — picks ``"vectorized"`` when jax is importable, the engine
  path is on and the batch/family are large enough to amortize the array
  program (``AUTO_MIN_TASKS`` pruned / ``AUTO_MIN_TASKS_UNPRUNED``
  full-family, with ``AUTO_MIN_FAMILY``), else ``"sequential"``.

**Equivalence contract:** both evaluators return bit-identical winners —
index, allocation, assignment and makespan — for any workload and spec.
The vectorized path earns this by construction rather than by tolerance:
every floating-point accumulation (chain folds, the serialized
reconfiguration tail, the prune-area recurrence) performs the same IEEE
operations in the same order as the sequential code, the lockstep pop
reproduces the heap's ``(time, seq)`` tie-breaking exactly, and the final
winner/prune scan is the shared :func:`_winner_scan` driver.  Enforced by
``tests/test_family_eval.py`` and the hypothesis property suite.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from repro.core.allocations import Allocation
from repro.core.device_spec import DeviceSpec
from repro.core.problem import Task
from repro.core.repartition import (
    Assignment,
    LPTGroups,
    list_schedule_allocation,
    replay,
    size_sorted_orders,
)
from repro.core.timing import (
    IdentityCache,
    chains_makespan,
    chains_makespan_batch,
)

# jax is probed, not imported: `import repro.core` must stay free of
# jax's multi-second import / backend init for users on the sequential
# path.  The modules load lazily on first vectorized evaluation.
import importlib.util

HAVE_JAX = importlib.util.find_spec("jax") is not None

_WARNED_NO_JAX = False


def _jax_modules():
    """(jax, jax.numpy, enable_x64), imported on first use."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    return jax, jnp, enable_x64

#: "auto" dispatch thresholds, calibrated on the container benchmarks
#: (benchmarks/t_cost.py, paired medians).  The array program's per-step
#: cost is fixed per chunk while the sequential cost is per *scored*
#: candidate, so vectorized wins where many candidates are actually
#: scored: unpruned (full-family) runs from moderate sizes on (1.2-1.6x
#: at n=500-2000 on the 2-vCPU CI box), and pruned runs only once the
#: batch is so large that the ~2-dozen-candidate prune window still
#: carries enough per-candidate Python cost to beat the scan's fixed
#: dispatch floor (crossover measured at n~2000; margin added).
AUTO_MIN_TASKS = 3072          # pruned runs: scored window stays ~20-30
AUTO_MIN_TASKS_UNPRUNED = 512  # full-family runs: every candidate scored
AUTO_MIN_FAMILY = 48

#: chunk sizes for the vectorized scan.  Every chunk pays a full scan
#: pass, so a pruned run starts with one prune-window-sized chunk (the
#: admissible prune usually stops within a few dozen candidates) and an
#: unpruned run scores the whole family in one pass (memory-capped).
MAX_CHUNK = 32
MAX_FAMILY_CHUNK = 512


@dataclasses.dataclass
class FamilyWinner:
    """Phase-2 outcome: the EPS-ordered family winner."""

    makespan: float
    index: int
    assignment: Assignment
    allocation: Allocation
    evaluated: int


# -- registry ---------------------------------------------------------------

EVALUATORS: dict[str, "FamilyEvaluator"] = {}


def register_evaluator(name: str):
    """Class decorator adding a family evaluator under ``name``."""

    def deco(cls):
        cls.name = name
        EVALUATORS[name] = cls()
        return cls

    return deco


def get_evaluator(name: str) -> "FamilyEvaluator":
    try:
        return EVALUATORS[name]
    except KeyError:
        raise KeyError(
            f"unknown family evaluator {name!r}; "
            f"available: {', '.join(sorted(EVALUATORS))}"
        ) from None


def resolve_evaluator(config, n_tasks: int, family_size: int) -> str:
    """Map ``config.evaluator`` to a concrete evaluator name.

    The replay reference path (``use_engine=False``) always scores
    sequentially — it exists to cross-check the engine pipeline, so it
    must stay on the unoptimised code path.
    """
    name = config.evaluator
    if name == "auto":
        floor = AUTO_MIN_TASKS if config.prune else AUTO_MIN_TASKS_UNPRUNED
        if (
            HAVE_JAX
            and config.use_engine
            and n_tasks >= floor
            and family_size >= AUTO_MIN_FAMILY
        ):
            return "vectorized"
        return "sequential"
    if name == "vectorized" and not config.use_engine:
        return "sequential"
    return name


# -- shared selection semantics ---------------------------------------------


def family_areas(
    tasks: Sequence[Task], first: Allocation, deltas: list[tuple[int, int]]
) -> np.ndarray:
    """Prune area of every family candidate, by one-task delta recurrence.

    ``area_0`` is the plain left-fold sum over the first allocation;
    ``area_{i+1} = area_i + (s_new * t(s_new) - s_old * t(s_old))`` via
    ``np.add.accumulate`` — the same IEEE additions whether the recurrence
    runs here or one step at a time, so both evaluators see identical
    values.  O(n + family) total instead of O(n) per candidate.
    """
    area0 = sum(s * t.times[s] for t, s in zip(tasks, first))
    if not deltas:
        return np.array([area0])
    alloc = list(first)
    terms = np.empty(len(deltas))
    for k, (j, s_new) in enumerate(deltas):
        s_old = alloc[j]
        t = tasks[j]
        terms[k] = s_new * t.times[s_new] - s_old * t.times[s_old]
        alloc[j] = s_new
    return np.add.accumulate(np.concatenate(([area0], terms)))


def _winner_scan(
    score: Callable[[int], tuple[float, object]],
    areas: np.ndarray | None,
    eps: float,
    n_slices: int,
    family_size: int,
) -> tuple[tuple[float, int, object], int]:
    """The phase-2 selection loop, shared by every evaluator.

    ``score(i)`` is called for consecutive ``i`` starting at 0 and returns
    ``(makespan, payload)``.  Candidate ``i`` is pruned-past (loop break)
    when an incumbent exists and ``areas[i] / n_slices`` already reaches
    it; the incumbent is replaced only on a strict EPS improvement, so
    ties keep the earliest family index.  Returns the winning
    ``(makespan, index, payload)`` and the number of scored candidates.
    """
    best: tuple[float, int, object] | None = None
    evaluated = 0
    i = 0
    while True:
        if areas is not None and best is not None:
            if areas[i] / n_slices >= best[0] - eps:
                break  # all later allocations have >= area -> dominated
        makespan, payload = score(i)
        evaluated += 1
        if best is None or makespan < best[0] - eps:
            best = (makespan, i, payload)
        if i == family_size - 1:
            break
        i += 1
    assert best is not None
    return best, evaluated


class FamilyEvaluator:
    """Protocol: ``evaluate(tasks, spec, first, deltas, config)``."""

    name = "?"

    def evaluate(
        self,
        tasks: Sequence[Task],
        spec: DeviceSpec,
        first: Allocation,
        deltas: list[tuple[int, int]],
        config,
    ) -> FamilyWinner:
        raise NotImplementedError


# -- sequential reference ---------------------------------------------------


@register_evaluator("sequential")
class SequentialEvaluator(FamilyEvaluator):
    """One warm-started Algorithm-1 simulation per candidate (paper §3.2).

    ``config.use_engine`` selects the warm ``LPTGroups`` + lean
    ``chains_makespan`` pipeline (default) or the cold
    replay-per-candidate reference path; both produce identical winners.
    """

    def evaluate(self, tasks, spec, first, deltas, config):
        groups = LPTGroups(tasks, first, spec) if config.use_engine else None
        alloc = list(first)
        state = {"idx": 0}

        def score(i):
            assert i == state["idx"]
            if groups is not None:
                assignment, node_durs = groups.schedule_with_durs()
                makespan = chains_makespan(
                    spec, assignment.node_tasks, node_durs
                )
            else:
                assignment = list_schedule_allocation(tasks, tuple(alloc), spec)
                makespan = replay(assignment).makespan
            if i < len(deltas):
                j, s_new = deltas[i]
                if groups is not None:
                    groups.move(tasks[j], alloc[j], s_new)
                alloc[j] = s_new
                state["idx"] = i + 1
            return makespan, assignment

        areas = family_areas(tasks, first, deltas) if config.prune else None
        best, evaluated = _winner_scan(
            score, areas, config.eps, spec.n_slices, len(deltas) + 1
        )
        makespan, win, assignment = best
        winner_alloc = list(first)
        for j, s_new in deltas[:win]:
            winner_alloc[j] = s_new
        return FamilyWinner(
            makespan, win, assignment, tuple(winner_alloc), evaluated
        )


# -- vectorized array program -----------------------------------------------

_SPEC_CACHE = IdentityCache(16)       # spec -> _SpecArrays
_PROGRAM_CACHE = IdentityCache(64)    # (spec, (C, L)) -> jitted program

_BIG_SEQ = np.int32(2**30)



@dataclasses.dataclass
class _SpecArrays:
    """Per-spec constants of the lockstep program (spec.nodes BFS order)."""

    spec: DeviceSpec
    n_nodes: int
    n_sizes: int
    node_sizeidx: np.ndarray   # (N,) size-axis index per node
    node_keys: list            # (N,) NodeKey per node
    proj: np.ndarray           # (N, S+4+2N) selection-projection matrix
    theap0: np.ndarray         # (N,) initial heap times (roots 0, else inf)
    tseq0: np.ndarray          # (N,) initial heap seqs (roots 0..R-1)
    seq0: int                  # first free seq (= number of roots)


def _spec_eval_arrays(spec: DeviceSpec) -> _SpecArrays:
    cached = _SPEC_CACHE.get(spec)
    if cached is not None:
        return cached
    nodes = spec.nodes
    N = len(nodes)
    S = len(spec.sizes)
    sizeidx = {s: k for k, s in enumerate(spec.sizes)}
    index = {node.key: i for i, node in enumerate(nodes)}
    node_sizeidx = np.array([sizeidx[node.size] for node in nodes])
    size_onehot = np.zeros((N, S))
    size_onehot[np.arange(N), node_sizeidx] = 1.0
    tc = np.array([spec.t_create[node.size] for node in nodes])
    td = np.array([spec.t_destroy[node.size] for node in nodes])
    nid = np.arange(N, dtype=np.float64)
    nch = np.array([len(node.children) for node in nodes], dtype=np.float64)
    childmask = np.zeros((N, N))
    childrank = np.zeros((N, N))
    for i, node in enumerate(nodes):
        for rank, child in enumerate(node.children):
            childmask[i, index[child.key]] = 1.0
            childrank[i, index[child.key]] = float(rank)
    # one (C,N) @ (N, S+4+2N) matmul projects everything the step needs
    # out of the selected node's row: its size, reconfiguration costs, id,
    # child count, children mask and child push ranks.
    proj = np.concatenate(
        [size_onehot, tc[:, None], td[:, None], nid[:, None], nch[:, None],
         childmask, childrank], axis=1,
    )
    theap0 = np.full(N, np.inf)
    tseq0 = np.full(N, _BIG_SEQ, dtype=np.int32)
    roots = [index[r.key] for r in spec.roots]
    for rank, i in enumerate(roots):
        theap0[i] = 0.0
        tseq0[i] = rank
    out = _SpecArrays(
        spec, N, S, node_sizeidx, [node.key for node in nodes],
        proj, theap0, tseq0, len(roots),
    )
    _SPEC_CACHE.put(spec, out)
    return out


def _phase_a_program(sa: _SpecArrays, C: int, L: int) -> Callable:
    """Jitted lockstep Algorithm 1 over a ``(C, S, L)`` duration tensor.

    One step = one heap pop per candidate, in lockstep: a masked
    ``(time, seq)`` argmin over the node axis replaces the heap (the tree
    is tiny, so every node holds at most one pending entry), placement
    advances the popped size's cursor by one task, exhausted nodes
    repartition into their children or retire.  One-at-a-time placement
    pops in exactly the same order as the sequential runs-with-shortcut
    code (see ``_list_schedule_arrays``), and every reconfiguration /
    chain addition is a single f64 op in the same order, so the recorded
    pops are bit-identical to the sequential simulation.  Total steps are
    bounded by ``n + N``: every task is placed exactly once and each node
    leaves the heap at most once.

    Returns ``run(gdurs, glen) -> (nid, dur, pos)``, three ``(T, C)``
    step records: the popped node id when candidate ``c``'s ``t``-th pop
    placed a task (else -1), the placed duration, and the task's position
    in that node's chain.  The program is a ``lax.scan`` (stacked step
    outputs write into a preallocated buffer; a recording while_loop
    carry would copy the whole record every iteration, which on the CPU
    backend costs ~60x the step's arithmetic).  The op mix is deliberate:
    native min-reduces, one small matmul and one tiny gather per step —
    measured faster on the CPU backend than every "clever" alternative
    tried (variadic lax.reduce lex-min comparators, stacked payload
    tensors, block-amortized sliding-window duration lookups).
    """
    cached = _PROGRAM_CACHE.get(sa.spec, (C, L))
    if cached is not None:
        return cached
    jax, jnp, _ = _jax_modules()
    N = sa.n_nodes
    S = sa.n_sizes
    T = L + N
    INF = np.inf
    proj = jnp.asarray(sa.proj)
    theap0 = jnp.asarray(sa.theap0)
    tseq0 = jnp.asarray(sa.tseq0)
    seq0 = np.int32(sa.seq0)
    sizebase = jnp.asarray(np.arange(S, dtype=np.int32) * L)[None, :]
    CTC, CTD, CID, CNCH, CCH, CRK = S, S + 1, S + 2, S + 3, S + 4, S + 4 + N

    @jax.jit
    def run(gdurs, glen):
        gflat = gdurs.reshape(C, S * L)

        def body(st, _):
            (theap, tseq, seqctr, cursor, dnext, re, has, rem, ccnt) = st
            # pop: lexicographic (time, seq) min per candidate
            tmin = theap.min(1, keepdims=True)
            candm = theap == tmin
            seqm = jnp.where(candm, tseq, _BIG_SEQ)
            sel = candm & (seqm == seqm.min(1, keepdims=True))
            self_f = sel.astype(jnp.float64)
            p = self_f @ proj
            sel_s = p[:, :S] > 0.5
            tc = p[:, CTC:CTC + 1]
            td = p[:, CTD:CTD + 1]
            nid = p[:, CID:CID + 1]
            nch = p[:, CNCH:CNCH + 1]
            chmask = p[:, CCH:CCH + N] > 0.5
            chrank = p[:, CRK:CRK + N]

            alive = jnp.isfinite(tmin)
            place = (sel_s & (cursor < glen)).any(1, keepdims=True) & alive
            d = jnp.where(sel_s, dnext, 0.0).sum(1, keepdims=True)
            hasn = (sel & has).any(1, keepdims=True)
            create = place & ~hasn
            # the serialized reconfiguration tail (creation on first task,
            # destruction on repartitioning a used node)
            re_c = jnp.maximum(re, tmin) + tc
            start = jnp.where(create, re_c, tmin)
            end = start + d
            repart = alive & ~place & (rem > 0)
            destroy = repart & hasn
            re_d = jnp.maximum(re, tmin) + td
            re = jnp.where(create, re_c, jnp.where(destroy, re_d, re))
            # heap: placement re-pushes the node at its chain end; a
            # repartition replaces it by its children; a retire drops it
            theap = jnp.where(sel, jnp.where(place, end, INF), theap)
            theap = jnp.where(repart & chmask, tmin, theap)
            tseq = jnp.where(sel & place, seqctr, tseq)
            tseq = jnp.where(
                repart & chmask, seqctr + chrank.astype(jnp.int32), tseq
            )
            seqctr = seqctr + jnp.where(
                place, 1, jnp.where(repart, nch.astype(jnp.int32), 0)
            )
            has = has | (sel & create)
            pos = jnp.where(sel, ccnt, 0).sum(1, keepdims=True)
            ccnt = ccnt + (sel & place).astype(jnp.int32)
            adv = sel_s & place
            cursor = cursor + adv.astype(jnp.int32)
            # one scalar lookup per candidate (vmapped dynamic_slice beats
            # a (C, S) take_along_axis on the CPU backend)
            flatidx = jnp.where(
                sel_s, sizebase + jnp.minimum(cursor, L - 1), 0
            ).sum(1)
            gd = jax.vmap(
                lambda row, i: jax.lax.dynamic_slice(row, (i,), (1,))[0]
            )(gflat, flatidx)
            dnext = jnp.where(adv, gd[:, None], dnext)
            rem = rem - place.astype(jnp.int32)
            pl = place[:, 0]
            rec = (
                jnp.where(pl, nid[:, 0], -1.0),
                jnp.where(pl, d[:, 0], 0.0),
                jnp.where(pl, pos[:, 0].astype(jnp.float64), 0.0),
            )
            return (theap, tseq, seqctr, cursor, dnext, re, has, rem,
                    ccnt), rec

        st = (
            jnp.broadcast_to(theap0, (C, N)),
            jnp.broadcast_to(tseq0, (C, N)),
            jnp.full((C, 1), seq0, jnp.int32),
            jnp.zeros((C, S), jnp.int32),
            gdurs[:, :, 0],
            jnp.zeros((C, 1)),
            jnp.zeros((C, N), bool),
            glen.sum(1, keepdims=True),
            jnp.zeros((C, N), jnp.int32),
        )
        return jax.lax.scan(body, st, None, length=T)[1]

    _PROGRAM_CACHE.put(sa.spec, run, (C, L))
    return run


def _pow2(x: int) -> int:
    return 1 << max(1, (x - 1).bit_length())


@register_evaluator("vectorized")
class VectorizedEvaluator(FamilyEvaluator):
    """Chunked array-program scorer (module docstring has the design).

    Scores candidates in growing chunks through the jitted lockstep and
    the batched chain scorer; the shared :func:`_winner_scan` then walks
    the scores with the same prune/incumbent comparisons as the
    sequential path, so extra chunk-tail candidates cost time but never
    change the selection.  Only the winner's assignment is materialised
    (task ids resolved from the membership row + recorded pop sequence).
    """

    def evaluate(self, tasks, spec, first, deltas, config):
        if not HAVE_JAX:
            global _WARNED_NO_JAX
            if not _WARNED_NO_JAX:
                _WARNED_NO_JAX = True
                import warnings

                warnings.warn(
                    "evaluator='vectorized' requested but jax is not "
                    "importable; scoring sequentially (results are "
                    "identical, timings are not)",
                    RuntimeWarning,
                    stacklevel=2,
                )
            return EVALUATORS["sequential"].evaluate(
                tasks, spec, first, deltas, config
            )
        _, jnp, enable_x64 = _jax_modules()
        n = len(tasks)
        F = len(deltas) + 1
        sa = _spec_eval_arrays(spec)
        S, N = sa.n_sizes, sa.n_nodes
        orders = size_sorted_orders(tasks, spec)
        sizeidx = {s: k for k, s in enumerate(spec.sizes)}
        L = _pow2(n)

        # membership of each batch position in its per-size sorted order,
        # advanced chunk by chunk through the family deltas
        member = np.zeros((S, n), dtype=bool)
        rows = np.array([sizeidx[s] for s in first])
        member[rows, orders.inv[rows, np.arange(n)]] = True
        # delta column flips in sorted-position space: (size row, position)
        alloc = list(first)
        flips = []  # per delta: (row_old, pos_old, row_new, pos_new)
        for j, s_new in deltas:
            s_old = alloc[j]
            flips.append((
                sizeidx[s_old], orders.inv[sizeidx[s_old], j],
                sizeidx[s_new], orders.inv[sizeidx[s_new], j],
            ))
            alloc[j] = s_new

        # every chunk pays a full (n + N)-step scan regardless of its
        # width, so the schedule is: without pruning score the whole
        # family at once; with pruning one prune-window-sized chunk
        # first (the admissible prune usually stops within a few dozen
        # candidates), then geometrically growing remainders.  Only the
        # most recent chunk's pop records are retained — the scan keeps
        # the incumbent winner's single record column as its payload.
        first_chunk = min(F, MAX_CHUNK) if config.prune \
            else min(F, MAX_FAMILY_CHUNK)
        state = {"next": 0, "size": first_chunk, "scores": {},
                 "chunk": None}  # (i0, member at i0, pop node ids (T, C))

        def score_chunk(i0: int, count: int) -> None:
            # pad the candidate axis to a multiple of 32 (few compiled
            # variants, little waste — padded rows have no tasks and
            # retire in a handful of steps)
            Cb = max(8, -(-count // 32) * 32) if count > 8 else 8
            mem0 = member.copy()
            # duration tensor: candidate i0's rows by direct compress of
            # the base membership, then each next candidate as a copy of
            # the previous one with the one-task delta applied as two
            # shifted-row edits (delete at old LPT rank, insert at new)
            gdurs = np.zeros((Cb, S, L))
            glen = np.zeros((Cb, S), dtype=np.int32)
            for si in range(S):
                dsel = orders.durs[si][member[si]]
                gdurs[0, si, : len(dsel)] = dsel
                glen[0, si] = len(dsel)
            for k in range(1, count):
                ro, po, rn, pn = flips[i0 + k - 1]
                gdurs[k] = gdurs[k - 1]
                glen[k] = glen[k - 1]
                r_o = int(member[ro, :po].sum())
                lo = int(glen[k, ro])
                row = gdurs[k, ro]
                row[r_o:lo - 1] = row[r_o + 1:lo]
                row[lo - 1] = 0.0
                glen[k, ro] = lo - 1
                member[ro, po] = False
                r_n = int(member[rn, :pn].sum())
                ln = int(glen[k, rn])
                row = gdurs[k, rn]
                row[r_n + 1:ln + 1] = row[r_n:ln]
                row[r_n] = orders.durs[rn][pn]
                glen[k, rn] = ln + 1
                member[rn, pn] = True
            # advance the base membership past this chunk's last candidate
            if i0 + count - 1 < len(flips):
                ro, po, rn, pn = flips[i0 + count - 1]
                member[ro, po] = False
                member[rn, pn] = True
            # constants, tracing and execution must all sit inside the
            # x64 scope, or the program silently truncates to float32
            with enable_x64():
                run = _phase_a_program(sa, Cb, L)
                nid_j, dur_j, pos_j = run(jnp.asarray(gdurs), jnp.asarray(glen))
            t_used = n + N
            nid = np.asarray(nid_j)[:t_used].astype(np.int64)   # (T, Cb)
            dv = np.asarray(dur_j)[:t_used]
            cpos = np.asarray(pos_j)[:t_used].astype(np.int64)
            # per-node duration chains -> batched replay-semantics scoring
            # (the program already recorded each pop's chain position)
            valid = nid >= 0
            cols = np.broadcast_to(np.arange(Cb), nid.shape)[valid]
            nodes = nid[valid]
            grp = cols * N + nodes
            chain_len = np.bincount(grp, minlength=Cb * N).reshape(Cb, N)
            Lc = max(1, int(chain_len.max()))
            cd = np.zeros((Cb, N, Lc))
            cd[cols, nodes, cpos[valid]] = dv[valid]
            scores = chains_makespan_batch(spec, cd, chain_len)
            for k in range(count):
                state["scores"][i0 + k] = float(scores[k])
            state["chunk"] = (i0, mem0, nid)

        def score(i):
            while i >= state["next"]:
                count = min(state["size"], F - state["next"])
                score_chunk(state["next"], count)
                state["next"] += count
                # geometric growth bounds over-scoring past the prune
                # break to ~the last chunk's width
                state["size"] = max(
                    1, min(state["size"] * 4, F - state["next"],
                           MAX_FAMILY_CHUNK)
                )
            i0, mem0, nid = state["chunk"]
            return state["scores"][i], (i0, mem0, nid[:, i - i0].copy())

        areas = family_areas(tasks, first, deltas) if config.prune else None
        best, evaluated = _winner_scan(
            score, areas, config.eps, spec.n_slices, F
        )
        makespan, win, payload = best
        assignment = self._winner_assignment(
            tasks, spec, sa, orders, payload, flips, win
        )
        winner_alloc = list(first)
        for j, s_new in deltas[:win]:
            winner_alloc[j] = s_new
        return FamilyWinner(
            makespan, win, assignment, tuple(winner_alloc), evaluated
        )

    @staticmethod
    def _winner_assignment(tasks, spec, sa, orders, payload, flips, win):
        """Task-id chains of the winning candidate, in the exact node
        creation order the sequential simulation produces.  ``payload``
        is the scan-retained ``(chunk start, membership at chunk start,
        winner's pop-record column)``."""
        i0, mem0, pops = payload
        member_w = mem0.copy()
        for k in range(i0, win):
            ro, po, rn, pn = flips[k]
            member_w[ro, po] = False
            member_w[rn, pn] = True
        seqn = pops[pops >= 0]                 # node index per placement
        sidx = sa.node_sizeidx[seqn]
        pos = np.empty(len(seqn), dtype=np.int64)
        ids_w = {}
        for si in range(sa.n_sizes):
            m = sidx == si
            pos[m] = np.arange(m.sum())
            ids_w[si] = orders.ids[si][member_w[si]]
        node_tasks: dict = {}
        first_step = {}
        for nn in np.unique(seqn):
            first_step[nn] = int(np.argmax(seqn == nn))
        for nn in sorted(first_step, key=first_step.get):
            m = seqn == nn
            si = int(sa.node_sizeidx[nn])
            node_tasks[sa.node_keys[nn]] = ids_w[si][pos[m]].tolist()
        tasks_by_id = {t.id: t for t in tasks}
        return Assignment(spec, tasks_by_id, node_tasks)


__all__ = [
    "AUTO_MIN_FAMILY",
    "AUTO_MIN_TASKS",
    "AUTO_MIN_TASKS_UNPRUNED",
    "EVALUATORS",
    "FamilyEvaluator",
    "FamilyWinner",
    "HAVE_JAX",
    "SequentialEvaluator",
    "VectorizedEvaluator",
    "family_areas",
    "get_evaluator",
    "register_evaluator",
    "resolve_evaluator",
]
