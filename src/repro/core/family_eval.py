"""FAR phase-2 family evaluation behind a pluggable evaluator.

Phase 2 scores every Turek-family candidate with Algorithm 1 and keeps the
EPS-ordered winner (ties broken by family index).  This module owns that
loop behind a small registry so the scoring engine is swappable through
``SchedulerConfig(evaluator=...)`` while the *selection semantics* stay in
exactly one place (:func:`_winner_scan`):

* ``"sequential"`` — the reference path: one warm-started
  :class:`~repro.core.repartition.LPTGroups` simulation per candidate
  (or cold ``list_schedule_allocation`` + ``replay`` when
  ``config.use_engine`` is off).  The admissible prune area is maintained
  incrementally from the one-task family deltas (O(1) per candidate)
  instead of re-summing all tasks each iteration.
* ``"incremental"`` — delta-replay scoring: consecutive family candidates
  differ by one task's allocation, so each simulation snapshots its state
  right before the next delta's divergence point (derived from the LPT
  ranks the moved task leaves and enters) and the next candidate replays
  only the suffix.  The post-divergence resimulation runs in a small
  compiled C replica of Algorithm 1's heap loop
  (:mod:`repro.core.fastsim`, built on demand with the system compiler,
  strict IEEE flags); without a compiler a pure-Python full resimulation
  per candidate keeps the results identical.
* ``"parallel"`` — family sharding across a ``concurrent.futures``
  process pool: workers score contiguous candidate chunks with the
  sequential pipeline, the parent reduces the ordered scores through
  :func:`_winner_scan`, so selection (prune break, EPS rule, tie-break,
  ``evaluated``) is bit-identical and independent of worker count or
  completion order.
* ``"vectorized"`` — an array program that scores *chunks of candidates at
  once*.  Algorithm 1's heap is replaced by a ``(chunk, nodes)`` tensor
  lockstep: the device tree is tiny and fixed, so the event queue holds at
  most one entry per tree node and the pop becomes a masked argmin over
  the node axis, identical across all candidates of the chunk.  The
  per-size LPT groups come from one set of
  :func:`~repro.core.repartition.size_sorted_orders` total orders —
  consecutive candidates differ in exactly one task
  (``allocation_family_deltas``), so a chunk is a boolean membership
  tensor over those fixed orders, built by two column flips per candidate.
  The simulation itself is a jax-jitted ``lax.scan`` in float64 (the
  repo's accelerator toolchain; compiled once per shape bucket and cached)
  and the resulting per-node duration chains are scored with the batched
  :func:`~repro.core.timing.chains_makespan_batch`.  Without jax the
  evaluator transparently falls back to sequential scoring — same
  results, no speedup.
* ``"auto"`` — three-way dispatch: ``"incremental"`` when the C backend
  is buildable and the batch clears ``AUTO_MIN_TASKS_INCREMENTAL``,
  else ``"vectorized"`` when jax is importable and the batch/family are
  large enough to amortize the array program (``AUTO_MIN_TASKS`` pruned
  / ``AUTO_MIN_TASKS_UNPRUNED`` full-family, with ``AUTO_MIN_FAMILY``),
  else ``"sequential"``.  ``SchedulerConfig(evaluator_floor=)``
  overrides the task floors.

**Equivalence contract:** every evaluator returns a bit-identical winner —
index, allocation, assignment and makespan — for any workload and spec.
The vectorized path earns this by construction rather than by tolerance:
every floating-point accumulation (chain folds, the serialized
reconfiguration tail, the prune-area recurrence) performs the same IEEE
operations in the same order as the sequential code, the lockstep pop
reproduces the heap's ``(time, seq)`` tie-breaking exactly, and the final
winner/prune scan is the shared :func:`_winner_scan` driver.  Enforced by
``tests/test_family_eval.py`` and the hypothesis property suite.
"""

from __future__ import annotations

import bisect
import dataclasses
import heapq
import os
from typing import Callable, Sequence

import numpy as np

from repro.core.allocations import Allocation
from repro.core.device_spec import DeviceSpec
from repro.core.problem import Task
from repro.core.repartition import (
    Assignment,
    LPTGroups,
    list_schedule_allocation,
    replay,
    size_sorted_orders,
)
from repro.core.timing import (
    IdentityCache,
    chains_makespan,
    chains_makespan_batch,
)

# jax is probed, not imported: `import repro.core` must stay free of
# jax's multi-second import / backend init for users on the sequential
# path.  The modules load lazily on first vectorized evaluation.
import importlib.util

HAVE_JAX = importlib.util.find_spec("jax") is not None

_WARNED_NO_JAX = False


def _jax_modules():
    """(jax, jax.numpy, enable_x64), imported on first use."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    return jax, jnp, enable_x64

#: "auto" dispatch thresholds, calibrated on the container benchmarks
#: (benchmarks/t_cost.py, paired medians).  The incremental evaluator's
#: compiled delta-replay wins as soon as candidates are expensive enough
#: to amortise its buffer setup (n>=256 with the usual prune window;
#: measured ~2.2x at n=500 pruned, ~4x at n=1000, ~5.7x at n=2000, and
#: up to ~8x full-family), so it is auto's first choice whenever the C
#: backend is buildable.  The
#: vectorized array program is the fallback tier (jax present, no C
#: compiler): its per-step cost is fixed per chunk while the sequential
#: cost is per *scored* candidate, so it wins where many candidates are
#: actually scored — unpruned (full-family) runs from moderate sizes on
#: (1.2-1.6x at n=500-2000 on the 2-vCPU CI box), pruned runs only once
#: the batch is so large that the ~2-dozen-candidate prune window still
#: beats the scan's fixed dispatch floor (crossover n~2000; margin
#: added).  ``SchedulerConfig(evaluator_floor=)`` overrides the task
#: floors without touching these module constants.
AUTO_MIN_TASKS_INCREMENTAL = 256  # delta-replay: wins from small batches
AUTO_MIN_TASKS = 3072          # pruned runs: scored window stays ~20-30
AUTO_MIN_TASKS_UNPRUNED = 512  # full-family runs: every candidate scored
AUTO_MIN_FAMILY = 48

#: chunk sizes for the vectorized scan.  Every chunk pays a full scan
#: pass, so a pruned run starts with one prune-window-sized chunk (the
#: admissible prune usually stops within a few dozen candidates) and an
#: unpruned run scores the whole family in one pass (memory-capped).
MAX_CHUNK = 32
MAX_FAMILY_CHUNK = 512


@dataclasses.dataclass
class FamilyWinner:
    """Phase-2 outcome: the EPS-ordered family winner."""

    makespan: float
    index: int
    assignment: Assignment
    allocation: Allocation
    evaluated: int


# -- registry ---------------------------------------------------------------

EVALUATORS: dict[str, "FamilyEvaluator"] = {}


def register_evaluator(name: str):
    """Class decorator adding a family evaluator under ``name``."""

    def deco(cls):
        cls.name = name
        EVALUATORS[name] = cls()
        return cls

    return deco


def get_evaluator(name: str) -> "FamilyEvaluator":
    try:
        return EVALUATORS[name]
    except KeyError:
        raise KeyError(
            f"unknown family evaluator {name!r}; "
            f"available: {', '.join(sorted(EVALUATORS))}"
        ) from None


def resolve_evaluator(config, n_tasks: int, family_size: int) -> str:
    """Map ``config.evaluator`` to a concrete evaluator name.

    The replay reference path (``use_engine=False``) always scores
    sequentially — it exists to cross-check the engine pipeline, so it
    must stay on the unoptimised code path.
    """
    name = config.evaluator
    if name == "auto":
        if not config.use_engine or family_size < AUTO_MIN_FAMILY:
            return "sequential"
        floor = getattr(config, "evaluator_floor", None)
        floor_inc = AUTO_MIN_TASKS_INCREMENTAL if floor is None else floor
        if floor is None:
            floor_vec = (
                AUTO_MIN_TASKS if config.prune else AUTO_MIN_TASKS_UNPRUNED
            )
        else:
            floor_vec = floor
        if n_tasks >= floor_inc:
            from repro.core import fastsim

            if fastsim.available():
                return "incremental"
        if HAVE_JAX and n_tasks >= floor_vec:
            return "vectorized"
        return "sequential"
    if name in ("vectorized", "incremental", "parallel") \
            and not config.use_engine:
        return "sequential"
    return name


# -- shared selection semantics ---------------------------------------------


def family_areas(
    tasks: Sequence[Task], first: Allocation, deltas: list[tuple[int, int]]
) -> np.ndarray:
    """Prune area of every family candidate, by one-task delta recurrence.

    ``area_0`` is the plain left-fold sum over the first allocation;
    ``area_{i+1} = area_i + (s_new * t(s_new) - s_old * t(s_old))`` via
    ``np.add.accumulate`` — the same IEEE additions whether the recurrence
    runs here or one step at a time, so both evaluators see identical
    values.  O(n + family) total instead of O(n) per candidate.
    """
    area0 = sum(s * t.times[s] for t, s in zip(tasks, first))
    if not deltas:
        return np.array([area0])
    alloc = list(first)
    terms = np.empty(len(deltas))
    for k, (j, s_new) in enumerate(deltas):
        s_old = alloc[j]
        t = tasks[j]
        terms[k] = s_new * t.times[s_new] - s_old * t.times[s_old]
        alloc[j] = s_new
    return np.add.accumulate(np.concatenate(([area0], terms)))


def _winner_scan(
    score: Callable[[int], tuple[float, object]],
    areas: np.ndarray | None,
    eps: float,
    n_slices: int,
    family_size: int,
) -> tuple[tuple[float, int, object], int]:
    """The phase-2 selection loop, shared by every evaluator.

    ``score(i)`` is called for consecutive ``i`` starting at 0 and returns
    ``(makespan, payload)``.  Candidate ``i`` is pruned-past (loop break)
    when an incumbent exists and ``areas[i] / n_slices`` already reaches
    it; the incumbent is replaced only on a strict EPS improvement, so
    ties keep the earliest family index.  Returns the winning
    ``(makespan, index, payload)`` and the number of scored candidates.
    """
    best: tuple[float, int, object] | None = None
    evaluated = 0
    i = 0
    while True:
        if areas is not None and best is not None:
            if areas[i] / n_slices >= best[0] - eps:
                break  # all later allocations have >= area -> dominated
        makespan, payload = score(i)
        evaluated += 1
        if best is None or makespan < best[0] - eps:
            best = (makespan, i, payload)
        if i == family_size - 1:
            break
        i += 1
    assert best is not None
    return best, evaluated


class FamilyEvaluator:
    """Protocol: ``evaluate(tasks, spec, first, deltas, config)``."""

    name = "?"

    def evaluate(
        self,
        tasks: Sequence[Task],
        spec: DeviceSpec,
        first: Allocation,
        deltas: list[tuple[int, int]],
        config,
    ) -> FamilyWinner:
        raise NotImplementedError


# -- sequential reference ---------------------------------------------------


@register_evaluator("sequential")
class SequentialEvaluator(FamilyEvaluator):
    """One warm-started Algorithm-1 simulation per candidate (paper §3.2).

    ``config.use_engine`` selects the warm ``LPTGroups`` + lean
    ``chains_makespan`` pipeline (default) or the cold
    replay-per-candidate reference path; both produce identical winners.
    """

    def evaluate(self, tasks, spec, first, deltas, config):
        groups = LPTGroups(tasks, first, spec) if config.use_engine else None
        alloc = list(first)
        state = {"idx": 0}

        def score(i):
            assert i == state["idx"]
            if groups is not None:
                assignment, node_durs = groups.schedule_with_durs()
                makespan = chains_makespan(
                    spec, assignment.node_tasks, node_durs
                )
            else:
                assignment = list_schedule_allocation(tasks, tuple(alloc), spec)
                makespan = replay(assignment).makespan
            if i < len(deltas):
                j, s_new = deltas[i]
                if groups is not None:
                    groups.move(tasks[j], alloc[j], s_new)
                alloc[j] = s_new
                state["idx"] = i + 1
            return makespan, assignment

        areas = family_areas(tasks, first, deltas) if config.prune else None
        best, evaluated = _winner_scan(
            score, areas, config.eps, spec.n_slices, len(deltas) + 1
        )
        makespan, win, assignment = best
        winner_alloc = list(first)
        for j, s_new in deltas[:win]:
            winner_alloc[j] = s_new
        return FamilyWinner(
            makespan, win, assignment, tuple(winner_alloc), evaluated
        )


# -- incremental delta-replay evaluator -------------------------------------

_SIM_CACHE = IdentityCache(16)  # spec -> _SimContext


@dataclasses.dataclass
class _SimContext:
    """Flat per-spec arrays of Algorithm 1's heap phase (C + Python)."""

    spec: DeviceSpec
    n_nodes: int
    n_sizes: int
    sizeidx: dict              # instance size -> size-axis index
    node_keys: list            # node index -> NodeKey
    ns_list: list              # node index -> size-axis index
    tc_list: list              # size-axis index -> creation charge
    td_list: list
    children: list             # node index -> [child node indices]
    roots: list                # root node indices, spec order
    ns: np.ndarray             # the same, as C-ready arrays
    tc: np.ndarray
    td: np.ndarray
    ch_off: np.ndarray
    ch_idx: np.ndarray
    tree: np.ndarray           # node index -> forest tree index
    n_trees: int


def _sim_context(spec: DeviceSpec) -> _SimContext:
    cached = _SIM_CACHE.get(spec)
    if cached is not None:
        return cached
    nodes = spec.nodes
    sizeidx = {s: k for k, s in enumerate(spec.sizes)}
    index = {node.key: i for i, node in enumerate(nodes)}
    ns_list = [sizeidx[node.size] for node in nodes]
    tc_list = [spec.t_create[s] for s in spec.sizes]
    td_list = [spec.t_destroy[s] for s in spec.sizes]
    children = [[index[c.key] for c in node.children] for node in nodes]
    ch_off = np.zeros(len(nodes) + 1, dtype=np.int32)
    for i, ch in enumerate(children):
        ch_off[i + 1] = ch_off[i] + len(ch)
    flat = [c for ch in children for c in ch]
    tree_list = [node.tree for node in nodes]
    ctx = _SimContext(
        spec, len(nodes), len(spec.sizes), sizeidx,
        [node.key for node in nodes], ns_list, tc_list, td_list, children,
        [index[r.key] for r in spec.roots],
        np.array(ns_list, dtype=np.int32),
        np.array(tc_list), np.array(td_list),
        ch_off, np.array(flat or [0], dtype=np.int32),
        np.array(tree_list, dtype=np.int32),
        max(tree_list) + 1 if tree_list else 1,
    )
    _SIM_CACHE.put(spec, ctx)
    return ctx


def _py_sim(ctx: _SimContext, durs_rows: list, n_tasks: int) -> list:
    """Pure-Python cold run of the C loop: Algorithm 1's heap phase over
    size-indexed duration rows, returning the placement visit trace
    ``[(node index, slice start, slice end), ...]``.  Same pops, same
    IEEE additions, same early stop as ``_fastsim.c`` — the incremental
    evaluator's fallback when no C compiler is available."""
    ns_list = ctx.ns_list
    tc_list = ctx.tc_list
    td_list = ctx.td_list
    children = ctx.children
    INF = float("inf")
    cursor = [0] * ctx.n_sizes
    created = bytearray(ctx.n_nodes)
    lens = [len(r) for r in durs_rows]
    reconfig_end = 0.0
    heap = [(0.0, k, r) for k, r in enumerate(ctx.roots)]
    seq = len(heap)
    remaining = n_tasks
    visits: list[tuple[int, int, int]] = []
    heapreplace = heapq.heapreplace
    heappush = heapq.heappush
    heappop = heapq.heappop
    while heap:
        end, _, nidx = heap[0]
        si = ns_list[nidx]
        cur = cursor[si]
        n_grp = lens[si]
        if cur < n_grp:
            if not created[nidx]:
                if end > reconfig_end:
                    reconfig_end = end
                reconfig_end += tc_list[si]
                end = reconfig_end
                created[nidx] = 1
            L = len(heap)
            if L > 2:
                t1 = heap[1][0]
                t2 = heap[2][0]
                nxt = t2 if t2 < t1 else t1
            elif L == 2:
                nxt = heap[1][0]
            else:
                nxt = INF
            row = durs_rows[si]
            start = cur
            while True:
                end += row[cur]
                cur += 1
                if cur >= n_grp or end >= nxt:
                    break
            cursor[si] = cur
            visits.append((nidx, start, cur))
            remaining -= cur - start
            if not remaining:
                break  # drain pops place nothing: early stop
            heapreplace(heap, (end, seq, nidx))
            seq += 1
        elif remaining:
            if created[nidx]:
                if end > reconfig_end:
                    reconfig_end = end
                reconfig_end += td_list[si]
            ch = children[nidx]
            if ch:
                heapreplace(heap, (end, seq, ch[0]))
                seq += 1
                for c in ch[1:]:
                    heappush(heap, (end, seq, c))
                    seq += 1
            else:
                heappop(heap)
        else:
            break  # every task placed: remaining pops only retire
    return visits


@register_evaluator("incremental")
class IncrementalEvaluator(FamilyEvaluator):
    """Delta-replay family scoring: patch the previous trajectory.

    Consecutive family candidates differ by one task's allocation
    (``allocation_family_deltas``), so their Algorithm-1 trajectories
    share a prefix up to the first heap pop whose outcome the delta
    changes.  While simulating candidate ``i``, the compiled backend
    (:mod:`repro.core.fastsim`) snapshots the live state right before
    that divergence point — derived exactly from the LPT ranks the moved
    task leaves and enters, not from fixed checkpoint strides — and
    candidate ``i+1`` restores the snapshot and replays only the
    suffix.  The per-node duration chains come straight from the visit
    trace and are scored by the same :func:`chains_makespan` left folds
    as the sequential path; the winner's assignment is materialised
    lazily, only when an incumbent improves, with the same strict-EPS
    comparison :func:`_winner_scan` applies.  Bit-identical winners by
    construction: same pops, same IEEE additions, same selection scan.

    Without a C compiler the evaluator degrades to a full pure-Python
    resimulation per candidate (:func:`_py_sim`) — still bit-identical,
    only the speedup is gone.  ``use_engine=False`` delegates to
    sequential like the vectorized path does.
    """

    def evaluate(self, tasks, spec, first, deltas, config):
        if not config.use_engine:
            return EVALUATORS["sequential"].evaluate(
                tasks, spec, first, deltas, config
            )
        from repro.core import fastsim

        lib = fastsim.load()
        n = len(tasks)
        F = len(deltas) + 1
        ctx = _sim_context(spec)
        S, N = ctx.n_sizes, ctx.n_nodes
        sizes = spec.sizes
        sizeidx = ctx.sizeidx
        node_keys = ctx.node_keys
        ns_list = ctx.ns_list
        groups = LPTGroups(tasks, first, spec)
        alloc = list(first)
        eps = config.eps
        # live per-size rows, ordered by size index: LPTGroups mutates
        # these list objects in place, so the references stay current
        durs_rows = [groups._durs[s] for s in sizes]
        ids_rows = [groups._ids[s] for s in sizes]

        if lib is not None:
            lmax = max(1, n)
            gdurs = np.zeros((S, lmax))
            glens = np.zeros(S, dtype=np.int32)
            for k in range(S):
                row = durs_rows[k]
                glens[k] = len(row)
                if row:
                    gdurs[k, : len(row)] = row
            hdt = fastsim.heap_dtype()
            cursor = np.zeros(S, dtype=np.int32)
            created = np.zeros(N, dtype=np.int8)
            exh = np.zeros(S, dtype=np.int8)
            heap = np.zeros(N, dtype=hdt)
            heap_len = np.zeros(1, dtype=np.int32)
            scalars = np.zeros(1)
            counters = np.zeros(3, dtype=np.int64)
            s_cursor = np.zeros_like(cursor)
            s_created = np.zeros_like(created)
            s_exh = np.zeros_like(exh)
            s_heap = np.zeros_like(heap)
            s_heap_len = np.zeros(1, dtype=np.int32)
            s_scalars = np.zeros(1)
            s_counters = np.zeros(3, dtype=np.int64)
            snap_flags = np.zeros(2, dtype=np.int32)
            v_node = np.zeros(max(1, n), dtype=np.int32)
            v_start = np.zeros_like(v_node)
            v_end = np.zeros_like(v_node)
            roots = np.array(ctx.roots, dtype=np.int32)
            # chains_makespan scorer scratch (see fastsim_score)
            sc_act = np.zeros(N, dtype=np.int8)
            sc_sub = np.zeros(N, dtype=np.int8)
            sc_head = np.zeros(N, dtype=np.int32)
            sc_tail = np.zeros(N, dtype=np.int32)
            sc_nxt = np.zeros(max(1, n), dtype=np.int32)
            sc_heap = np.zeros(N, dtype=fastsim.evt_dtype())
            sc_rc = np.zeros(max(1, ctx.n_trees))
            per_tree = 1 if spec.reconfig_scope != "global" else 0

            def _cold():
                R = len(roots)
                cursor[:] = 0
                created[:] = 0
                exh[:] = 0
                heap["end"][:R] = 0.0
                heap["seq"][:R] = np.arange(R)
                heap["nidx"][:R] = roots
                heap_len[0] = R
                scalars[0] = 0.0
                counters[0] = R
                counters[1] = n
                counters[2] = 0

            def _run_c(trig):
                a_si, a_rk, b_si, b_rk, b_visit = trig
                rc = lib.run(
                    cursor.ctypes.data, created.ctypes.data,
                    exh.ctypes.data,
                    heap.ctypes.data, heap_len.ctypes.data,
                    scalars.ctypes.data, counters.ctypes.data,
                    N, S,
                    ctx.ns.ctypes.data, ctx.tc.ctypes.data,
                    ctx.td.ctypes.data, ctx.ch_off.ctypes.data,
                    ctx.ch_idx.ctypes.data,
                    gdurs.ctypes.data, glens.ctypes.data, lmax,
                    a_si, a_rk, b_si, b_rk, b_visit,
                    s_cursor.ctypes.data, s_created.ctypes.data,
                    s_exh.ctypes.data,
                    s_heap.ctypes.data, s_heap_len.ctypes.data,
                    s_scalars.ctypes.data, s_counters.ctypes.data,
                    snap_flags.ctypes.data,
                    v_node.ctypes.data, v_start.ctypes.data,
                    v_end.ctypes.data, len(v_node),
                )
                assert rc == 0, "fastsim visit buffer overflow"

            def _score_c(nv):
                return lib.score(
                    N, S,
                    ctx.ns.ctypes.data, ctx.tree.ctypes.data,
                    per_tree, ctx.n_trees,
                    ctx.tc.ctypes.data, ctx.td.ctypes.data,
                    ctx.ch_off.ctypes.data, ctx.ch_idx.ctypes.data,
                    roots.ctypes.data, len(roots),
                    gdurs.ctypes.data, lmax,
                    v_node.ctypes.data, v_start.ctypes.data,
                    v_end.ctypes.data, nv,
                    sc_act.ctypes.data, sc_sub.ctypes.data,
                    sc_head.ctypes.data, sc_tail.ctypes.data,
                    sc_nxt.ctypes.data, sc_heap.ctypes.data,
                    sc_rc.ctypes.data,
                )

        tasks_by_id = groups.tasks_by_id
        best_state = {"mk": None, "assignment": None, "snap": False}

        def _score_visits(visits):
            node_durs: dict = {}
            for nidx, sv, ev in visits:
                key = node_keys[nidx]
                lst = node_durs.get(key)
                if lst is None:
                    node_durs[key] = durs_rows[ns_list[nidx]][sv:ev]
                else:
                    lst.extend(durs_rows[ns_list[nidx]][sv:ev])
            return chains_makespan(spec, node_durs, node_durs)

        def _materialize(visits):
            node_tasks: dict = {}
            for nidx, sv, ev in visits:
                key = node_keys[nidx]
                lst = node_tasks.get(key)
                if lst is None:
                    node_tasks[key] = ids_rows[ns_list[nidx]][sv:ev]
                else:
                    lst.extend(ids_rows[ns_list[nidx]][sv:ev])
            return Assignment(spec, tasks_by_id, node_tasks)

        state = {"idx": 0}

        def score(i):
            assert i == state["idx"]
            # the *next* delta's divergence trigger, in candidate i's rows
            if i < len(deltas):
                j, s_new = deltas[i]
                s_old = alloc[j]
                task = tasks[j]
                keys_old = groups._keys[s_old]
                r_old = bisect.bisect_left(
                    keys_old, (-task.times[s_old], task.id)
                )
                keys_new = groups._keys[s_new]
                r_new = bisect.bisect_left(
                    keys_new, (-task.times[s_new], task.id)
                )
                trig = (
                    sizeidx[s_old], r_old, sizeidx[s_new], r_new,
                    1 if r_new == len(keys_new) else 0,
                )
            else:
                task = r_old = r_new = None
                trig = (-1, -1, -1, -1, 0)
            if lib is not None:
                if i == 0 or not best_state["snap"]:
                    _cold()
                else:
                    # restore the snapshot taken during candidate i-1
                    L = int(s_heap_len[0])
                    cursor[:] = s_cursor
                    created[:] = s_created
                    exh[:] = s_exh
                    heap[:L] = s_heap[:L]
                    heap_len[0] = L
                    scalars[0] = s_scalars[0]
                    counters[:] = s_counters
                # a snapshot produced by this run is only trustworthy
                # when the run *starts* at a shared-prefix point of the
                # next delta — a resume point past the delta's ranks (or
                # past an exhausted-row pop, for tail appends) would hide
                # an earlier divergence, so disarm and resimulate the
                # next candidate cold instead
                trusted = True
                if trig[0] >= 0:
                    a_si, a_rk, b_si, b_rk, b_visit = trig
                    if (
                        cursor[a_si] > a_rk
                        or cursor[b_si] > b_rk
                        or (b_visit and exh[b_si])
                    ):
                        trig = (-1, -1, -1, -1, 0)
                        trusted = False
                snap_flags[:] = 0
                _run_c(trig)
                nv = int(counters[2])
                best_state["snap"] = trusted and bool(snap_flags[0])
                makespan = _score_c(nv)
                visits = None  # materialised only for improving incumbents
            else:
                visits = _py_sim(ctx, durs_rows, n)
                makespan = _score_visits(visits)
            # mirror _winner_scan's replacement comparison exactly, so
            # the assignment is built only for improving incumbents
            if best_state["mk"] is None or makespan < best_state["mk"] - eps:
                best_state["mk"] = makespan
                if visits is None:
                    visits = list(zip(
                        v_node[:nv].tolist(), v_start[:nv].tolist(),
                        v_end[:nv].tolist(),
                    ))
                best_state["assignment"] = _materialize(visits)
            if i < len(deltas):
                groups.move(task, s_old, s_new)
                alloc[j] = s_new
                if lib is not None:
                    a, b = sizeidx[s_old], sizeidx[s_new]
                    la = int(glens[a])
                    row = gdurs[a]
                    row[r_old:la - 1] = row[r_old + 1:la]
                    row[la - 1] = 0.0
                    glens[a] = la - 1
                    lb = int(glens[b])
                    row = gdurs[b]
                    row[r_new + 1:lb + 1] = row[r_new:lb]
                    row[r_new] = task.times[s_new]
                    glens[b] = lb + 1
                state["idx"] = i + 1
            return makespan, None

        areas = family_areas(tasks, first, deltas) if config.prune else None
        best, evaluated = _winner_scan(
            score, areas, config.eps, spec.n_slices, F
        )
        makespan, win, _ = best
        winner_alloc = list(first)
        for j, s_new in deltas[:win]:
            winner_alloc[j] = s_new
        return FamilyWinner(
            makespan, win, best_state["assignment"], tuple(winner_alloc),
            evaluated,
        )


# -- parallel family sharding -----------------------------------------------

#: candidates per worker chunk on pruned runs (the prune break usually
#: lands inside the first chunk, so small chunks bound wasted scoring)
PARALLEL_PRUNED_CHUNK = 32


def _parallel_chunk_scores(payload):
    """Pool worker: full Algorithm-1 scores of family chunk ``[lo, hi)``.

    Warm-starts :class:`LPTGroups` at candidate ``lo`` (the maintained
    order is bit-identical to a cold sort) and scores every candidate of
    the chunk with the exact sequential pipeline — no pruning in the
    worker, the parent's reduce owns the selection semantics.
    """
    tasks, spec, first, deltas, lo, hi = payload
    alloc = list(first)
    for j, s_new in deltas[:lo]:
        alloc[j] = s_new
    groups = LPTGroups(tasks, tuple(alloc), spec)
    out = []
    for i in range(lo, hi):
        assignment, node_durs = groups.schedule_with_durs()
        out.append(chains_makespan(spec, assignment.node_tasks, node_durs))
        if i < len(deltas):
            j, s_new = deltas[i]
            groups.move(tasks[j], alloc[j], s_new)
            alloc[j] = s_new
    return out


@register_evaluator("parallel")
class ParallelEvaluator(FamilyEvaluator):
    """Process-pool family sharding with a deterministic ordered reduce.

    The family is cut into contiguous index chunks; pool workers score
    whole chunks with the sequential pipeline (LPT warm-start inside the
    chunk, no pruning) and return plain makespan lists.  The parent
    walks those scores through the shared :func:`_winner_scan` in family
    order, so the prune break, the strict-EPS incumbent rule, the
    family-index tie-break and the ``evaluated`` count are reproduced
    bit-identically no matter how many workers run or in which order
    chunks complete — results are keyed by chunk index, never by
    arrival.  Only the winner is resimulated (once, in-process) to
    materialise its assignment.

    ``SchedulerConfig(parallel_workers=)`` sizes the pool (0 = all
    cores); one worker or a one-candidate family short-circuits to the
    sequential evaluator.  Chunks are dispatched lazily a pool-width
    ahead of the scan so pruned runs do not score the whole family.

    Like any forkserver/spawn ``multiprocessing`` use, calling this
    evaluator from a script requires the usual
    ``if __name__ == "__main__":`` entry guard — the workers re-import
    ``__main__``.
    """

    def evaluate(self, tasks, spec, first, deltas, config):
        workers = getattr(config, "parallel_workers", 0) or (
            os.cpu_count() or 1
        )
        F = len(deltas) + 1
        if not config.use_engine or workers <= 1 or F <= 1:
            return EVALUATORS["sequential"].evaluate(
                tasks, spec, first, deltas, config
            )
        import concurrent.futures as cf
        import multiprocessing as mp

        # fork would clone whatever thread pools the parent has running
        # (jax's in particular — a known deadlock); the forkserver is a
        # clean process forked before any of that, with spawn as the
        # portable fallback
        try:
            mp_ctx = mp.get_context("forkserver")
        except ValueError:  # pragma: no cover - platform without it
            mp_ctx = mp.get_context("spawn")

        chunk = (
            PARALLEL_PRUNED_CHUNK if config.prune
            else max(1, -(-F // workers))
        )
        bounds = [
            (lo, min(lo + chunk, F)) for lo in range(0, F, chunk)
        ]
        scores: dict[int, float] = {}
        futures: dict[int, object] = {}
        submitted = {"next": 0}

        with cf.ProcessPoolExecutor(
            max_workers=workers, mp_context=mp_ctx
        ) as pool:

            def _submit_ahead(upto_chunk: int) -> None:
                # keep a pool-width of chunks in flight past the scan
                while (
                    submitted["next"] < len(bounds)
                    and submitted["next"] <= upto_chunk + workers
                ):
                    lo, hi = bounds[submitted["next"]]
                    futures[submitted["next"]] = pool.submit(
                        _parallel_chunk_scores,
                        (tasks, spec, first, deltas, lo, hi),
                    )
                    submitted["next"] += 1

            def score(i):
                k = i // chunk
                _submit_ahead(k)
                if i not in scores:
                    lo = bounds[k][0]
                    for off, mk in enumerate(futures[k].result()):
                        scores[lo + off] = mk
                return scores[i], None

            areas = (
                family_areas(tasks, first, deltas) if config.prune else None
            )
            best, evaluated = _winner_scan(
                score, areas, config.eps, spec.n_slices, F
            )
        makespan, win, _ = best
        winner_alloc = list(first)
        for j, s_new in deltas[:win]:
            winner_alloc[j] = s_new
        # one in-process resimulation materialises the winner (the
        # maintained LPT order is bit-identical to this cold build)
        assignment = LPTGroups(
            tasks, tuple(winner_alloc), spec
        ).schedule()
        return FamilyWinner(
            makespan, win, assignment, tuple(winner_alloc), evaluated
        )


# -- vectorized array program -----------------------------------------------

_SPEC_CACHE = IdentityCache(16)       # spec -> _SpecArrays
_PROGRAM_CACHE = IdentityCache(64)    # (spec, (C, L)) -> jitted program

_BIG_SEQ = np.int32(2**30)



@dataclasses.dataclass
class _SpecArrays:
    """Per-spec constants of the lockstep program (spec.nodes BFS order)."""

    spec: DeviceSpec
    n_nodes: int
    n_sizes: int
    node_sizeidx: np.ndarray   # (N,) size-axis index per node
    node_keys: list            # (N,) NodeKey per node
    proj: np.ndarray           # (N, S+4+2N) selection-projection matrix
    theap0: np.ndarray         # (N,) initial heap times (roots 0, else inf)
    tseq0: np.ndarray          # (N,) initial heap seqs (roots 0..R-1)
    seq0: int                  # first free seq (= number of roots)


def _spec_eval_arrays(spec: DeviceSpec) -> _SpecArrays:
    cached = _SPEC_CACHE.get(spec)
    if cached is not None:
        return cached
    nodes = spec.nodes
    N = len(nodes)
    S = len(spec.sizes)
    sizeidx = {s: k for k, s in enumerate(spec.sizes)}
    index = {node.key: i for i, node in enumerate(nodes)}
    node_sizeidx = np.array([sizeidx[node.size] for node in nodes])
    size_onehot = np.zeros((N, S))
    size_onehot[np.arange(N), node_sizeidx] = 1.0
    tc = np.array([spec.t_create[node.size] for node in nodes])
    td = np.array([spec.t_destroy[node.size] for node in nodes])
    nid = np.arange(N, dtype=np.float64)
    nch = np.array([len(node.children) for node in nodes], dtype=np.float64)
    childmask = np.zeros((N, N))
    childrank = np.zeros((N, N))
    for i, node in enumerate(nodes):
        for rank, child in enumerate(node.children):
            childmask[i, index[child.key]] = 1.0
            childrank[i, index[child.key]] = float(rank)
    # one (C,N) @ (N, S+4+2N) matmul projects everything the step needs
    # out of the selected node's row: its size, reconfiguration costs, id,
    # child count, children mask and child push ranks.
    proj = np.concatenate(
        [size_onehot, tc[:, None], td[:, None], nid[:, None], nch[:, None],
         childmask, childrank], axis=1,
    )
    theap0 = np.full(N, np.inf)
    tseq0 = np.full(N, _BIG_SEQ, dtype=np.int32)
    roots = [index[r.key] for r in spec.roots]
    for rank, i in enumerate(roots):
        theap0[i] = 0.0
        tseq0[i] = rank
    out = _SpecArrays(
        spec, N, S, node_sizeidx, [node.key for node in nodes],
        proj, theap0, tseq0, len(roots),
    )
    _SPEC_CACHE.put(spec, out)
    return out


def _phase_a_program(sa: _SpecArrays, C: int, L: int) -> Callable:
    """Jitted lockstep Algorithm 1 over a ``(C, S, L)`` duration tensor.

    One step = one heap pop per candidate, in lockstep: a masked
    ``(time, seq)`` argmin over the node axis replaces the heap (the tree
    is tiny, so every node holds at most one pending entry), placement
    advances the popped size's cursor by one task, exhausted nodes
    repartition into their children or retire.  One-at-a-time placement
    pops in exactly the same order as the sequential runs-with-shortcut
    code (see ``_list_schedule_arrays``), and every reconfiguration /
    chain addition is a single f64 op in the same order, so the recorded
    pops are bit-identical to the sequential simulation.  Total steps are
    bounded by ``n + N``: every task is placed exactly once and each node
    leaves the heap at most once.

    Returns ``run(gdurs, glen) -> (nid, dur, pos)``, three ``(T, C)``
    step records: the popped node id when candidate ``c``'s ``t``-th pop
    placed a task (else -1), the placed duration, and the task's position
    in that node's chain.  The program is a ``lax.scan`` (stacked step
    outputs write into a preallocated buffer; a recording while_loop
    carry would copy the whole record every iteration, which on the CPU
    backend costs ~60x the step's arithmetic).  The op mix is deliberate:
    native min-reduces, one small matmul and one tiny gather per step —
    measured faster on the CPU backend than every "clever" alternative
    tried (variadic lax.reduce lex-min comparators, stacked payload
    tensors, block-amortized sliding-window duration lookups).
    """
    cached = _PROGRAM_CACHE.get(sa.spec, (C, L))
    if cached is not None:
        return cached
    jax, jnp, _ = _jax_modules()
    N = sa.n_nodes
    S = sa.n_sizes
    T = L + N
    INF = np.inf
    proj = jnp.asarray(sa.proj)
    theap0 = jnp.asarray(sa.theap0)
    tseq0 = jnp.asarray(sa.tseq0)
    seq0 = np.int32(sa.seq0)
    sizebase = jnp.asarray(np.arange(S, dtype=np.int32) * L)[None, :]
    CTC, CTD, CID, CNCH, CCH, CRK = S, S + 1, S + 2, S + 3, S + 4, S + 4 + N

    @jax.jit
    def run(gdurs, glen):
        gflat = gdurs.reshape(C, S * L)

        def body(st, _):
            (theap, tseq, seqctr, cursor, dnext, re, has, rem, ccnt) = st
            # pop: lexicographic (time, seq) min per candidate
            tmin = theap.min(1, keepdims=True)
            candm = theap == tmin
            seqm = jnp.where(candm, tseq, _BIG_SEQ)
            sel = candm & (seqm == seqm.min(1, keepdims=True))
            self_f = sel.astype(jnp.float64)
            p = self_f @ proj
            sel_s = p[:, :S] > 0.5
            tc = p[:, CTC:CTC + 1]
            td = p[:, CTD:CTD + 1]
            nid = p[:, CID:CID + 1]
            nch = p[:, CNCH:CNCH + 1]
            chmask = p[:, CCH:CCH + N] > 0.5
            chrank = p[:, CRK:CRK + N]

            alive = jnp.isfinite(tmin)
            place = (sel_s & (cursor < glen)).any(1, keepdims=True) & alive
            d = jnp.where(sel_s, dnext, 0.0).sum(1, keepdims=True)
            hasn = (sel & has).any(1, keepdims=True)
            create = place & ~hasn
            # the serialized reconfiguration tail (creation on first task,
            # destruction on repartitioning a used node)
            re_c = jnp.maximum(re, tmin) + tc
            start = jnp.where(create, re_c, tmin)
            end = start + d
            repart = alive & ~place & (rem > 0)
            destroy = repart & hasn
            re_d = jnp.maximum(re, tmin) + td
            re = jnp.where(create, re_c, jnp.where(destroy, re_d, re))
            # heap: placement re-pushes the node at its chain end; a
            # repartition replaces it by its children; a retire drops it
            theap = jnp.where(sel, jnp.where(place, end, INF), theap)
            theap = jnp.where(repart & chmask, tmin, theap)
            tseq = jnp.where(sel & place, seqctr, tseq)
            tseq = jnp.where(
                repart & chmask, seqctr + chrank.astype(jnp.int32), tseq
            )
            seqctr = seqctr + jnp.where(
                place, 1, jnp.where(repart, nch.astype(jnp.int32), 0)
            )
            has = has | (sel & create)
            pos = jnp.where(sel, ccnt, 0).sum(1, keepdims=True)
            ccnt = ccnt + (sel & place).astype(jnp.int32)
            adv = sel_s & place
            cursor = cursor + adv.astype(jnp.int32)
            # one scalar lookup per candidate (vmapped dynamic_slice beats
            # a (C, S) take_along_axis on the CPU backend)
            flatidx = jnp.where(
                sel_s, sizebase + jnp.minimum(cursor, L - 1), 0
            ).sum(1)
            gd = jax.vmap(
                lambda row, i: jax.lax.dynamic_slice(row, (i,), (1,))[0]
            )(gflat, flatidx)
            dnext = jnp.where(adv, gd[:, None], dnext)
            rem = rem - place.astype(jnp.int32)
            pl = place[:, 0]
            rec = (
                jnp.where(pl, nid[:, 0], -1.0),
                jnp.where(pl, d[:, 0], 0.0),
                jnp.where(pl, pos[:, 0].astype(jnp.float64), 0.0),
            )
            return (theap, tseq, seqctr, cursor, dnext, re, has, rem,
                    ccnt), rec

        st = (
            jnp.broadcast_to(theap0, (C, N)),
            jnp.broadcast_to(tseq0, (C, N)),
            jnp.full((C, 1), seq0, jnp.int32),
            jnp.zeros((C, S), jnp.int32),
            gdurs[:, :, 0],
            jnp.zeros((C, 1)),
            jnp.zeros((C, N), bool),
            glen.sum(1, keepdims=True),
            jnp.zeros((C, N), jnp.int32),
        )
        return jax.lax.scan(body, st, None, length=T)[1]

    _PROGRAM_CACHE.put(sa.spec, run, (C, L))
    return run


def _pow2(x: int) -> int:
    return 1 << max(1, (x - 1).bit_length())


def _score_chains_batch(spec, chain_durs, chain_len):
    """Batched chain scoring backend: the fused Pallas kernel on
    accelerator backends (``repro.kernels.chains_makespan``), the numpy
    lockstep otherwise.  Both are pinned bit-identical per candidate to
    :func:`chains_makespan`, so the dispatch cannot change a winner."""
    try:
        from repro.kernels.chains_makespan import ops as _cm_ops
    except ImportError:  # pragma: no cover - kernels package stripped
        _cm_ops = None
    if _cm_ops is not None and _cm_ops.pallas_usable():
        return _cm_ops.chains_makespan_batch_pallas(
            spec, chain_durs, chain_len
        )
    return chains_makespan_batch(spec, chain_durs, chain_len)


@register_evaluator("vectorized")
class VectorizedEvaluator(FamilyEvaluator):
    """Chunked array-program scorer (module docstring has the design).

    Scores candidates in growing chunks through the jitted lockstep and
    the batched chain scorer; the shared :func:`_winner_scan` then walks
    the scores with the same prune/incumbent comparisons as the
    sequential path, so extra chunk-tail candidates cost time but never
    change the selection.  Only the winner's assignment is materialised
    (task ids resolved from the membership row + recorded pop sequence).
    """

    def evaluate(self, tasks, spec, first, deltas, config):
        if not HAVE_JAX:
            global _WARNED_NO_JAX
            if not _WARNED_NO_JAX:
                _WARNED_NO_JAX = True
                import warnings

                warnings.warn(
                    "evaluator='vectorized' requested but jax is not "
                    "importable; scoring sequentially (results are "
                    "identical, timings are not)",
                    RuntimeWarning,
                    stacklevel=2,
                )
            return EVALUATORS["sequential"].evaluate(
                tasks, spec, first, deltas, config
            )
        _, jnp, enable_x64 = _jax_modules()
        n = len(tasks)
        F = len(deltas) + 1
        sa = _spec_eval_arrays(spec)
        S, N = sa.n_sizes, sa.n_nodes
        orders = size_sorted_orders(tasks, spec)
        sizeidx = {s: k for k, s in enumerate(spec.sizes)}
        L = _pow2(n)

        # membership of each batch position in its per-size sorted order,
        # advanced chunk by chunk through the family deltas
        member = np.zeros((S, n), dtype=bool)
        rows = np.array([sizeidx[s] for s in first])
        member[rows, orders.inv[rows, np.arange(n)]] = True
        # delta column flips in sorted-position space: (size row, position)
        alloc = list(first)
        flips = []  # per delta: (row_old, pos_old, row_new, pos_new)
        for j, s_new in deltas:
            s_old = alloc[j]
            flips.append((
                sizeidx[s_old], orders.inv[sizeidx[s_old], j],
                sizeidx[s_new], orders.inv[sizeidx[s_new], j],
            ))
            alloc[j] = s_new

        # every chunk pays a full (n + N)-step scan regardless of its
        # width, so the schedule is: without pruning score the whole
        # family at once; with pruning one prune-window-sized chunk
        # first (the admissible prune usually stops within a few dozen
        # candidates), then geometrically growing remainders.  Only the
        # most recent chunk's pop records are retained — the scan keeps
        # the incumbent winner's single record column as its payload.
        first_chunk = min(F, MAX_CHUNK) if config.prune \
            else min(F, MAX_FAMILY_CHUNK)
        state = {"next": 0, "size": first_chunk, "scores": {},
                 "chunk": None}  # (i0, member at i0, pop node ids (T, C))

        def score_chunk(i0: int, count: int) -> None:
            # pad the candidate axis to a multiple of 32 (few compiled
            # variants, little waste — padded rows have no tasks and
            # retire in a handful of steps)
            Cb = max(8, -(-count // 32) * 32) if count > 8 else 8
            mem0 = member.copy()
            # duration tensor: candidate i0's rows by direct compress of
            # the base membership, then each next candidate as a copy of
            # the previous one with the one-task delta applied as two
            # shifted-row edits (delete at old LPT rank, insert at new)
            gdurs = np.zeros((Cb, S, L))
            glen = np.zeros((Cb, S), dtype=np.int32)
            for si in range(S):
                dsel = orders.durs[si][member[si]]
                gdurs[0, si, : len(dsel)] = dsel
                glen[0, si] = len(dsel)
            for k in range(1, count):
                ro, po, rn, pn = flips[i0 + k - 1]
                gdurs[k] = gdurs[k - 1]
                glen[k] = glen[k - 1]
                r_o = int(member[ro, :po].sum())
                lo = int(glen[k, ro])
                row = gdurs[k, ro]
                row[r_o:lo - 1] = row[r_o + 1:lo]
                row[lo - 1] = 0.0
                glen[k, ro] = lo - 1
                member[ro, po] = False
                r_n = int(member[rn, :pn].sum())
                ln = int(glen[k, rn])
                row = gdurs[k, rn]
                row[r_n + 1:ln + 1] = row[r_n:ln]
                row[r_n] = orders.durs[rn][pn]
                glen[k, rn] = ln + 1
                member[rn, pn] = True
            # advance the base membership past this chunk's last candidate
            if i0 + count - 1 < len(flips):
                ro, po, rn, pn = flips[i0 + count - 1]
                member[ro, po] = False
                member[rn, pn] = True
            # constants, tracing and execution must all sit inside the
            # x64 scope, or the program silently truncates to float32
            with enable_x64():
                run = _phase_a_program(sa, Cb, L)
                nid_j, dur_j, pos_j = run(jnp.asarray(gdurs), jnp.asarray(glen))
            t_used = n + N
            nid = np.asarray(nid_j)[:t_used].astype(np.int64)   # (T, Cb)
            dv = np.asarray(dur_j)[:t_used]
            cpos = np.asarray(pos_j)[:t_used].astype(np.int64)
            # per-node duration chains -> batched replay-semantics scoring
            # (the program already recorded each pop's chain position)
            valid = nid >= 0
            cols = np.broadcast_to(np.arange(Cb), nid.shape)[valid]
            nodes = nid[valid]
            grp = cols * N + nodes
            chain_len = np.bincount(grp, minlength=Cb * N).reshape(Cb, N)
            Lc = max(1, int(chain_len.max()))
            cd = np.zeros((Cb, N, Lc))
            cd[cols, nodes, cpos[valid]] = dv[valid]
            scores = _score_chains_batch(spec, cd, chain_len)
            for k in range(count):
                state["scores"][i0 + k] = float(scores[k])
            state["chunk"] = (i0, mem0, nid)

        def score(i):
            while i >= state["next"]:
                count = min(state["size"], F - state["next"])
                score_chunk(state["next"], count)
                state["next"] += count
                # geometric growth bounds over-scoring past the prune
                # break to ~the last chunk's width
                state["size"] = max(
                    1, min(state["size"] * 4, F - state["next"],
                           MAX_FAMILY_CHUNK)
                )
            i0, mem0, nid = state["chunk"]
            return state["scores"][i], (i0, mem0, nid[:, i - i0].copy())

        areas = family_areas(tasks, first, deltas) if config.prune else None
        best, evaluated = _winner_scan(
            score, areas, config.eps, spec.n_slices, F
        )
        makespan, win, payload = best
        assignment = self._winner_assignment(
            tasks, spec, sa, orders, payload, flips, win
        )
        winner_alloc = list(first)
        for j, s_new in deltas[:win]:
            winner_alloc[j] = s_new
        return FamilyWinner(
            makespan, win, assignment, tuple(winner_alloc), evaluated
        )

    @staticmethod
    def _winner_assignment(tasks, spec, sa, orders, payload, flips, win):
        """Task-id chains of the winning candidate, in the exact node
        creation order the sequential simulation produces.  ``payload``
        is the scan-retained ``(chunk start, membership at chunk start,
        winner's pop-record column)``."""
        i0, mem0, pops = payload
        member_w = mem0.copy()
        for k in range(i0, win):
            ro, po, rn, pn = flips[k]
            member_w[ro, po] = False
            member_w[rn, pn] = True
        seqn = pops[pops >= 0]                 # node index per placement
        sidx = sa.node_sizeidx[seqn]
        pos = np.empty(len(seqn), dtype=np.int64)
        ids_w = {}
        for si in range(sa.n_sizes):
            m = sidx == si
            pos[m] = np.arange(m.sum())
            ids_w[si] = orders.ids[si][member_w[si]]
        node_tasks: dict = {}
        first_step = {}
        for nn in np.unique(seqn):
            first_step[nn] = int(np.argmax(seqn == nn))
        for nn in sorted(first_step, key=first_step.get):
            m = seqn == nn
            si = int(sa.node_sizeidx[nn])
            node_tasks[sa.node_keys[nn]] = ids_w[si][pos[m]].tolist()
        tasks_by_id = {t.id: t for t in tasks}
        return Assignment(spec, tasks_by_id, node_tasks)


__all__ = [
    "AUTO_MIN_FAMILY",
    "AUTO_MIN_TASKS",
    "AUTO_MIN_TASKS_INCREMENTAL",
    "AUTO_MIN_TASKS_UNPRUNED",
    "EVALUATORS",
    "FamilyEvaluator",
    "FamilyWinner",
    "HAVE_JAX",
    "IncrementalEvaluator",
    "ParallelEvaluator",
    "SequentialEvaluator",
    "VectorizedEvaluator",
    "family_areas",
    "get_evaluator",
    "register_evaluator",
    "resolve_evaluator",
]
