"""FAR Phase 1: the Turek-style family of allocations (paper §3.1).

First allocation: each task gets the minimum slice count minimising its
*work* ``s * t_i(s)``.  Each successive allocation widens the currently
longest task to its next work-minimising larger size; when the longest task
cannot grow, the family ends.  Family size is O(|C_G| * n).

Only monotony point 1 (time non-increasing in slices) is assumed — the
method is explicitly safe for the non-monotone-work profiles MIG exhibits
(paper §2.4).
"""

from __future__ import annotations

from typing import Sequence

from repro.core.device_spec import DeviceSpec
from repro.core.problem import Task

Allocation = tuple[int, ...]  # size per task, indexed like the batch


def first_allocation(tasks: Sequence[Task], spec: DeviceSpec) -> Allocation:
    sizes = spec.sizes
    return tuple(t.min_work_size(sizes) for t in tasks)


def _next_size(task: Task, current: int, sizes: Sequence[int]) -> int | None:
    """argmin_{s>current} s*t(s), or None when current is already max."""
    bigger = [s for s in sizes if s > current]
    if not bigger:
        return None
    return min(bigger, key=lambda s: (s * task.times[s], s))


def allocation_family(
    tasks: Sequence[Task], spec: DeviceSpec
) -> list[Allocation]:
    """Generate the whole family (paper §3.1 recurrence)."""
    if not tasks:
        return [()]
    sizes = spec.sizes
    alloc = list(first_allocation(tasks, spec))
    family = [tuple(alloc)]
    while True:
        # the longest task under the current allocation
        j = max(range(len(tasks)), key=lambda i: tasks[i].times[alloc[i]])
        nxt = _next_size(tasks[j], alloc[j], sizes)
        if nxt is None:
            return family
        alloc[j] = nxt
        family.append(tuple(alloc))
