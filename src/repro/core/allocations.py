"""FAR Phase 1: the Turek-style family of allocations (paper §3.1).

First allocation: each task gets the minimum slice count minimising its
*work* ``s * t_i(s)``.  Each successive allocation widens the currently
longest task to its next work-minimising larger size; when the longest task
cannot grow, the family ends.  Family size is O(|C_G| * n).

Only monotony point 1 (time non-increasing in slices) is assumed — the
method is explicitly safe for the non-monotone-work profiles MIG exhibits
(paper §2.4).
"""

from __future__ import annotations

import heapq
from typing import Sequence

from repro.core.device_spec import DeviceSpec
from repro.core.problem import Task, min_work_size

Allocation = tuple[int, ...]  # size per task, indexed like the batch


def first_allocation(tasks: Sequence[Task], spec: DeviceSpec) -> Allocation:
    sizes = spec.sizes
    return tuple(min_work_size(t.times, sizes) for t in tasks)


def allocation_family_deltas(
    tasks: Sequence[Task], spec: DeviceSpec
) -> tuple[Allocation, list[tuple[int, int]]]:
    """The family as ``(first_allocation, [(task_index, new_size), ...])``.

    Consecutive family members differ in exactly one task's size, so the
    delta form is the natural one for warm-started phase-2 evaluation —
    and it avoids materialising O(family · n) allocation tuples.

    The longest task is tracked with a lazy max-heap instead of an O(n)
    scan per step: only the widened task's duration changes, and durations
    are non-increasing along the family (monotony point 1), so stale heap
    entries are safely discarded on pop.  Entries are ``(-duration, id)``,
    matching ``max``'s first-of-the-maxima tie-break exactly.
    """
    if not tasks:
        return (), []
    sizes = spec.sizes
    first = first_allocation(tasks, spec)
    alloc = list(first)
    deltas: list[tuple[int, int]] = []
    heap = [(-tasks[i].times[alloc[i]], i) for i in range(len(tasks))]
    heapq.heapify(heap)
    # the strictly-larger size options per current size, precomputed once
    # and sorted ascending so the first-wins tie-break below picks the
    # fewest slices even if a custom spec lists sizes out of order
    bigger = {s: tuple(sorted(b for b in sizes if b > s)) for s in sizes}
    while True:
        # the longest task under the current allocation
        while True:
            d, j = heap[0]
            if -d == tasks[j].times[alloc[j]]:
                break
            heapq.heappop(heap)  # stale: task j has since been widened
        options = bigger[alloc[j]]
        if not options:
            return first, deltas
        times = tasks[j].times
        nxt = options[0]
        best_w = nxt * times[nxt]
        for s in options[1:]:
            w = s * times[s]
            if w < best_w:  # ties toward fewer slices: options ascend
                best_w, nxt = w, s
        alloc[j] = nxt
        heapq.heappush(heap, (-times[nxt], j))
        deltas.append((j, nxt))


def allocation_family(
    tasks: Sequence[Task], spec: DeviceSpec
) -> list[Allocation]:
    """Generate the whole family (paper §3.1 recurrence) as full tuples."""
    if not tasks:
        return [()]
    first, deltas = allocation_family_deltas(tasks, spec)
    alloc = list(first)
    family = [first]
    for j, size in deltas:
        alloc[j] = size
        family.append(tuple(alloc))
    return family
