"""Multi-batch concatenation of FAR schedules (paper §4).

Batches of tasks arrive over time; each is scheduled offline by FAR and its
schedule is spliced after the live tail of the previous one:

* **trivial** — the next batch starts after the previous batch's last task
  (the paper's reference point);
* **reversed** — every other batch is played leaves-first (paper §4.2), so
  the small trailing instances of one batch meet the small leading instances
  of the next; the feasible overlap is found per slice, and instances that
  coincide across the seam skip their destroy+create pair;
* **reversed + move/swap** — additionally runs the phase-3 move/swap engine
  against the combined makespan (paper §4.3: the inter-batch idle gap plays
  the role of the refinement margin).

State carried across the seam: per-slice release times, the set of alive
instances (with busy-until times) and the reconfiguration-sequence release.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.device_spec import DeviceSpec
from repro.core.policy import (
    BasePolicy,
    PlanResult,
    SchedulerConfig,
    get_policy,
    register_policy,
)
from repro.core.problem import Schedule, ScheduledTask, Task, area_lower_bound
from repro.core.refine import ChainViews, _best_move, _best_swap
from repro.core.repartition import (
    Assignment,
    NodeKey,
    alive_at_end,
    is_reconfig_key,
    replay,
)
from repro.core.timing import make_engine


@dataclasses.dataclass
class Tail:
    """Live state at the end of the already-committed schedule."""

    # (tree, slice) -> time, plus the reconfiguration-sequence releases:
    # "reconfig" (floor on every driver) and per-driver ("reconfig", tree)
    release: dict
    alive: dict[NodeKey, float]

    @classmethod
    def empty(cls, spec: DeviceSpec) -> "Tail":
        rel = {(r.tree, s): 0.0 for r in spec.roots for s in r.blocked}
        rel["reconfig"] = 0.0
        return cls(release=rel, alive={})

    def floored(self, t: float) -> "Tail":
        """Tail with every release time (slices and the reconfiguration
        sequence) floored at ``t`` — the serving causality rule: work
        committed by a decision at time ``t`` may not be scheduled before
        it."""
        return Tail(
            release={k: max(float(v), t) for k, v in self.release.items()},
            alive=self.alive,
        )


def tail_after(schedule: Schedule, prev: Tail) -> Tail:
    release = dict(prev.release)
    for cell, t in schedule.slice_end_times().items():
        release[cell] = max(release.get(cell, 0.0), t)
    # destroys also occupy their instance's slices
    for rc in schedule.reconfigs:
        for s in rc.node.blocked:
            cell = (rc.node.tree, s)
            release[cell] = max(release.get(cell, 0.0), rc.end)
    # reconfiguration-sequence releases: the driver serialises per tree,
    # so EVERY tree gets its own ("reconfig", tree) release — trees idle
    # this segment carry their previous value forward (seeded from the
    # legacy global key for pre-existing tails), otherwise a keyless tree
    # would fall back to the global maximum and re-couple the drivers at
    # the seam.  The plain "reconfig" key stays the global max for
    # back-compat readers and for reconfig_scope="global" specs.
    base = float(prev.release.get("reconfig", 0.0))
    for r in schedule.spec.roots:
        k = ("reconfig", r.tree)
        release.setdefault(k, base)
    for rc in schedule.reconfigs:
        k = ("reconfig", rc.node.tree)
        release[k] = max(release[k], rc.end)
    release["reconfig"] = max(
        base,
        max((rc.end for rc in schedule.reconfigs), default=0.0),
    )
    alive = dict(prev.alive)
    # instances destroyed by this segment disappear …
    for rc in schedule.reconfigs:
        if rc.kind == "destroy":
            alive.pop(rc.node.key, None)
    # … and this segment's own survivors join (alive_at_end sees creates)
    seg_alive = alive_at_end(schedule)
    for key, t in seg_alive.items():
        alive[key] = max(alive.get(key, 0.0), t)
    # reused-without-recreation instances keep living: bump busy-until
    by_node = schedule.by_node()
    for key, lst in by_node.items():
        if key in alive:
            alive[key] = max(alive[key], max(it.end for it in lst))
    return Tail(release=release, alive=alive)


@dataclasses.dataclass
class ConcatResult:
    schedule: Schedule       # absolute-timed segment for this batch
    tail: Tail
    reversed_: bool
    moves: int = 0
    swaps: int = 0


def concatenate(
    assignment: Assignment,
    tail: Tail,
    mode: str = "move_swap",
    reverse: bool = True,
    use_engine: bool = True,
) -> ConcatResult:
    """Splice one batch's assignment after ``tail``.

    Args:
      assignment: the FAR output tree for the new batch.
      tail: live state of the committed schedule.
      mode: "trivial" | "reverse" | "move_swap".
      reverse: whether this batch is the reversed one (alternates between
        consecutive batches; ignored for mode="trivial").
      use_engine: score seam edits with the incremental timing engine
        (default) or with full replays — identical results.
    """
    if mode == "trivial":
        slice_rel = [
            v for k, v in tail.release.items() if not is_reconfig_key(k)
        ]
        barrier = max(slice_rel) if slice_rel else 0.0
        release = tail.floored(barrier).release
        sched = replay(assignment, release=release, alive=tail.alive)
        return ConcatResult(sched, tail_after(sched, tail), False)

    if mode == "auto":
        # beyond-paper: with short tasks, reversal's extra reconfigurations
        # can outweigh its overlap — evaluate every seam strategy and keep
        # the best (never worse than trivial, by construction)
        candidates = [
            concatenate(assignment, tail, mode="trivial"),
            concatenate(assignment, tail, mode="move_swap", reverse=False,
                        use_engine=use_engine),
            concatenate(assignment, tail, mode="move_swap", reverse=True,
                        use_engine=use_engine),
        ]
        return min(candidates, key=lambda c: (
            c.schedule.makespan,
            sum(v for k, v in c.tail.release.items()
                if not is_reconfig_key(k)),
        ))

    direction = "reverse" if reverse else "forward"
    moves = swaps = 0
    if mode == "move_swap":
        assignment, sched, moves, swaps = seam_refine(
            assignment, tail, direction, use_engine=use_engine
        )
    elif mode == "reverse":
        sched = replay(
            assignment, release=tail.release, alive=tail.alive,
            direction=direction,
        )
    else:
        raise ValueError(f"unknown mode {mode!r}")
    return ConcatResult(sched, tail_after(sched, tail), reverse, moves, swaps)


def seam_refine(
    assignment: Assignment,
    tail: Tail,
    direction: str,
    max_edits: int = 32,
    use_engine: bool = True,
) -> tuple[Assignment, Schedule, int, int]:
    """Paper §4.3: move/swap tasks of the incoming batch so they fill the
    idle gaps its slices have against the previous batch's release times.

    Candidates follow the phase-3 heuristics — the transferred duration
    should be closest to half the target instance's seam gap — but every
    edit is evaluated exactly (makespan, then total task-begin mass as
    compaction tie-break) and only kept when it improves.  Candidate edits
    are scored speculatively through the incremental timing engine
    (apply → read → undo); ``use_engine=False`` scores each with a full
    :func:`replay` instead, with identical results.
    """
    kwargs = dict(release=tail.release, alive=tail.alive, direction=direction)
    eng = make_engine(assignment, use_engine=use_engine, **kwargs)
    work = eng.assignment  # live view of the engine's chains
    views = ChainViews(eng)

    def score_now() -> tuple[float, float]:
        return (eng.makespan(), eng.begin_mass())

    best_score = score_now()
    moves = swaps = 0
    spec = assignment.spec

    for _ in range(max_edits):
        # per-instance chain ends: the seam margin between two same-size
        # instances is their imbalance end(I) - end(Iᵃ) (the idle the later
        # chain forces against the earlier one, paper §4.3)
        node_end: dict[NodeKey, float] = dict(eng.node_end_times())
        # same-size instances never used by this batch are still valid
        # move targets: their chains end at their slice release times
        def slice_release(node) -> float:
            return max(
                float(tail.release.get(cell, 0.0))
                for cell in node.blocked_cells
            )
        used_sizes = {k[2] for k in node_end}
        for node in spec.nodes:
            if node.size in used_sizes and node.key not in node_end:
                node_end[node.key] = slice_release(node)
        active = sorted(node_end, key=lambda k: node_end[k])
        candidate_edits: list[tuple[str, NodeKey, NodeKey, object]] = []
        for ki in active:
            if not work.node_tasks.get(ki):
                continue
            for ka in active:
                if ki == ka or ki[2] != ka[2]:
                    continue
                margin = node_end[ki] - node_end[ka]
                if margin <= 0:
                    continue
                tid = _best_move(views, ki, margin)
                if tid is not None:
                    candidate_edits.append(("move", ki, ka, tid))
                pair = _best_swap(views, ki, ka, margin)
                if pair is not None:
                    candidate_edits.append(("swap", ki, ka, pair))
        best_edit = None
        for kind, ki, ka, payload in candidate_edits:
            if kind == "move":
                eng.apply_move(payload, dst=ka, src=ki)
            else:
                tk, tj = payload
                eng.apply_swap(tk, tj)
            score = score_now()
            eng.undo()
            if score < best_score:
                best_score, best_edit = score, (kind, ki, ka, payload)
        if best_edit is None:
            break
        kind, ki, ka, payload = best_edit
        if kind == "move":
            eng.apply_move(payload, dst=ka, src=ki)
            moves += 1
        else:
            eng.apply_swap(*payload)
            swaps += 1
    return eng.export_assignment(), eng.schedule(), moves, swaps


def edf_order(assignment: Assignment,
              deadlines: dict[int, float]) -> Assignment:
    """Reorder each node chain earliest-deadline-first (stable: ties and
    deadline-free tasks keep their plan order, after the deadline
    carriers).

    Tasks on one chain run back-to-back on the same instance, so any
    permutation of a chain leaves every chain's *end* — and therefore
    the batch makespan, the seam tail and feasibility — exactly as the
    makespan-only policy planned them.  Only the per-task completion
    order inside the chain changes, which is the whole point: a task
    with an SLO finishes before the best-effort work sharing its
    instance.  Chains without any deadline carrier are returned as the
    same list object, so a deadline-free batch commits bit-identically.
    """
    changed = False
    node_tasks: dict[NodeKey, list[int]] = {}
    for key, tids in assignment.node_tasks.items():
        if len(tids) > 1 and any(t in deadlines for t in tids):
            order = sorted(
                range(len(tids)),
                key=lambda i: (deadlines.get(tids[i], float("inf")), i),
            )
            reordered = [tids[i] for i in order]
            if reordered != tids:
                changed = True
            node_tasks[key] = reordered
        else:
            node_tasks[key] = tids
    if not changed:
        return assignment
    return Assignment(assignment.spec, assignment.tasks, node_tasks)


class MultiBatchScheduler:
    """Online driver: one plan per batch + intelligent concatenation (§4).

    Each batch is planned cold by the registered ``policy`` (FAR by
    default, but any name from :func:`~repro.core.policy.get_policy`
    works — the plan only needs to carry an assignment) and its tree is
    spliced after the committed tail.  Alternates schedule direction
    between consecutive batches so seams pair similar instance sizes, and
    applies seam move/swap by default.

    ``config`` is authoritative when given; the legacy ``mode`` /
    ``refine`` / ``use_engine`` parameters are only consulted to build a
    default config when it is not.
    """

    def __init__(
        self,
        spec: DeviceSpec,
        mode: str = "move_swap",
        refine: bool = True,
        use_engine: bool = True,
        policy: str = "far",
        config: SchedulerConfig | None = None,
    ) -> None:
        self.spec = spec
        if config is None:
            config = SchedulerConfig(
                refine=refine, use_engine=use_engine, concat_mode=mode
            )
        self.config = config
        self.mode = config.concat_mode
        self.policy = policy
        self.tail = Tail.empty(spec)
        self.segments: list[Schedule] = []
        self.results: list[PlanResult] = []
        self._flip = False
        # persistent floor on the rebuilt tail: a device-loss recovery
        # resets the physical partition at some time t, which the
        # committed segments cannot encode — rebuild_tail() must keep
        # honouring it after later withdrawals/corrections
        self.reset_at = 0.0

    def add_batch(
        self, tasks: Sequence[Task], not_before: float = 0.0,
        deadlines: dict[int, float] | None = None,
    ) -> ConcatResult:
        """Plan ``tasks`` cold and splice them after the tail.

        ``not_before`` floors every release time (slices and the
        reconfiguration sequence) — the serving facade passes its flush
        time so nothing is scheduled before the decision that placed it.
        ``deadlines`` (task id -> absolute SLO) triggers the EDF
        within-batch reorder before the splice; see :func:`edf_order`.
        """
        return self.commit_plan(
            self.plan_batch(tasks), not_before, deadlines=deadlines
        )

    def plan_batch(self, tasks: Sequence[Task]) -> PlanResult:
        """Stage 1 of a flush: plan ``tasks`` cold under the registered
        policy.  Tail-independent by construction (the §4 seam only
        enters at commit), so several batches can be planned while
        earlier commits are still outstanding — the pipelining seam the
        sharded service and the cluster driver exploit."""
        return get_policy(self.policy).plan(tasks, self.spec, self.config)

    def commit_plan(
        self, plan: PlanResult, not_before: float = 0.0,
        deadlines: dict[int, float] | None = None,
    ) -> ConcatResult:
        """Stage 2 of a flush: splice a cold plan after the committed
        tail.  ``add_batch`` is exactly ``commit_plan(plan_batch(...))``,
        so pipelined and monolithic flushes commit bit-identically."""
        if plan.assignment is None:
            raise ValueError(
                f"policy {plan.policy!r} produced no assignment; "
                "tail-aware planning is unsupported"
            )
        self.results.append(plan)
        assignment = plan.assignment
        if deadlines:
            assignment = edf_order(assignment, deadlines)
        tail = self.tail.floored(not_before) if not_before > 0.0 else self.tail
        out = concatenate(
            assignment, tail, mode=self.mode, reverse=self._flip,
            use_engine=self.config.use_engine,
        )
        if self.mode != "trivial":
            self._flip = not self._flip
        self.tail = out.tail
        self.segments.append(out.schedule)
        return out

    def adopt_segment(self, schedule: Schedule) -> None:
        """Splice an externally-planned absolute-timed segment (e.g. the
        serving facade's online-fallback placements) after the tail: the
        segment joins the combined schedule and the tail advances exactly
        as for a planned batch."""
        self.tail = tail_after(schedule, self.tail)
        self.segments.append(schedule)

    def online_place(
        self,
        batch: Sequence[tuple[Task, float, object]],
        decided_at: float,
    ) -> Schedule:
        """Greedy per-arrival placement after the committed tail (the
        serving facade's trickle/urgent fallback).  The release context is
        floored at the decision time so every placement begins no earlier
        than the decision that made it — the combined timeline stays
        causal.  The cluster driver implements the same method with a
        per-device device-choice step, so the facade calls one surface."""
        from repro.core.online import OnlineScheduler

        floored = self.tail.floored(decided_at)
        online = OnlineScheduler(
            self.spec, release=floored.release, alive=floored.alive,
        )
        for task, arrival, _ in batch:
            online.submit(task, arrival=arrival)
        sched = online.schedule()
        self.adopt_segment(sched)
        return sched

    def clone(self) -> "MultiBatchScheduler":
        """Independent copy of the committed state (segments are lists of
        immutable items, so a shallow per-segment copy suffices).  The
        serving facade trial-evaluates a re-planned flush against the
        plain one on two clones before committing either."""
        new = MultiBatchScheduler(
            self.spec, policy=self.policy, config=self.config
        )
        new.mode = self.mode
        new.tail = Tail(dict(self.tail.release), dict(self.tail.alive))
        new.segments = [
            Schedule(spec=s.spec, items=list(s.items),
                     reconfigs=list(s.reconfigs))
            for s in self.segments
        ]
        new.results = list(self.results)
        new._flip = self._flip
        new.reset_at = self.reset_at
        return new

    def withdraw_uncommitted(self, t: float, eps: float = 1e-9) -> list[Task]:
        """Pull every placement that has not started by time ``t`` back out
        of the committed segments and rebuild the tail from what remains.

        This is the §4-seam analogue of the reconfigurable-machine serving
        model (Tan et al., arXiv:2109.11067): a placement is *committed*
        only once it starts.  Items with ``begin <= t`` keep their exact
        absolute times (running tasks are never moved — the no-preemption
        model); items with ``begin > t`` are withdrawn for re-planning.
        Reconfigurations that have begun by ``t`` are irreversible and
        stay; later ones only served withdrawn work (a creation precedes
        every task of its chain, so a future creation's tasks are all
        withdrawn) and are dropped — their instances simply stay alive in
        the rebuilt tail until the re-plan decides otherwise.

        Returns the withdrawn tasks ordered by their old begin times
        (deterministic: ties break on task id).
        """
        withdrawn: list = []
        kept_segments: list[Schedule] = []
        for seg in self.segments:
            keep = [it for it in seg.items if it.begin <= t + eps]
            gone = [it for it in seg.items if it.begin > t + eps]
            withdrawn.extend(gone)
            rcs = [rc for rc in seg.reconfigs if rc.begin <= t + eps]
            if keep or rcs:
                kept_segments.append(
                    Schedule(spec=seg.spec, items=keep, reconfigs=rcs)
                )
        self.segments = kept_segments
        self.rebuild_tail()
        withdrawn.sort(key=lambda it: (it.begin, it.task.id))
        return [it.task for it in withdrawn]

    # -- runtime corrections (closed-loop serving) --------------------------
    def find_item(self, task_id: int) -> ScheduledTask | None:
        """The live committed placement of ``task_id`` — the one
        non-``failed`` item carrying it (failed attempts stay behind as
        occupancy records, so they are skipped).  None when the task has
        no live placement (never committed, or withdrawn)."""
        for seg in reversed(self.segments):
            for it in seg.items:
                if it.task.id == task_id and not it.failed:
                    return it
        return None

    def replace_item(
        self,
        task_id: int,
        end_override: float | None,
        failed: bool = False,
    ) -> ScheduledTask:
        """Correct the live placement of ``task_id`` with runtime truth
        (an actual completion, a straggler projection, or a failure
        instant) and rebuild the tail from the corrected segments.
        Returns the corrected item.  The §4 seam analogue of the timing
        engine's logged ``apply_stretch``: segments are immutable-item
        lists, so the correction is a replace, and every downstream
        release/alive time is re-derived rather than patched."""
        for seg in reversed(self.segments):
            for i, it in enumerate(seg.items):
                if it.task.id == task_id and not it.failed:
                    new = dataclasses.replace(
                        it, end_override=end_override, failed=failed
                    )
                    seg.items[i] = new
                    self.rebuild_tail()
                    return new
        raise KeyError(f"task {task_id} has no live committed placement")

    def relabel_item(
        self,
        task_id: int,
        task: Task,
        end_override: float | None = None,
        failed: bool = False,
    ) -> ScheduledTask:
        """Rewrite the live placement of ``task_id`` to carry ``task``
        (keeping node/begin/size) — the speculation-resolution primitive:
        when a backup attempt wins its race, its committed record is
        re-keyed to the logical task id it raced for, so the combined
        schedule keeps exactly one live record per logical task."""
        for seg in reversed(self.segments):
            for i, it in enumerate(seg.items):
                if it.task.id == task_id and not it.failed:
                    new = dataclasses.replace(
                        it, task=task,
                        end_override=(end_override if end_override is not None
                                      else it.end_override),
                        failed=failed,
                    )
                    seg.items[i] = new
                    self.rebuild_tail()
                    return new
        raise KeyError(f"task {task_id} has no live committed placement")

    def remove_items(self, task_ids: set[int]) -> list[Task]:
        """Drop the live placements of ``task_ids`` from the committed
        segments (failed occupancy records stay) and rebuild the tail.
        Returns the removed tasks ordered by old begin (ties by id) —
        the surgical sibling of :meth:`withdraw_uncommitted` for
        placements invalidated by a runtime correction rather than by a
        flush-time withdrawal."""
        removed: list[ScheduledTask] = []
        kept_segments: list[Schedule] = []
        for seg in self.segments:
            keep = [
                it for it in seg.items
                if it.failed or it.task.id not in task_ids
            ]
            removed.extend(
                it for it in seg.items
                if not it.failed and it.task.id in task_ids
            )
            if keep or seg.reconfigs:
                kept_segments.append(Schedule(
                    spec=seg.spec, items=keep, reconfigs=seg.reconfigs
                ))
        self.segments = kept_segments
        self.rebuild_tail()
        removed.sort(key=lambda it: (it.begin, it.task.id))
        return [it.task for it in removed]

    def rebuild_tail(self) -> None:
        """Re-derive the seam tail from the committed segments (after a
        correction changed an item's end, or a removal dropped one).
        ``reset_at`` (a device-loss recovery) stays applied: releases are
        floored there, and instances whose busy-until predates the reset
        stay dead — the outage destroyed the physical partition.

        An instance survives the reset only if its latest *creation
        began* at or after ``reset_at``: a creation window still in
        progress when the device was lost was aborted by the outage, yet
        its busy-until extends past the reset, so testing busy-until
        alone would leave it alive and let the very next flush place
        work — starting as early as the recovery instant itself — on an
        instance that was never re-created.  The boundary is inclusive:
        ``begin == reset_at`` is legitimate post-recovery work."""
        tail = Tail.empty(self.spec)
        for seg in self.segments:
            tail = tail_after(seg, tail)
        if self.reset_at > 0.0:
            created_at: dict = {}
            for seg in self.segments:
                for rc in seg.reconfigs:
                    if rc.kind == "create":
                        prev = created_at.get(rc.node.key)
                        if prev is None or rc.begin > prev:
                            created_at[rc.node.key] = rc.begin
            alive: dict = {}
            for k, v in tail.alive.items():
                if v <= self.reset_at + 1e-12:
                    continue  # busy-until predates the reset: died with it
                born = created_at.get(k)
                if born is None or born < self.reset_at - 1e-12:
                    continue  # creation began before the reset: aborted
                alive[k] = v
            tail = Tail(
                release={k: max(float(v), self.reset_at)
                         for k, v in tail.release.items()},
                alive=alive,
            )
        self.tail = tail

    @property
    def makespan(self) -> float:
        return max((seg.makespan for seg in self.segments), default=0.0)

    def last_flush_items(self) -> list[ScheduledTask]:
        """Absolute-timed placements of the most recent flush only (the
        serving facade reads the just-flushed batch's completions from
        here instead of rebuilding the whole combined schedule)."""
        return list(self.segments[-1].items) if self.segments else []

    def combined_schedule(self) -> Schedule:
        """All segments merged into one absolute-timed Schedule."""
        items = [it for seg in self.segments for it in seg.items]
        reconfigs = [rc for seg in self.segments for rc in seg.reconfigs]
        return Schedule(spec=self.spec, items=items, reconfigs=reconfigs)


@register_policy("lower-bound")
class LowerBoundPolicy(BasePolicy):
    """Paper §6.4/§6.7.2 area bound as a (schedule-less) registry policy:
    total minimum work spread evenly over the slices.  ``makespan`` is the
    bound; the schedule is empty and the plan carries no assignment, so
    this policy only serves as the denominator in comparisons."""

    def plan(
        self,
        tasks: Sequence[Task],
        spec: DeviceSpec,
        config: SchedulerConfig | None = None,
        tail: object | None = None,
    ) -> PlanResult:
        return PlanResult(
            policy=self.name,
            schedule=Schedule(spec=spec, items=[], reconfigs=[]),
            makespan=area_lower_bound(tasks, spec),
            tail=tail,
        )


def multibatch_baseline(
    batches: Sequence[Sequence[Task]], spec: DeviceSpec
) -> float:
    """Paper §6.7.2 lower bound over a batch chain (delegates to the
    registered ``"lower-bound"`` policy on the flattened task list)."""
    flat = [t for batch in batches for t in batch]
    return get_policy("lower-bound").plan(flat, spec).makespan
