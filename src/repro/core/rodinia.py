"""Rodinia-inspired task-time fixture (paper §6, Fig. 2/3, Table 3).

The paper profiles 16 Rodinia kernels on an A100 and reports their MIG
speedup curves graphically (Fig. 3) without a numeric table.  This module
encodes profiles *digitised from the described behaviour*: BFS /
StreamCluster-style memory-bound kernels super-scale up to 7 slices but
barely improve 3→4 (same bandwidth), Gaussian saturates beyond 3 slices
(Fig. 2), LavaMD-style compute kernels scale near-linearly, and a tail of
kernels hardly scales at all.  They are an approximation, clearly marked as
such — the benchmarks that use them report our own numbers next to the
paper's (ρ = 1.22 on the real profiles).

``speedup[s]`` is t(1)/t(s); absolute 1-slice times span 0.3–20 s as in
Fig. 2.
"""

from __future__ import annotations

from repro.core.device_spec import DeviceSpec
from repro.core.problem import Task

# name -> (t(1) seconds, {size: speedup})
_PROFILES: dict[str, tuple[float, dict[int, float]]] = {
    # memory-bound super-scalers (isolated bandwidth per slice); long on one
    # slice, dramatically shorter wide — these are what fixed partitions and
    # FIFO-partition schedulers handle worst (paper Fig. 12)
    # (the A100 bandwidth steps make speedup jump at 2->3 and 4->7: sizes 3
    # and 4 share the same memory bandwidth — paper §2.4 on BFS/StreamCluster)
    "BFS":            (22.0, {2: 1.9, 3: 4.2, 4: 4.4, 7: 8.6}),
    "StreamCluster":  (34.0, {2: 1.8, 3: 4.0, 4: 4.2, 7: 8.2}),
    "Kmeans":         (26.0, {2: 1.8, 3: 3.6, 4: 3.9, 7: 7.4}),
    "NW":             (14.0, {2: 1.7, 3: 3.3, 4: 3.6, 7: 6.4}),
    # saturating (Fig. 2: Gaussian stops scaling beyond 3 slices)
    "Gaussian":       (20.0, {2: 1.8, 3: 2.4, 4: 2.45, 7: 2.5}),
    "SradV1":         (5.5,  {2: 1.8, 3: 2.3, 4: 2.6, 7: 2.9}),
    # compute-bound, near-linear
    "LavaMD":         (15.0, {2: 1.95, 3: 2.9, 4: 3.8, 7: 6.4}),
    "HeartWall":      (9.0,  {2: 1.9, 3: 2.8, 4: 3.7, 7: 6.1}),
    "LUD":            (18.0, {2: 1.85, 3: 2.7, 4: 3.6, 7: 5.8}),
    "HotSpot3D":      (7.0,  {2: 1.8, 3: 2.6, 4: 3.4, 7: 5.2}),
    # moderate scalers
    "Backprop":       (3.0,  {2: 1.7, 3: 2.3, 4: 2.8, 7: 3.8}),
    "HotSpot":        (2.4,  {2: 1.7, 3: 2.2, 4: 2.7, 7: 3.6}),
    "ParticleFilter": (10.5, {2: 1.6, 3: 2.1, 4: 2.5, 7: 3.3}),
    # poor scalers (hardly improve past one slice)
    "NN":             (0.9,  {2: 1.3, 3: 1.45, 4: 1.55, 7: 1.7}),
    "Huffman":        (1.6,  {2: 1.25, 3: 1.4, 4: 1.5, 7: 1.6}),
    "PathFinder":     (2.0,  {2: 1.35, 3: 1.5, 4: 1.6, 7: 1.75}),
}

# the 9-kernel A30 batch of paper Table 3
TABLE3_KERNELS = (
    "PathFinder", "LavaMD", "HotSpot", "Gaussian", "NW",
    "Huffman", "HeartWall", "ParticleFilter", "LUD",
)


def rodinia_tasks(
    spec: DeviceSpec, names: tuple[str, ...] | None = None
) -> list[Task]:
    """Tasks with the fixture profiles restricted to ``spec.sizes``.

    Default order is alphabetical — a neutral "submission order" for the
    FIFO baselines (the paper does not publish theirs).
    """
    names = names or tuple(sorted(_PROFILES))
    tasks = []
    for i, name in enumerate(names):
        t1, sp = _PROFILES[name]
        times = {1: t1}
        for s in spec.sizes:
            if s == 1:
                continue
            if s in sp:
                times[s] = t1 / sp[s]
            else:
                # size not profiled (e.g. A30 lacks 3): interpolate on the
                # nearest profiled sizes, keeping monotone times
                below = max(x for x in sp if x < s)
                times[s] = t1 / sp[below]
        tasks.append(Task(id=i, times=times, name=name))
    return tasks
