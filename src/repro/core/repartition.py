"""FAR Phase 2: rigid scheduling of one allocation by instance
repartitioning (paper §3.2, Algorithm 1).

LPT-ordered list scheduling on the device's repartitioning tree: the next
instance to host a task is the first to be released (min-heap on end time),
an instance with no remaining same-size tasks is repartitioned into its
children, and all creations/destructions are charged sequentially through a
global ``reconfig_end`` (the NVIDIA driver serialises them, paper §2.1).

Two artefacts are produced:

* an :class:`Assignment` — the repartitioning tree with an ordered task list
  per node (the paper's "output tree");
* a :class:`~repro.core.problem.Schedule` — begin times + reconfiguration
  windows, extracted from the assignment by :func:`replay` (the paper's
  "BFS traversal of the output tree"), which charges a destruction only
  when a descendant actually hosts tasks.

``replay`` is the single timing authority: phase 3 (refinement) and the
multi-batch concatenation edit the assignment and re-derive times with it.
"""

from __future__ import annotations

import bisect
import dataclasses
import heapq
from typing import Sequence

from repro.core.allocations import Allocation
from repro.core.device_spec import DeviceSpec, InstanceNode
from repro.core.problem import ReconfigEvent, Schedule, ScheduledTask, Task

NodeKey = tuple[int, int, int, int]


def is_reconfig_key(key) -> bool:
    """Whether a ``release`` mapping key names a reconfiguration-sequence
    release ( ``"reconfig"`` or per-driver ``("reconfig", tree)`` ) rather
    than a ``(tree, slice)`` cell."""
    return key == "reconfig" or (
        isinstance(key, tuple) and len(key) == 2 and key[0] == "reconfig"
    )


def reconfig_sequence_starts(spec: DeviceSpec, release: dict) -> dict:
    """Initial per-driver reconfiguration-sequence end times.

    One sequence per tree when ``spec.reconfig_scope == "tree"`` (keys are
    the forest's tree indices), a single ``None``-keyed sequence for
    ``"global"`` scope.  A driver's sequence starts at its own
    ``("reconfig", tree)`` release when present; the plain ``"reconfig"``
    key is the *fallback* for drivers without one (legacy tails carry
    only the plain key) — it must not floor drivers that do carry their
    own release, or the per-driver decoupling would be re-coupled at
    every multi-batch seam through the global maximum.
    """
    base = float(release.get("reconfig", 0.0))
    if getattr(spec, "reconfig_scope", "tree") != "global":
        return {
            r.tree: float(release.get(("reconfig", r.tree), base))
            for r in spec.roots
        }
    start = base
    for k, v in release.items():
        if isinstance(k, tuple) and len(k) == 2 and k[0] == "reconfig":
            start = max(start, float(v))
    return {None: start}


@dataclasses.dataclass
class Assignment:
    """Tasks assigned, in execution order, to repartitioning-tree nodes."""

    spec: DeviceSpec
    tasks: dict[int, Task]              # task id -> Task
    node_tasks: dict[NodeKey, list[int]]  # node key -> ordered task ids

    def copy(self) -> "Assignment":
        return Assignment(
            self.spec,
            dict(self.tasks),
            {k: list(v) for k, v in self.node_tasks.items()},
        )

    def size_of(self, key: NodeKey) -> int:
        return key[2]

    def active_keys(self) -> set[NodeKey]:
        return {k for k, v in self.node_tasks.items() if v}


def list_schedule_allocation(
    tasks: Sequence[Task],
    allocation: Allocation,
    spec: DeviceSpec,
) -> Assignment:
    """Algorithm 1 — returns the output tree (assignment)."""
    # lines 1-2: group by allocated size, LPT order within each group
    groups: dict[int, list[Task]] = {s: [] for s in spec.sizes}
    for task, size in zip(tasks, allocation):
        groups[size].append(task)
    for size, grp in groups.items():
        grp.sort(key=lambda t: (-t.times[size], t.id))
    return list_schedule_groups(tasks, groups, spec)


def _list_schedule_arrays(
    ids_by_size: dict[int, list[int]],
    durs_by_size: dict[int, list[float]],
    n_tasks: int,
    spec: DeviceSpec,
) -> tuple[dict[NodeKey, list[int]], dict[NodeKey, list[float]]]:
    """Algorithm 1's heap phase over parallel (id, duration) arrays.

    The arrays must be LPT-ordered per size (sorted by ``(-dur, id)``);
    they are read through cursors and NOT consumed.  Returns the per-node
    task-id chains plus the matching duration chains (the latter feed the
    timing evaluators without re-resolving task profiles).

    The heap deliberately keeps the paper's single global ``reconfig_end``
    even on multi-tree forests: it only shapes which node receives the
    next task (the *construction heuristic*), while candidate scoring and
    the committed timing both use the per-driver sequences of
    :func:`replay` / ``chains_makespan``.  The vectorized phase-2
    evaluator's lockstep program mirrors this heap pop-for-pop
    (``family_eval._phase_a_program``), so the two must change together
    if the heuristic is ever made per-tree-aware."""
    remaining = n_tasks
    t_create = spec.t_create
    t_destroy = spec.t_destroy
    push = heapq.heappush
    pop = heapq.heappop

    cursor: dict[int, int] = {}
    for s in spec.sizes:  # node sizes are always a subset of spec.sizes
        ids_by_size.setdefault(s, [])
        durs_by_size.setdefault(s, [])
        cursor[s] = 0

    node_tasks: dict[NodeKey, list[int]] = {}
    node_durs: dict[NodeKey, list[float]] = {}
    reconfig_end = 0.0  # line 3
    heap: list[tuple[float, int, InstanceNode]] = []
    seq = 0
    for root in spec.roots:  # line 4
        push(heap, (0.0, seq, root))
        seq += 1

    while heap:  # line 5
        end, _, node = pop(heap)  # line 6
        size = node.size
        gids = ids_by_size[size]
        cur = cursor[size]
        n_grp = len(gids)
        if cur < n_grp:  # lines 7-16: task placement
            key = node.key
            lst = node_tasks.get(key)
            if lst is None:  # lines 8-11: charge creation
                if end > reconfig_end:
                    reconfig_end = end
                reconfig_end += t_create[size]
                end = reconfig_end
                lst = node_tasks[key] = []
                node_durs[key] = []
            dlst = node_durs[key]
            gdurs = durs_by_size[size]
            # place back-to-back while this node stays strictly earliest —
            # skips the pop/push pair the heap round-trip would cost; with
            # a strict ``<`` the visit order is identical to one-at-a-time
            # (a pushed re-entry always carries the largest seq, so it only
            # precedes entries with strictly larger end times)
            while True:
                lst.append(gids[cur])  # line 12: longest unscheduled
                d = gdurs[cur]
                dlst.append(d)
                cur += 1
                end += d  # lines 13-15
                remaining -= 1
                if cur >= n_grp or (heap and end >= heap[0][0]):
                    break
            cursor[size] = cur
            push(heap, (end, seq, node))  # line 16
            seq += 1
        elif remaining > 0:  # lines 17-23: repartitioning
            if node_tasks.get(node.key):  # lines 18-20: charge destruction
                if end > reconfig_end:
                    reconfig_end = end
                reconfig_end += t_destroy[size]
            for child in node.children:  # lines 21-24
                push(heap, (end, seq, child))
                seq += 1
        # else: all tasks scheduled -> the instance simply retires

    assert remaining == 0, "Algorithm 1 failed to place every task"
    return node_tasks, node_durs


def list_schedule_groups(
    tasks: Sequence[Task],
    groups: dict[int, list[Task]],
    spec: DeviceSpec,
    tasks_by_id: dict[int, Task] | None = None,
) -> Assignment:
    """Algorithm 1's heap phase over pre-built LPT groups.

    ``groups`` must hold each size's tasks sorted by ``(-t.times[size],
    t.id)``; they are read through per-size cursors and NOT consumed, so a
    caller evaluating the whole Turek family can maintain the groups
    incrementally across consecutive allocations (:class:`LPTGroups`)
    instead of re-sorting from scratch.  ``tasks_by_id`` (optional) is
    shared into the returned Assignment to skip rebuilding it per family
    candidate."""
    ids = {s: [t.id for t in grp] for s, grp in groups.items()}
    durs = {s: [t.times[s] for t in grp] for s, grp in groups.items()}
    node_tasks, _ = _list_schedule_arrays(ids, durs, len(tasks), spec)
    if tasks_by_id is None:
        tasks_by_id = {t.id: t for t in tasks}
    return Assignment(spec, tasks_by_id, node_tasks)


class LPTGroups:
    """Per-size LPT-ordered task groups, warm-startable across the family.

    Consecutive Turek-family allocations differ in exactly one task's size,
    so phase 2 keeps one instance of this class and calls :meth:`move` per
    family step — an O(group) bisect remove+insert instead of re-grouping
    and re-sorting all n tasks.  The maintained order is the total order
    ``(-t.times[size], t.id)``, hence bit-identical to a cold sort.
    """

    def __init__(self, tasks: Sequence[Task], allocation: Allocation,
                 spec: DeviceSpec):
        self.tasks = tasks
        self.tasks_by_id = {t.id: t for t in tasks}
        self.spec = spec
        self.groups: dict[int, list[Task]] = {s: [] for s in spec.sizes}
        for task, size in zip(tasks, allocation):
            self.groups[size].append(task)
        for size, grp in self.groups.items():
            grp.sort(key=lambda t: (-t.times[size], t.id))
        self._keys: dict[int, list[tuple[float, int]]] = {
            s: [(-t.times[s], t.id) for t in grp]
            for s, grp in self.groups.items()
        }
        # parallel id/duration arrays, consumed by _list_schedule_arrays
        # without re-resolving Task objects per candidate
        self._ids: dict[int, list[int]] = {
            s: [t.id for t in grp] for s, grp in self.groups.items()
        }
        self._durs: dict[int, list[float]] = {
            s: [t.times[s] for t in grp] for s, grp in self.groups.items()
        }

    def move(self, task: Task, old_size: int, new_size: int) -> None:
        """Re-file ``task`` after the family widened it old_size→new_size."""
        k_old = (-task.times[old_size], task.id)
        keys = self._keys[old_size]
        i = bisect.bisect_left(keys, k_old)
        assert keys[i] == k_old and self.groups[old_size][i].id == task.id
        keys.pop(i)
        self.groups[old_size].pop(i)
        self._ids[old_size].pop(i)
        self._durs[old_size].pop(i)

        k_new = (-task.times[new_size], task.id)
        keys = self._keys[new_size]
        j = bisect.bisect_left(keys, k_new)
        keys.insert(j, k_new)
        self.groups[new_size].insert(j, task)
        self._ids[new_size].insert(j, task.id)
        self._durs[new_size].insert(j, task.times[new_size])

    def schedule(self) -> Assignment:
        return self.schedule_with_durs()[0]

    def schedule_with_durs(
        self,
    ) -> tuple[Assignment, dict[NodeKey, list[float]]]:
        """Run Algorithm 1; also return the per-node duration chains (for
        the lean makespan evaluator in :mod:`repro.core.timing`)."""
        node_tasks, node_durs = _list_schedule_arrays(
            self._ids, self._durs, len(self.tasks), self.spec
        )
        return (
            Assignment(self.spec, self.tasks_by_id, node_tasks),
            node_durs,
        )


@dataclasses.dataclass
class SizeSortedOrders:
    """Per-size LPT total orders of one whole batch, as arrays.

    For each instance size ``s`` the batch is sorted by ``(-times[s], id)``
    — the exact key :class:`LPTGroups` maintains — so any allocation's
    size-``s`` group is a *subset of positions* in that fixed order, and a
    family of allocations becomes a boolean membership tensor over it.
    This is the array layout the vectorized phase-2 evaluator
    (:mod:`repro.core.family_eval`) scores candidate chunks from.

    Attributes (``S`` = number of sizes, ``n`` = batch size):
      sizes: the spec's sizes, fixing the ``S`` axis order.
      order: ``(S, n)`` int — batch positions sorted per size.
      inv: ``(S, n)`` int — inverse permutations (batch position -> rank).
      durs: ``(S, n)`` float64 — ``times[s]`` in sorted order.
      ids: ``(S, n)`` int64 — task ids in sorted order.
    """

    sizes: tuple[int, ...]
    order: "object"
    inv: "object"
    durs: "object"
    ids: "object"


def size_sorted_orders(tasks: Sequence[Task], spec: DeviceSpec) -> SizeSortedOrders:
    """Build the per-size LPT total orders of ``tasks`` (see
    :class:`SizeSortedOrders`)."""
    import numpy as np

    n = len(tasks)
    sizes = spec.sizes
    ids_arr = np.array([t.id for t in tasks], dtype=np.int64)
    times = np.array([[t.times[s] for t in tasks] for s in sizes])
    order = np.empty((len(sizes), n), dtype=np.int64)
    inv = np.empty_like(order)
    durs = np.empty((len(sizes), n))
    ids = np.empty((len(sizes), n), dtype=np.int64)
    for k in range(len(sizes)):
        o = np.lexsort((ids_arr, -times[k]))
        order[k] = o
        inv[k, o] = np.arange(n)
        durs[k] = times[k, o]
        ids[k] = ids_arr[o]
    return SizeSortedOrders(tuple(sizes), order, inv, durs, ids)


def replay(
    assignment: Assignment,
    release: dict | None = None,
    include_reconfig: bool = True,
    direction: str = "forward",
    alive: dict[NodeKey, float] | None = None,
) -> Schedule:
    """Extract the canonical timed schedule from an assignment.

    Deterministic event simulation that mirrors Algorithm 1's timing rules:
    an instance is created (sequentially, through the global reconfiguration
    window) when it first hosts a task, runs its tasks back-to-back, and is
    destroyed when the schedule moves past it.

    Reconfiguration windows serialise **per driver**: one sequence per
    tree of the forest (each GPU has its own driver, paper §2.1), so
    sibling trees reconfigure concurrently.  A spec pinning
    ``reconfig_scope="global"`` keeps the old single-sequence coupling
    (identical on single-tree specs either way).

    Args:
      assignment: tree + ordered per-node task lists.
      release: optional per-(tree, slice) release times — slices are not
        available before these (used by multi-batch concatenation to splice
        a batch after the previous one; paper §4).  May also contain
        ``"reconfig"`` (a floor on every driver's sequence) and/or
        ``("reconfig", tree)`` per-driver release times.
      include_reconfig: when False, creations/destructions take zero time
        (used by phase-3 bookkeeping between full recomputations).
      direction: ``"forward"`` runs root -> leaves (Algorithm 1's order:
        big instances first, destroy parent before children); ``"reverse"``
        runs leaves -> root with each node's task list reversed (paper §4.2
        batch reversal: small instances first, children destroyed before
        their parent is created).
      alive: instances still existing when this batch starts (carried over
        from the previous batch), mapped to their busy-until time.  A
        conflicting alive instance is destroyed (sequentially) before any
        overlapping instance is created; an alive instance reused by this
        batch skips its creation window entirely (paper §4.2: reconfigs are
        "eliminated when the last instance of B_{k-1} coincides with the
        first instance of B_k").
    """
    spec = assignment.spec
    release = release or {}
    alive = dict(alive or {})
    active = assignment.active_keys()
    t_create = spec.t_create if include_reconfig else {s: 0.0 for s in spec.sizes}
    t_destroy = spec.t_destroy if include_reconfig else {s: 0.0 for s in spec.sizes}

    items: list[ScheduledTask] = []
    reconfigs: list[ReconfigEvent] = []
    rc_end = reconfig_sequence_starts(spec, release)
    destroyed_alive: set[NodeKey] = set()

    alive_sorted = sorted(alive)

    def node_release(node: InstanceNode) -> float:
        return max(
            (float(release.get(cell, 0.0)) for cell in node.blocked_cells),
            default=0.0,
        )

    def clear_alive_conflicts(node: InstanceNode) -> None:
        """Destroy carried-over instances overlapping ``node``'s footprint."""
        cells = node.blocked_cells
        for akey in alive_sorted:
            if akey == node.key or akey in destroyed_alive:
                continue
            anode = spec.node_by_key(akey)
            if not (cells & anode.blocked_cells):
                continue
            g = anode.tree if anode.tree in rc_end else None
            begin_d = max(rc_end[g], alive[akey])
            rc_end[g] = begin_d + t_destroy[anode.size]
            reconfigs.append(ReconfigEvent("destroy", anode, begin_d, rc_end[g]))
            destroyed_alive.add(akey)

    def run_node(node: InstanceNode, ready: float) -> float:
        """Create (if needed), run tasks, return the node's task-end time."""
        key = node.key
        ready = max(ready, node_release(node))
        if key in alive and key not in destroyed_alive:
            # instance reuse across the batch seam: no creation window
            t = max(ready, alive[key])
        else:
            clear_alive_conflicts(node)
            g = node.tree if node.tree in rc_end else None
            begin_c = max(rc_end[g], ready)
            rc_end[g] = begin_c + t_create[node.size]
            reconfigs.append(ReconfigEvent("create", node, begin_c, rc_end[g]))
            t = rc_end[g]
        tids = assignment.node_tasks[key]
        if direction == "reverse":
            tids = list(reversed(tids))
        for tid in tids:
            task = assignment.tasks[tid]
            items.append(ScheduledTask(task, node, t, node.size))
            t += task.times[node.size]
        return t

    def destroy_node(node: InstanceNode, after: float) -> None:
        g = node.tree if node.tree in rc_end else None
        begin_d = max(rc_end[g], after)
        rc_end[g] = begin_d + t_destroy[node.size]
        reconfigs.append(ReconfigEvent("destroy", node, begin_d, rc_end[g]))

    # Event-driven simulation.  Reconfiguration windows are appended to the
    # sequentialised reconfiguration timeline strictly in event-time order
    # (Algorithm 1 interleaves creations/destructions of different instances
    # by their release times — processing a whole node atomically would
    # wrongly serialise sibling creations behind a later destroy).
    heap: list[tuple[float, int, str, InstanceNode]] = []
    seq = 0

    def push(when: float, what: str, node: InstanceNode) -> None:
        nonlocal seq
        heapq.heappush(heap, (when, seq, what, node))
        seq += 1

    if direction == "forward":
        # memoized per replay: the naive recursion re-walks whole subtrees
        # on every "done" event and measurably dominates small replays
        _sub_act: dict[NodeKey, bool] = {}

        def subtree_active(node: InstanceNode) -> bool:
            v = _sub_act.get(node.key)
            if v is None:
                v = node.key in active or any(
                    subtree_active(c) for c in node.children
                )
                _sub_act[node.key] = v
            return v

        for root in spec.roots:
            push(0.0, "visit", root)
        while heap:
            when, _, what, node = heapq.heappop(heap)
            if what == "visit":
                if node.key in active:
                    node_end = run_node(node, when)
                    push(node_end, "done", node)
                else:
                    push(when, "done", node)
            else:  # done -> destroy (if needed) and release children
                if not any(subtree_active(c) for c in node.children):
                    continue
                if node.key in active:
                    destroy_node(node, when)
                for child in node.children:
                    if subtree_active(child):
                        push(when, "visit", child)
    elif direction == "reverse":
        # leaves -> root: an active node waits for all its active strict
        # descendants; it is destroyed iff an active strict ancestor exists.
        anc: dict[NodeKey, list[NodeKey]] = {k: [] for k in active}
        desc_count: dict[NodeKey, int] = {k: 0 for k in active}

        def walk(node: InstanceNode, chain: list[NodeKey]) -> None:
            """chain = active ancestors of ``node``, top-down."""
            if node.key in active:
                anc[node.key] = list(chain)
                for a in chain:
                    desc_count[a] += 1
                chain = chain + [node.key]
            for c in node.children:
                walk(c, chain)

        for root in spec.roots:
            walk(root, [])

        ready_t: dict[NodeKey, float] = {k: 0.0 for k in active}
        # NodeKey is a tuple of small ints — CPython's int/tuple hashing
        # is not randomized, so this set iterates identically every run,
        # and TimingEngine._simulate seeds its reverse walk from the
        # same iteration; see the matching pragma there.
        for k in active:  # contracts: ignore[determinism] -- int-tuple set: hash order is run-stable and mirrored by TimingEngine's reverse seeding
            if desc_count[k] == 0:
                push(0.0, "visit", spec.node_by_key(k))
        while heap:
            when, _, what, node = heapq.heappop(heap)
            key = node.key
            if what == "visit":
                node_end = run_node(node, when)
                push(node_end, "done", node)
            else:
                if anc[key]:
                    destroy_node(node, when)
                for a in anc[key]:
                    ready_t[a] = max(ready_t[a], when)
                    desc_count[a] -= 1
                    if desc_count[a] == 0:
                        push(ready_t[a], "visit", spec.node_by_key(a))
    else:
        raise ValueError(f"unknown direction {direction!r}")

    return Schedule(spec=spec, items=items, reconfigs=reconfigs)


def alive_at_end(schedule: Schedule) -> dict[NodeKey, float]:
    """Instances existing when the schedule finishes -> busy-until time."""
    created: dict[NodeKey, float] = {}
    for rc in schedule.reconfigs:
        if rc.kind == "create":
            created[rc.node.key] = rc.end
        elif rc.kind == "destroy":
            created.pop(rc.node.key, None)
    out: dict[NodeKey, float] = {}
    by_node = schedule.by_node()
    for key, end_c in created.items():
        lst = by_node.get(key, [])
        out[key] = max([end_c] + [it.end for it in lst])
    return out
