"""Incremental timing engine for the FAR hot path.

:func:`~repro.core.repartition.replay` is the repo's single timing
authority, but it rebuilds the full :class:`~repro.core.problem.Schedule`
(one ``ScheduledTask`` object per task, one event per tree node) on every
call.  Phase-3 refinement, the §4.3 seam move/swap engine and the online
scheduler all evaluate *many* small edits of one assignment, so they paid
a full replay per candidate — the dominant scheduler cost in
``benchmarks/t_cost.py``.

:class:`TimingEngine` is a mutable evaluator over the same state replay
consumes (per-node task chains + the device tree + the seam carry-over
``release``/``alive``/``direction`` context).  It supports

* ``apply_move(tid, dst[, src])`` / ``apply_swap(tk, tj)`` /
  ``apply_append(tid, key)`` — the exact chain edits phases 3 and §4.3
  perform (LPT-position inserts identical to theirs);
* ``apply_retract(tid)`` / ``retract_suffix(key, n)`` — the inverse of
  append: pull a not-yet-started suffix back off a chain (serving
  re-planning withdraws queued placements when a flush lands);
* ``apply_stretch(tid, duration)`` — override one task's duration with
  runtime truth (actual completion, straggler projection): the
  closed-loop feedback correction, logged and undo-exact like every
  other edit; ``schedule()`` marks corrected items via
  ``ScheduledTask.end_override``;
* ``apply_cancel(tid, duration)`` / ``apply_credit(tid, credit_s)`` —
  the speculation/checkpoint primitives: truncate a slot into a failed
  occupancy record (the losing attempt of a first-finisher race), or
  shorten a not-yet-started retry by its banked checkpoint credit;
* ``undo()`` — speculative evaluation: apply an edit, read the timing,
  undo, bit-for-bit back to the previous state;
* ``makespan()`` / ``slice_end_times()`` / ``node_end_times()`` /
  ``begin_mass()`` — timings of the *current* chains.

**Replay-equivalence contract:** for any assignment state and any
``(release, alive, direction, include_reconfig)`` context, every accessor
returns exactly what a fresh ``replay()`` of the same assignment would
yield — bit-for-bit, not just within EPS.  The engine achieves this by
running the same event simulation with the same heap tie-breaking and the
same float-addition order, but at *node granularity*: chains contribute a
cached duration list (updated incrementally on each edit) instead of
per-task ``ScheduledTask`` objects, and only the affected nodes' chains
plus the sequential reconfiguration tail are touched per edit.  The
contract is enforced by ``tests/test_timing_engine.py`` against randomized
edit sequences in all four context combinations.

:class:`ReplayEngine` is the reference implementation of the same mutable
API, scoring every query with a full replay — it exists so the consumers
can be flipped between the two (``use_engine=`` flags) and compared.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from bisect import bisect_left
from typing import Sequence

from repro.core.device_spec import DeviceSpec, InstanceNode
from repro.core.problem import ReconfigEvent, Schedule, ScheduledTask
from repro.core.repartition import (
    Assignment,
    NodeKey,
    reconfig_sequence_starts,
    replay,
)


def _lpt_insert_pos(lst: list[int], tid: int, tasks, size: int) -> int:
    """Insert position keeping ``lst`` LPT-ordered (desc by duration), the
    invariant phase 3 / §4.3 maintain on every node's task list."""
    times = [-tasks[t].times[size] for t in lst]
    return bisect_left(times, -tasks[tid].times[size])


class ChainState:
    """Mutable per-node task chains with an undo log.

    Owns a working copy of an :class:`Assignment`'s ``node_tasks`` (the
    ``tasks`` dict and spec are shared — tasks are immutable).  All edits go
    through ``apply_*`` so subclasses can invalidate timing caches, and every
    edit records exact list positions so ``undo()`` restores bit-identical
    state (including tie order within equal durations).
    """

    def __init__(self, assignment: Assignment, copy_chains: bool = True):
        self.spec: DeviceSpec = assignment.spec
        self.tasks = assignment.tasks
        if copy_chains:
            self.chains: dict[NodeKey, list[int]] = {
                k: list(v) for k, v in assignment.node_tasks.items()
            }
        else:
            self.chains = assignment.node_tasks
        # cached per-chain duration lists, kept aligned with self.chains
        self.durs: dict[NodeKey, list[float]] = {
            k: [self.tasks[t].times[k[2]] for t in v]
            for k, v in self.chains.items()
        }
        # runtime duration corrections (tid -> actual/projected seconds);
        # consulted whenever a chain slot is (re)built so undo of a
        # retract/extract restores the corrected duration, not the profile
        self.stretched: dict[int, float] = {}
        # tids whose slot is a *cancelled occupancy record* (the losing
        # attempt of a speculation race): the slice stays busy for the
        # truncated span but the task did not complete there, so
        # schedule() materialises the slot with failed=True
        self.cancelled: set[int] = set()
        self._task_node: dict[int, NodeKey] | None = None  # built lazily
        self._chain_ver: dict[NodeKey, int] = {}  # bumped per chain edit
        self._log: list[tuple] = []

    @property
    def task_node(self) -> dict[int, NodeKey]:
        """tid -> hosting node key (lazy: query-only engines skip it)."""
        if self._task_node is None:
            self._task_node = {
                tid: k for k, lst in self.chains.items() for tid in lst
            }
        return self._task_node

    def _bump(self, key: NodeKey) -> None:
        self._chain_ver[key] = self._chain_ver.get(key, 0) + 1

    # -- views --------------------------------------------------------------
    @property
    def assignment(self) -> Assignment:
        """Live (zero-copy) Assignment view of the current chains."""
        return Assignment(self.spec, self.tasks, self.chains)

    def export_assignment(self) -> Assignment:
        return Assignment(
            self.spec, dict(self.tasks), {k: list(v) for k, v in self.chains.items()}
        )

    # -- primitive list surgery --------------------------------------------
    def _remove(self, key: NodeKey, tid: int) -> int:
        lst = self.chains[key]
        idx = lst.index(tid)
        lst.pop(idx)
        self.durs[key].pop(idx)
        self._bump(key)
        return idx

    def _insert(self, key: NodeKey, idx: int, tid: int) -> None:
        self.chains.setdefault(key, [])
        self.durs.setdefault(key, [])
        self.chains[key].insert(idx, tid)
        dur = self.stretched.get(tid)
        if dur is None:
            dur = self.tasks[tid].times[key[2]]
        self.durs[key].insert(idx, dur)
        self._bump(key)
        if self._task_node is not None:
            self._task_node[tid] = key

    # -- edits --------------------------------------------------------------
    def apply_move(self, tid: int, dst: NodeKey, src: NodeKey | None = None) -> None:
        """Move ``tid`` from its node to ``dst`` (LPT-position insert)."""
        if src is None:
            src = self.task_node[tid]
        i = self._remove(src, tid)
        p = _lpt_insert_pos(self.chains.get(dst, []), tid, self.tasks, dst[2])
        self._insert(dst, p, tid)
        self._log.append(("move", tid, src, i, dst, p))
        self._invalidate()

    def apply_swap(self, tk: int, tj: int) -> None:
        """Swap ``tk`` (on I) with ``tj`` (on Iᵃ) — exact edit order of
        phase 3 / §4.3: remove tk, remove tj, insert tk→Iᵃ, insert tj→I."""
        ki = self.task_node[tk]
        ka = self.task_node[tj]
        assert ki != ka, "swap within one node is a no-op"
        i1 = self._remove(ki, tk)
        i2 = self._remove(ka, tj)
        p1 = _lpt_insert_pos(self.chains[ka], tk, self.tasks, ka[2])
        self._insert(ka, p1, tk)
        p2 = _lpt_insert_pos(self.chains[ki], tj, self.tasks, ki[2])
        self._insert(ki, p2, tj)
        self._log.append(("swap", tk, tj, ki, i1, ka, i2, p1, p2))
        self._invalidate()

    def apply_append(self, tid: int, key: NodeKey) -> None:
        """Append ``tid`` at the end of ``key``'s chain (online placement)."""
        self.chains.setdefault(key, [])
        self._insert(key, len(self.chains[key]), tid)
        self._log.append(("append", tid, key))
        self._invalidate()

    def apply_extract(self, tid: int, src: NodeKey | None = None) -> None:
        """Remove ``tid`` from its chain at its current position — the
        outbound half of a *cross-engine* move: the inter-device local
        search extracts a task here and places it on another device's
        engine (each engine only ever sees its own tree)."""
        if src is None:
            src = self.task_node[tid]
        idx = self._remove(src, tid)
        if self._task_node is not None:
            del self._task_node[tid]
        self._log.append(("extract", tid, src, idx))
        self._invalidate()

    def apply_place(self, tid: int, key: NodeKey) -> None:
        """LPT-position insert of a task not currently on any chain — the
        inbound half of a cross-engine move (``self.tasks`` must already
        know ``tid``, bound to this engine's device kind)."""
        p = _lpt_insert_pos(self.chains.get(key, []), tid, self.tasks, key[2])
        self._insert(key, p, tid)
        self._log.append(("place", tid, key, p))
        self._invalidate()

    def apply_retract(self, tid: int, key: NodeKey | None = None) -> None:
        """Retract ``tid`` from the END of its chain — the exact inverse of
        :meth:`apply_append`, for pulling back an appended placement that
        has not started yet (serving re-planning).  Only the last task of a
        chain may be retracted: anything earlier would shift the begin
        times of the tasks behind it, which the no-preemption model
        forbids once they are running."""
        if key is None:
            key = self.task_node[tid]
        lst = self.chains.get(key)
        if not lst or lst[-1] != tid:
            raise ValueError(
                f"task {tid} is not the last task of chain {key}; only a "
                f"chain suffix can be retracted"
            )
        lst.pop()
        self.durs[key].pop()
        self._bump(key)
        if self._task_node is not None:
            del self._task_node[tid]
        self._log.append(("retract", tid, key))
        self._invalidate()

    def apply_stretch(self, tid: int, duration: float) -> None:
        """Override ``tid``'s duration on its chain with runtime truth —
        the closed-loop correction primitive (logged, undo-exact, like
        :meth:`apply_retract`).  ``duration`` is the task's *actual* (or
        projected) runtime; everything behind it on the chain re-times
        through the normal invalidation path.  Stretching (late) and
        shrinking (early completion) are both allowed; the correction
        sticks to the task through later retracts/undos via
        ``self.stretched``.  The no-preemption model is untouched — the
        task still runs once, contiguously, just for a different span."""
        if duration <= 0.0:
            raise ValueError(
                f"stretch duration must be positive, got {duration}"
            )
        key = self.task_node[tid]
        idx = self.chains[key].index(tid)
        old_dur = self.durs[key][idx]
        old_mark = self.stretched.get(tid)
        self.durs[key][idx] = duration
        self.stretched[tid] = duration
        self._bump(key)
        self._log.append(("stretch", tid, key, idx, old_dur, old_mark))
        self._invalidate()

    def apply_cancel(self, tid: int, duration: float) -> None:
        """Cancel ``tid`` mid-run: its chain slot is truncated to
        ``duration`` — the span the slice was physically occupied before
        the cancellation — and marked as a failed occupancy record.  This
        is the speculation primitive: when the first finisher of a
        primary/backup race wins, the loser is cancelled through this
        logged op so successors re-time against the truncated slot and
        ``undo()`` restores the race state bit-exactly.  Like
        :meth:`apply_stretch`, the truncation sticks through later
        retract/undo cycles via ``self.stretched``."""
        if duration <= 0.0:
            raise ValueError(
                f"cancel duration must be positive, got {duration}"
            )
        key = self.task_node[tid]
        idx = self.chains[key].index(tid)
        old_dur = self.durs[key][idx]
        old_mark = self.stretched.get(tid)
        was_cancelled = tid in self.cancelled
        self.durs[key][idx] = duration
        self.stretched[tid] = duration
        self.cancelled.add(tid)
        self._bump(key)
        self._log.append(
            ("cancel", tid, key, idx, old_dur, old_mark, was_cancelled)
        )
        self._invalidate()

    def apply_credit(self, tid: int, credit_s: float) -> None:
        """Shorten ``tid``'s not-yet-started slot by ``credit_s`` seconds
        of banked checkpoint progress — the partial-progress primitive: a
        retried attempt that resumes from its last checkpoint boundary
        occupies only the remainder of its profiled duration.  The credit
        must leave a strictly positive remainder (a fully-credited task
        is a completion, not a placement)."""
        if credit_s <= 0.0:
            raise ValueError(
                f"checkpoint credit must be positive, got {credit_s}"
            )
        key = self.task_node[tid]
        idx = self.chains[key].index(tid)
        old_dur = self.durs[key][idx]
        if credit_s >= old_dur - 1e-12:
            raise ValueError(
                f"checkpoint credit {credit_s} must leave a positive "
                f"remainder of the slot duration {old_dur}"
            )
        old_mark = self.stretched.get(tid)
        remainder = old_dur - credit_s
        self.durs[key][idx] = remainder
        self.stretched[tid] = remainder
        self._bump(key)
        self._log.append(("credit", tid, key, idx, old_dur, old_mark))
        self._invalidate()

    def retract_suffix(self, key: NodeKey, count: int) -> list[int]:
        """Retract the last ``count`` tasks of ``key``'s chain (newest
        first); returns the retracted task ids in retraction order.  Each
        retraction is logged individually, so ``undo()`` restores them one
        at a time."""
        lst = self.chains.get(key, [])
        if count < 0 or count > len(lst):
            raise ValueError(
                f"cannot retract {count} tasks from chain {key} of "
                f"length {len(lst)}"
            )
        out: list[int] = []
        for _ in range(count):
            tid = lst[-1]
            self.apply_retract(tid, key)
            out.append(tid)
        return out

    def undo(self) -> None:
        """Revert the most recent edit exactly."""
        entry = self._log.pop()
        kind = entry[0]
        if kind == "move":
            _, tid, src, i, dst, p = entry
            popped = self.chains[dst].pop(p)
            assert popped == tid
            self.durs[dst].pop(p)
            self._bump(dst)
            self._insert(src, i, tid)
        elif kind == "swap":
            _, tk, tj, ki, i1, ka, i2, p1, p2 = entry
            popped = self.chains[ki].pop(p2)
            assert popped == tj
            self.durs[ki].pop(p2)
            popped = self.chains[ka].pop(p1)
            assert popped == tk
            self.durs[ka].pop(p1)
            self._bump(ki)
            self._bump(ka)
            self._insert(ka, i2, tj)
            self._insert(ki, i1, tk)
        elif kind == "append":
            _, tid, key = entry
            popped = self.chains[key].pop()
            assert popped == tid
            self.durs[key].pop()
            self._bump(key)
            if self._task_node is not None:
                del self._task_node[tid]
        elif kind == "retract":
            _, tid, key = entry
            self._insert(key, len(self.chains[key]), tid)
        elif kind == "stretch":
            _, tid, key, idx, old_dur, old_mark = entry
            self.durs[key][idx] = old_dur
            if old_mark is None:
                self.stretched.pop(tid, None)
            else:
                self.stretched[tid] = old_mark
            self._bump(key)
        elif kind == "cancel":
            _, tid, key, idx, old_dur, old_mark, was_cancelled = entry
            self.durs[key][idx] = old_dur
            if old_mark is None:
                self.stretched.pop(tid, None)
            else:
                self.stretched[tid] = old_mark
            if not was_cancelled:
                self.cancelled.discard(tid)
            self._bump(key)
        elif kind == "credit":
            _, tid, key, idx, old_dur, old_mark = entry
            self.durs[key][idx] = old_dur
            if old_mark is None:
                self.stretched.pop(tid, None)
            else:
                self.stretched[tid] = old_mark
            self._bump(key)
        elif kind == "extract":
            _, tid, src, idx = entry
            self._insert(src, idx, tid)
        elif kind == "place":
            _, tid, key, p = entry
            popped = self.chains[key].pop(p)
            assert popped == tid
            self.durs[key].pop(p)
            self._bump(key)
            if self._task_node is not None:
                del self._task_node[tid]
        else:  # pragma: no cover
            raise AssertionError(f"unknown log entry {kind}")
        self._invalidate()

    def undo_all(self) -> None:
        while self._log:
            self.undo()

    @property
    def log_length(self) -> int:
        """Number of applied (un-undone) edits — a rollback token."""
        return len(self._log)

    def rollback(self, log_length: int) -> None:
        """Undo edits until exactly ``log_length`` remain applied."""
        while len(self._log) > log_length:
            self.undo()

    def chain_version(self, key: NodeKey) -> int:
        """Monotone per-chain edit counter (for caching sorted views)."""
        return self._chain_ver.get(key, 0)

    def chain_durations(self, key: NodeKey) -> Sequence[float]:
        """Read-only view of ``key``'s per-slot durations (stretch
        corrections applied), aligned with ``self.chains[key]`` — the
        public way for cross-engine consumers (the cluster local search)
        to see chain times without reaching into the duration cache."""
        return self.durs.get(key, ())

    def _invalidate(self) -> None:  # overridden by timing subclasses
        pass


@dataclasses.dataclass
class _Eval:
    """One node-granular evaluation of the current chains."""

    node_t0: dict[NodeKey, float]    # chain start (post create/reuse)
    node_end: dict[NodeKey, float]   # chain end (last task end)
    makespan: float
    begin_mass: float | None         # fsum of per-chain begin-time sums;
    #                                  None when mass wasn't requested
    reconfig_end: float              # sequential reconfiguration tail
    order: list[NodeKey] | None      # node processing order (= replay's);
    reconfigs: list[tuple] | None    # None when the fast path skipped the
    #                                  event walk (schedule() re-simulates)


class TimingEngine(ChainState):
    """Incremental, replay-equivalent timing over mutable chains.

    The evaluation context (``release`` / ``alive`` / ``direction`` /
    ``include_reconfig``) is fixed per engine, matching how the consumers
    use replay; ``include_reconfig`` can be overridden per query because
    phase 3 interleaves reconfig-free bookkeeping with full acceptance
    checks on the same state.
    """

    def __init__(
        self,
        assignment: Assignment,
        release: dict | None = None,
        alive: dict[NodeKey, float] | None = None,
        direction: str = "forward",
        include_reconfig: bool = True,
        copy_chains: bool = True,
    ):
        super().__init__(assignment, copy_chains=copy_chains)
        if direction not in ("forward", "reverse"):
            raise ValueError(f"unknown direction {direction!r}")
        self.release = release or {}
        self.alive = dict(alive or {})
        self.direction = direction
        self.include_reconfig = include_reconfig
        spec = self.spec
        # static per-node context, computed once per engine
        if self.release:
            self._node_release: dict[NodeKey, float] = {
                n.key: max(
                    (float(self.release.get(c, 0.0)) for c in n.blocked_cells),
                    default=0.0,
                )
                for n in spec.nodes
            }
        else:
            self._node_release = dict.fromkeys(
                (n.key for n in spec.nodes), 0.0
            )
        # initial per-driver reconfiguration-sequence ends (one per tree,
        # or one global sequence when the spec pins reconfig_scope)
        self._rc_starts = reconfig_sequence_starts(spec, self.release)
        self._alive_sorted = sorted(self.alive)
        self._zero = {s: 0.0 for s in spec.sizes}
        self._ends_template = {
            (r.tree, s): 0.0 for r in spec.roots for s in r.blocked
        }
        self._compute_cells = {
            n.key: n.compute_cells for n in spec.nodes
        }
        self._cache: dict[bool, _Eval] = {}
        # per-chain fold caches: key -> (t0, version, end, begin_mass).  A
        # chain whose start time and contents are unchanged since the last
        # simulation reuses its folded end/mass — this is what makes an
        # edit's re-evaluation touch only the affected nodes' chains (plus
        # the reconfiguration tail, which is always re-walked).  One cache
        # per include_reconfig flag: chain start times differ between the
        # two contexts, and refinement alternates them every iteration.
        self._chain_folds: dict[
            bool, dict[NodeKey, tuple[float, int, float, float]]
        ] = {True: {}, False: {}}
        # begin-time masses are only folded once a consumer asks for them
        # (the seam tie-break does; refinement and phase 2 never do) — the
        # end-only fold is a C-speed ``sum`` instead of a Python loop
        self._need_mass = False

    def _invalidate(self) -> None:
        self._cache.clear()

    # -- accessors ----------------------------------------------------------
    def makespan(self, include_reconfig: bool | None = None) -> float:
        return self._evaluate(include_reconfig).makespan

    def node_end_times(
        self, include_reconfig: bool | None = None
    ) -> dict[NodeKey, float]:
        return self._evaluate(include_reconfig).node_end

    def begin_mass(self, include_reconfig: bool | None = None) -> float:
        ev = self._evaluate(include_reconfig)
        if ev.begin_mass is None:
            self._need_mass = True
            self._cache.clear()
            ev = self._evaluate(include_reconfig)
        return ev.begin_mass

    def slice_end_times(
        self, include_reconfig: bool | None = None
    ) -> dict[tuple[int, int], float]:
        """Last busy time per (tree, slice), == Schedule.slice_end_times()."""
        ev = self._evaluate(include_reconfig)
        ends = dict(self._ends_template)
        cells_of = self._compute_cells
        for key, end in ev.node_end.items():
            for cell in cells_of[key]:
                if end > ends[cell]:
                    ends[cell] = end
        return ends

    def schedule(self, include_reconfig: bool | None = None) -> Schedule:
        """Materialise the full canonical Schedule — bit-identical to
        ``replay()`` of the current chains (items in the same order, same
        reconfiguration windows).  Costs one pass over all tasks; use the
        scalar accessors while searching and this only for the winner."""
        ev = self._eval_recorded(include_reconfig)
        index = self.spec.node_index
        reverse = self.direction == "reverse"
        tasks = self.tasks
        stretched = self.stretched
        items: list[ScheduledTask] = []
        for key in ev.order:
            node = index[key]
            size = key[2]
            t = ev.node_t0[key]
            chain = self.chains[key]
            durs = self.durs[key]
            rng = range(len(chain) - 1, -1, -1) if reverse \
                else range(len(chain))
            for i in rng:
                tid = chain[i]
                if tid in stretched:
                    # runtime-corrected placement: carry the actual end;
                    # a cancelled slot is a failed occupancy record (the
                    # losing attempt of a speculation race)
                    items.append(ScheduledTask(
                        tasks[tid], node, t, size,
                        end_override=t + durs[i],
                        failed=tid in self.cancelled,
                    ))
                else:
                    items.append(ScheduledTask(tasks[tid], node, t, size))
                t += durs[i]
        reconfigs = [
            ReconfigEvent(kind, node, begin, end)
            for kind, node, begin, end in ev.reconfigs
        ]
        return Schedule(spec=self.spec, items=items, reconfigs=reconfigs)

    def task_begin_end(self, tid: int, include_reconfig: bool | None = None
                       ) -> tuple[float, float]:
        """Begin/end of one task, bit-identical to its ScheduledTask."""
        ev = self._evaluate(include_reconfig)
        key = self.task_node[tid]
        chain = self.chains[key]
        durs = self.durs[key]
        order = range(len(chain))
        if self.direction == "reverse":
            order = range(len(chain) - 1, -1, -1)
        t = ev.node_t0[key]
        for i in order:
            if chain[i] == tid:
                return t, t + durs[i]
            t += durs[i]
        raise KeyError(tid)  # pragma: no cover

    # -- core evaluation ----------------------------------------------------
    def _evaluate(self, include_reconfig: bool | None = None) -> _Eval:
        flag = self.include_reconfig if include_reconfig is None \
            else include_reconfig
        ev = self._cache.get(flag)
        if ev is None:
            ev = self._simulate(flag)
            self._cache[flag] = ev
        return ev

    def _eval_recorded(self, include_reconfig: bool | None = None) -> _Eval:
        """Like _evaluate, but guarantees event order/reconfig recording
        (re-simulates if the fast path produced the cached eval)."""
        flag = self.include_reconfig if include_reconfig is None \
            else include_reconfig
        ev = self._cache.get(flag)
        if ev is None or ev.order is None:
            ev = self._simulate(flag, record=True)
            self._cache[flag] = ev
        return ev

    def _simulate_fast(self) -> _Eval:
        """No-reconfig / no-carry-over / forward special case as a plain
        tree walk: with zero-width reconfiguration windows and no release
        constraints, events pop in non-decreasing time, so every chain
        starts exactly at the end of its nearest active ancestor's chain —
        the heap only dictated a summation order, which ``fsum`` makes
        irrelevant.  Scalar accessors are bit-identical to the full walk;
        ``schedule()`` falls back to the recording simulation."""
        chains = self.chains
        durs = self.durs
        chain_fold = self._chain_folds[False]
        chain_ver = self._chain_ver
        need_mass = self._need_mass
        node_t0: dict[NodeKey, float] = {}
        node_end: dict[NodeKey, float] = {}
        masses: list[float] = []
        makespan = 0.0
        stack = [(root, 0.0) for root in self.spec.roots]
        while stack:
            node, t = stack.pop()
            key = node.key
            lst = chains.get(key)
            if lst:
                ver = chain_ver.get(key, 0)
                fold = chain_fold.get(key)
                if fold is not None and fold[0] == t and fold[1] == ver \
                        and (not need_mass or fold[3] is not None):
                    end, mass = fold[2], fold[3]
                elif need_mass:
                    end = t
                    mass = 0.0
                    for d in durs[key]:
                        mass += end
                        end += d
                    chain_fold[key] = (t, ver, end, mass)
                else:
                    # sum() is the same left fold replay performs, in C
                    end = sum(durs[key], t)
                    mass = None
                    chain_fold[key] = (t, ver, end, None)
                node_t0[key] = t
                node_end[key] = end
                if need_mass:
                    masses.append(mass)
                if end > makespan:
                    makespan = end
                t = end
            for child in node.children:
                stack.append((child, t))
        return _Eval(node_t0, node_end, makespan,
                     math.fsum(masses) if need_mass else None,
                     makespan, None, None)

    def _simulate(self, include_reconfig: bool, record: bool = False) -> _Eval:
        """Node-granular mirror of ``repartition.replay`` — same events,
        same heap tie-breaking, same float-addition order."""
        spec = self.spec
        chains = self.chains
        durs = self.durs
        alive = self.alive
        reverse = self.direction == "reverse"
        active = {k for k, v in chains.items() if v}
        t_create = spec.t_create if include_reconfig else self._zero
        t_destroy = spec.t_destroy if include_reconfig else self._zero
        node_release = self._node_release
        index = spec.node_index

        have_alive = bool(alive)
        have_release = bool(self.release)
        if (not include_reconfig and not reverse and not have_alive
                and not have_release and not record):
            return self._simulate_fast()

        need_mass = self._need_mass
        node_t0: dict[NodeKey, float] = {}
        node_end: dict[NodeKey, float] = {}
        masses: list[float] = []
        rc_end = dict(self._rc_starts)  # per-driver sequence ends
        destroyed_alive: set[NodeKey] = set()
        order: list[NodeKey] = []
        reconfigs: list[tuple] = []

        def clear_alive_conflicts(node: InstanceNode) -> None:
            cells = node.blocked_cells
            for akey in self._alive_sorted:
                if akey == node.key or akey in destroyed_alive:
                    continue
                anode = index[akey]
                if not (cells & anode.blocked_cells):
                    continue
                g = anode.tree if anode.tree in rc_end else None
                begin_d = max(rc_end[g], alive[akey])
                rc_end[g] = begin_d + t_destroy[anode.size]
                reconfigs.append(("destroy", anode, begin_d, rc_end[g]))
                destroyed_alive.add(akey)

        chain_fold = self._chain_folds[include_reconfig]
        chain_ver = self._chain_ver

        def run_node(node: InstanceNode, ready: float) -> float:
            key = node.key
            if have_release:
                nr = node_release[key]
                if nr > ready:
                    ready = nr
            if have_alive and key in alive and key not in destroyed_alive:
                t = max(ready, alive[key])
            else:
                if have_alive:
                    clear_alive_conflicts(node)
                g = node.tree if node.tree in rc_end else None
                r = rc_end[g]
                if ready > r:
                    r = ready
                begin_c = r
                r += t_create[node.size]
                rc_end[g] = r
                reconfigs.append(("create", node, begin_c, r))
                t = r
            node_t0[key] = t
            order.append(key)
            ver = chain_ver.get(key, 0)
            fold = chain_fold.get(key)
            if fold is not None and fold[0] == t and fold[1] == ver \
                    and (not need_mass or fold[3] is not None):
                end, mass = fold[2], fold[3]
            else:
                ds = durs[key]
                if reverse:
                    ds = ds[::-1]
                if need_mass:
                    end = t
                    mass = 0.0
                    for d in ds:
                        mass += end
                        end += d
                else:
                    # sum() is the same left fold replay performs, in C
                    end = sum(ds, t)
                    mass = None
                chain_fold[key] = (t, ver, end, mass)
            if need_mass:
                masses.append(mass)
            node_end[key] = end
            return end

        def destroy_node(node: InstanceNode, after: float) -> None:
            g = node.tree if node.tree in rc_end else None
            r = rc_end[g]
            if after > r:
                r = after
            begin_d = r
            r += t_destroy[node.size]
            rc_end[g] = r
            reconfigs.append(("destroy", node, begin_d, r))

        heap: list[tuple[float, int, str, InstanceNode]] = []
        seq = 0

        def push(when: float, what: str, node: InstanceNode) -> None:
            nonlocal seq
            heapq.heappush(heap, (when, seq, what, node))
            seq += 1

        if not reverse:
            # subtree-active flags in one bottom-up pass (spec.nodes is BFS
            # order, so reversed() sees children before parents)
            sub_act: dict[NodeKey, bool] = {}
            for node in reversed(spec.nodes):
                sub_act[node.key] = node.key in active or any(
                    sub_act[c.key] for c in node.children
                )

            heappush = heapq.heappush
            heappop = heapq.heappop
            for root in spec.roots:
                if sub_act[root.key]:
                    heappush(heap, (0.0, seq, "visit", root))
                    seq += 1
            while heap:
                when, _, what, node = heappop(heap)
                if what == "visit":
                    if node.key in active:
                        heappush(heap, (run_node(node, when), seq, "done", node))
                    else:
                        heappush(heap, (when, seq, "done", node))
                    seq += 1
                else:
                    go = False
                    for child in node.children:
                        if sub_act[child.key]:
                            go = True
                            break
                    if not go:
                        continue
                    if node.key in active:
                        destroy_node(node, when)
                    for child in node.children:
                        if sub_act[child.key]:
                            heappush(heap, (when, seq, "visit", child))
                            seq += 1
        else:
            anc: dict[NodeKey, list[NodeKey]] = {k: [] for k in active}
            desc_count: dict[NodeKey, int] = {k: 0 for k in active}

            def walk(node: InstanceNode, chain: list[NodeKey]) -> None:
                if node.key in active:
                    anc[node.key] = list(chain)
                    for a in chain:
                        desc_count[a] += 1
                    chain = chain + [node.key]
                for c in node.children:
                    walk(c, chain)

            for root in spec.roots:
                walk(root, [])

            ready_t: dict[NodeKey, float] = {k: 0.0 for k in active}
            # NodeKey is a tuple of small ints, whose hashing CPython
            # pins across runs (no PYTHONHASHSEED dependence), and the
            # replay reference (repartition.py) seeds its heap from the
            # same literal iteration — sorting here would *break* the
            # bit-identity contract by changing the (time, seq) ties.
            for k in active:  # contracts: ignore[determinism] -- int-tuple set: hash order is run-stable and mirrors replay()'s seq order exactly
                if desc_count[k] == 0:
                    push(0.0, "visit", index[k])
            while heap:
                when, _, what, node = heapq.heappop(heap)
                key = node.key
                if what == "visit":
                    push(run_node(node, when), "done", node)
                else:
                    if anc[key]:
                        destroy_node(node, when)
                    for a in anc[key]:
                        ready_t[a] = max(ready_t[a], when)
                        desc_count[a] -= 1
                        if desc_count[a] == 0:
                            push(ready_t[a], "visit", index[a])

        makespan = max(node_end.values(), default=0.0)
        return _Eval(node_t0, node_end, makespan,
                     math.fsum(masses) if need_mass else None,
                     max(rc_end.values(), default=0.0), order, reconfigs)


def chains_makespan(
    spec: DeviceSpec,
    node_tasks: dict[NodeKey, list[int]],
    node_durs: dict[NodeKey, list[float]],
) -> float:
    """Exact ``replay(assignment).makespan`` for a fresh batch (forward,
    reconfig included, no carry-over state), computed from prebuilt
    duration chains without engine or Schedule construction.  This is the
    phase-2 family-evaluation scorer: one call per candidate allocation.
    Reconfigurations serialise per tree (per driver) like replay's;
    ``reconfig_scope="global"`` specs keep one shared sequence.
    """
    active = {k for k, v in node_tasks.items() if v}
    if not active:
        return 0.0
    t_create = spec.t_create
    t_destroy = spec.t_destroy
    per_tree = spec.reconfig_scope != "global"
    sub_act: dict[NodeKey, bool] = {}
    for node in reversed(spec.nodes):
        sub_act[node.key] = node.key in active or any(
            sub_act[c.key] for c in node.children
        )
    heappush = heapq.heappush
    heappop = heapq.heappop
    heap: list[tuple[float, int, int, InstanceNode]] = []  # 0=visit 1=done
    seq = 0
    rc_end: dict = {}  # per-driver reconfiguration-sequence ends
    makespan = 0.0
    for root in spec.roots:
        if sub_act[root.key]:
            heappush(heap, (0.0, seq, 0, root))
            seq += 1
    while heap:
        when, _, what, node = heappop(heap)
        key = node.key
        g = node.tree if per_tree else None
        if what == 0:
            if key in active:
                r = rc_end.get(g, 0.0)
                if when > r:
                    r = when
                r += t_create[node.size]
                rc_end[g] = r
                # sum() is the same left fold replay performs, in C
                t = sum(node_durs[key], r)
                if t > makespan:
                    makespan = t
                heappush(heap, (t, seq, 1, node))
            else:
                heappush(heap, (when, seq, 1, node))
            seq += 1
        else:
            go = False
            for child in node.children:
                if sub_act[child.key]:
                    go = True
                    break
            if not go:
                continue
            if key in active:
                r = rc_end.get(g, 0.0)
                if when > r:
                    r = when
                rc_end[g] = r + t_destroy[node.size]
            for child in node.children:
                if sub_act[child.key]:
                    heappush(heap, (when, seq, 0, child))
                    seq += 1
    return makespan


class IdentityCache:
    """Small FIFO cache keyed by an anchor object's identity (plus an
    optional hashable extra), for per-DeviceSpec derived structures.

    ``DeviceSpec`` holds dict fields, so it is not hashable; each entry
    keeps a strong reference to the anchor so its ``id`` stays valid for
    the entry's lifetime.  Shared by the batched-walk matrices below and
    the array-program caches in :mod:`repro.core.family_eval`.

    Why identity keying cannot influence plan bytes (the determinism
    contract): (1) every cached value is a *pure function of the
    anchor's contents* — for a given spec, hit and miss produce the same
    arrays; ``id`` only decides whether the derivation is re-run, never
    what it returns.  (2) The strong reference in the entry pins the
    anchor alive, so an ``id`` can never be recycled onto a different
    live spec while its entry exists — a stale hit is impossible, the
    ``entry[0] is anchor`` guard turns id collisions into ordinary
    misses.  (3) Eviction is FIFO by insertion, not by key order, so
    memory layout never chooses *which* entry survives.  Worst case for
    an unlucky allocation pattern is a recompute, never wrong bytes.
    ``tests/test_timing_engine.py::test_two_engines_same_spec_bit_identical``
    pins the observable half of this argument.
    """

    def __init__(self, max_size: int):
        self._max = max_size
        self._entries: dict[tuple, tuple] = {}

    def get(self, anchor, extra=()):
        entry = self._entries.get((id(anchor), extra))  # contracts: ignore[determinism] -- hit/miss parity: cached value is a pure function of the anchor, strong ref makes stale hits impossible (see class docstring)
        if entry is not None and entry[0] is anchor:
            return entry[1]
        return None

    def put(self, anchor, value, extra=()) -> None:
        if len(self._entries) >= self._max:
            self._entries.pop(next(iter(self._entries)))
        self._entries[(id(anchor), extra)] = (anchor, value)  # contracts: ignore[determinism] -- same argument as get(): identity only gates recomputation, never the computed bytes


#: per-spec static matrices for the batched walk
_BATCH_SPEC_CACHE = IdentityCache(16)


def _batch_spec_arrays(spec: DeviceSpec) -> tuple:
    """(tc, td, childmask, descmask, root_idx, grp_idx, n_groups) per
    spec.nodes order; ``grp_idx`` maps each node to its driver's
    reconfiguration-sequence index (one per tree, or a single shared
    sequence for ``reconfig_scope="global"``)."""
    cached = _BATCH_SPEC_CACHE.get(spec)
    if cached is not None:
        return cached
    import numpy as np

    nodes = spec.nodes
    n = len(nodes)
    index = {node.key: i for i, node in enumerate(nodes)}
    tc = np.array([spec.t_create[node.size] for node in nodes])
    td = np.array([spec.t_destroy[node.size] for node in nodes])
    childmask = np.zeros((n, n), dtype=bool)   # childmask[p, c]: c child of p
    descmask = np.zeros((n, n), dtype=bool)    # descmask[a, b]: b in subtree(a)
    for i, node in enumerate(nodes):
        for child in node.children:
            childmask[i, index[child.key]] = True

    def mark(i: int, anc: list[int]) -> None:
        for a in anc:
            descmask[a, i] = True
        descmask[i, i] = True
        for child in nodes[i].children:
            mark(index[child.key], anc + [i])

    root_idx = [index[r.key] for r in spec.roots]
    for i in root_idx:
        mark(i, [])
    if spec.reconfig_scope != "global":
        trees = sorted({node.tree for node in nodes})
        tmap = {t: k for k, t in enumerate(trees)}
        grp_idx = np.array([tmap[node.tree] for node in nodes])
        n_groups = len(trees)
    else:
        grp_idx = np.zeros(n, dtype=np.int64)
        n_groups = 1
    out = (tc, td, childmask, descmask, root_idx, grp_idx, n_groups)
    _BATCH_SPEC_CACHE.put(spec, out)
    return out


def chains_makespan_batch(spec, chain_durs, chain_len):
    """Batched :func:`chains_makespan` over C candidates at once.

    ``chain_durs`` is a ``(C, N, L)`` float64 array of per-node duration
    chains (N = ``len(spec.nodes)`` in BFS order, rows zero-padded past
    ``chain_len``) and ``chain_len`` the matching ``(C, N)`` counts.
    Returns the ``(C,)`` makespans, **bit-identical** per candidate to
    ``chains_makespan`` on the same chains: the event walk is run in
    lockstep across candidates with the same ``(time, seq)`` heap ordering
    and the chain fold is an ``np.add.accumulate`` — the exact left fold
    the sequential scorer performs.
    """
    import numpy as np

    C, N, L = chain_durs.shape
    (tc_n, td_n, childmask, descmask, root_idx, grp_idx,
     n_groups) = _batch_spec_arrays(spec)
    BIG = np.int64(2**62)
    INF = np.inf

    active = chain_len > 0                               # (C, N)
    if not active.any():
        return np.zeros(C)
    # sub_act[c, a]: any active node in subtree(a); goflag: any sub_act child
    sub_act = (active.astype(np.int8) @ descmask.T.astype(np.int8)) > 0
    goflag = (sub_act.astype(np.int8) @ childmask.T.astype(np.int8)) > 0

    tevt = np.full((C, N), INF)       # pending event time (one per node)
    sevt = np.full((C, N), BIG)       # pending event seq
    wevt = np.zeros((C, N), dtype=np.int8)  # 0 = visit, 1 = done
    seqctr = np.zeros(C, dtype=np.int64)
    for i in root_idx:                # roots pushed in order, seq 0, 1, ...
        pushed = sub_act[:, i]
        tevt[pushed, i] = 0.0
        sevt[pushed, i] = seqctr[pushed]
        seqctr += pushed
    # one reconfiguration sequence per driver group (per tree, or one
    # shared column for reconfig_scope="global" — G=1 reproduces the old
    # globally-coupled walk bit-for-bit)
    re = np.zeros((C, n_groups))
    mk = np.zeros(C)
    r = np.arange(C)

    while True:
        rows = np.isfinite(tevt).any(1)
        if not rows.any():
            break
        when = tevt.min(1)
        cand = tevt == when[:, None]
        seqm = np.where(cand, sevt, BIG)
        sel = cand & (seqm == seqm.min(1)[:, None]) & rows[:, None]
        n_star = sel.argmax(1)
        g_star = grp_idx[n_star]
        re_cur = re[r, g_star]
        what = wevt[r, n_star]
        act = active[r, n_star]
        m_visit = rows & (what == 0)
        m_va = m_visit & act
        m_done = rows & (what == 1)

        # visit of an active node: creation charge + exact chain fold
        t0 = np.maximum(re_cur, when) + tc_n[n_star]
        fold = np.add.accumulate(
            np.concatenate([t0[:, None], chain_durs[r, n_star]], 1), 1
        )
        end = fold[r, chain_len[r, n_star]]
        re[r[m_va], g_star[m_va]] = t0[m_va]
        mk = np.where(m_va & (end > mk), end, mk)
        # visit -> done event in place (active at chain end, else pass-through)
        tevt[r[m_visit], n_star[m_visit]] = np.where(m_va, end, when)[m_visit]
        wevt[r[m_visit], n_star[m_visit]] = 1
        sevt[r[m_visit], n_star[m_visit]] = seqctr[m_visit]
        seqctr += m_visit

        # done: destroy (if active and an active subtree remains) + children
        go = goflag[r, n_star]
        m_dgo = m_done & go
        m_destroy = m_dgo & act
        re_d = np.maximum(re[r, g_star], when) + td_n[n_star]
        re[r[m_destroy], g_star[m_destroy]] = re_d[m_destroy]
        tevt[r[m_done], n_star[m_done]] = INF
        if m_dgo.any():
            push = childmask[n_star] & sub_act & m_dgo[:, None]
            rank = np.cumsum(push, 1) - 1
            tevt = np.where(push, when[:, None], tevt)
            wevt = np.where(push, np.int8(0), wevt)
            sevt = np.where(push, seqctr[:, None] + rank, sevt)
            seqctr += push.sum(1)
    return mk


class ReplayEngine(ChainState):
    """Reference evaluator: same mutable API, every query a full replay.

    Used by the ``use_engine=False`` paths of refinement / seam move-swap
    and by the equivalence tests; intentionally unoptimised.
    """

    def __init__(
        self,
        assignment: Assignment,
        release: dict | None = None,
        alive: dict[NodeKey, float] | None = None,
        direction: str = "forward",
        include_reconfig: bool = True,
        copy_chains: bool = True,
    ):
        super().__init__(assignment, copy_chains=copy_chains)
        self.release = release or {}
        self.alive = dict(alive or {})
        self.direction = direction
        self.include_reconfig = include_reconfig

    def apply_stretch(self, tid: int, duration: float) -> None:
        raise NotImplementedError(
            "ReplayEngine scores every query with a profile-driven "
            "replay(); runtime duration corrections need TimingEngine"
        )

    def apply_cancel(self, tid: int, duration: float) -> None:
        raise NotImplementedError(
            "ReplayEngine scores every query with a profile-driven "
            "replay(); cancelled occupancy records need TimingEngine"
        )

    def apply_credit(self, tid: int, credit_s: float) -> None:
        raise NotImplementedError(
            "ReplayEngine scores every query with a profile-driven "
            "replay(); checkpoint-credit corrections need TimingEngine"
        )

    def _replay(self, include_reconfig: bool | None = None):
        flag = self.include_reconfig if include_reconfig is None \
            else include_reconfig
        return replay(
            self.assignment,
            release=self.release,
            include_reconfig=flag,
            direction=self.direction,
            alive=self.alive,
        )

    def makespan(self, include_reconfig: bool | None = None) -> float:
        return self._replay(include_reconfig).makespan

    def slice_end_times(self, include_reconfig: bool | None = None):
        return self._replay(include_reconfig).slice_end_times()

    def node_end_times(self, include_reconfig: bool | None = None):
        out: dict[NodeKey, float] = {}
        for it in self._replay(include_reconfig).items:
            k = it.node.key
            end = it.end
            if end > out.get(k, float("-inf")):
                out[k] = end
        return out

    def begin_mass(self, include_reconfig: bool | None = None) -> float:
        # per-chain sequential sums (items of one node are contiguous in
        # replay order) combined with the exactly-rounded fsum, so the
        # result is bit-identical to TimingEngine regardless of the order
        # its simulation visited the chains in
        subs: list[float] = []
        sub = 0.0
        cur: NodeKey | None = None
        for it in self._replay(include_reconfig).items:
            k = it.node.key
            if k != cur:
                if cur is not None:
                    subs.append(sub)
                cur, sub = k, 0.0
            sub += it.begin
        if cur is not None:
            subs.append(sub)
        return math.fsum(subs)

    def task_begin_end(self, tid: int, include_reconfig: bool | None = None
                       ) -> tuple[float, float]:
        it = next(
            it for it in self._replay(include_reconfig).items
            if it.task.id == tid
        )
        return it.begin, it.end

    def schedule(self, include_reconfig: bool | None = None) -> Schedule:
        return self._replay(include_reconfig)


def make_engine(
    assignment: Assignment,
    use_engine: bool = True,
    **context,
) -> TimingEngine | ReplayEngine:
    """Factory the consumers use to flip incremental vs reference timing."""
    cls = TimingEngine if use_engine else ReplayEngine
    return cls(assignment, **context)


__all__ = [
    "ChainState",
    "TimingEngine",
    "ReplayEngine",
    "chains_makespan",
    "chains_makespan_batch",
    "make_engine",
]
