"""Arrival-driven scheduling service with a latency budget, per-task
deadlines and tail re-planning (ROADMAP "online serving at scale"; cf.
Tan et al., serving DNN models on MIG, arXiv:2109.11067).

The paper's offline formulation needs batches; a serving frontend has
arrivals.  :class:`SchedulingService` bridges the two with a classic
latency-budget accumulator:

* ``submit(task, arrival)`` queues the task.  Virtual time advances with
  the (non-decreasing) arrival stamps;
* once the **oldest** queued task has waited ``config.max_wait_s`` — or
  ``config.max_batch`` tasks have queued up — the pending set is flushed
  as one batch through a :class:`~repro.core.multibatch.MultiBatchScheduler`
  under any registered policy, with tail-aware seam concatenation (§4);
* a deadline flush smaller than ``config.min_batch`` (a slow trickle) and
  ``urgent=True`` submits skip batching entirely: they are placed
  immediately by the :class:`~repro.core.online.OnlineScheduler` greedy,
  seeded with the committed tail's ``release``/``alive`` context so the
  fallback lands in the same timeline as the batches;
* multi-GPU pools come for free: ``pool_size=k`` schedules onto
  ``device_spec.multi_gpu(spec, k)``.

Two serving extensions ride on top of that accumulator:

**Deadlines and admission control.**  ``submit(task, deadline=d)`` tracks
the task's SLO; :meth:`deadline_report` scores misses against the final
combined schedule.  With ``config.admission`` set to ``"reject"`` or
``"demote"``, a submit whose deadline is *provably* unmeetable —
:meth:`completion_lower_bound`, an admissible floor built from the
running (never-preemptible) work on the committed timeline — is refused
outright or accepted best-effort with the deadline dropped.

**Tail re-planning.**  The batch-concatenation scheme normally commits
placements forever, but a placement that has not *started* is not
physically committed.  With ``config.replan=True`` every batch flush
first pulls the not-yet-started tail back
(:meth:`~repro.core.multibatch.MultiBatchScheduler.withdraw_uncommitted`)
and re-plans it together with the arrivals; the re-planned candidate is
kept only when it strictly beats the plain arrivals-only flush on the
combined makespan.  Running tasks keep their exact begin times — the
no-preemption model holds.  The service also carries the never-replanned
chain as a shadow, and every report (``makespan`` / ``drain`` /
``combined_schedule``) answers from whichever chain is ahead, so
``replan=True`` can never end a stream worse than ``replan=False`` —
the fragmentation-aware-scheduler observation (arXiv:2512.16099) that
online decisions degrade without revisiting queued placements, made safe
by construction.

**Runtime feedback (closed-loop fault tolerance).**  The committed
timeline is a *belief* built from profiled durations; ``report(task_id,
event, t)`` feeds it runtime truth.  A ``completed`` report replaces the
profiled end with the actual one (an early finish frees capacity, a late
one forces the conflicting tail out for re-planning); a ``failed``
report truncates the attempt into an occupancy record and re-releases
the task through ``config.retry`` (:class:`~repro.core.faults.RetryPolicy`
— capped exponential backoff, optional demotion).  With
``config.straggler_factor`` set, any time advance scans the running
placements and *stretches* those whose observed runtime exceeds the
factor without a completion report — the serving analogue of the timing
engine's logged ``apply_stretch``.  ``quarantine(device, t)`` /
``recover(device, t)`` handle device loss on a pool: every not-yet-
started placement on the lost device is withdrawn and re-partitioned
onto the survivors (tasks only the lost device supports are *parked*
and re-admitted on recovery; still parked at ``drain`` they are
reported rejected, never silently stranded), and admission floors
(:meth:`completion_lower_bound`) see only the surviving capacity.  The
first runtime deviation drops the never-replanned shadow — it is a
counterfactual over profiled durations and cannot absorb truth.

Everything is deterministic given the submission sequence — there is no
RNG and no wall-clock dependence in any placement decision (wall time is
only *measured*, for the decision-latency statistics).
"""

from __future__ import annotations

import dataclasses
import heapq
import math
import time
from typing import Sequence

from repro.core.cluster import ClusterMultiBatchScheduler, ClusterSpec
from repro.core.device_spec import DeviceSpec, multi_gpu
from repro.core.multibatch import MultiBatchScheduler
from repro.core.online import completion_floor
from repro.core.policy import SchedulerConfig
from repro.core.problem import (
    EPS,
    Schedule,
    ScheduledTask,
    Task,
    remainder_task,
    transfer_profile,
)

#: backup attempts get ids far above any plausible user task id so the
#: primary/backup records coexist on the committed timeline without
#: colliding in any id-keyed bookkeeping
_BACKUP_ID_BASE = 1 << 48


@dataclasses.dataclass(frozen=True)
class Decision:
    """How and when one task's placement was decided."""

    task_id: int
    arrival: float        # virtual time the task was submitted
    decided_at: float     # virtual time the placement decision fired
    route: str            # "batch" | "online" | "replan" | "fault" | "speculate"
    flush_id: int         # which flush carried it
    plan_wall_s: float    # wall-clock seconds the scheduler spent deciding
    deadline: float | None = None  # the task's SLO, if it kept one

    @property
    def queue_delay(self) -> float:
        """Virtual seconds the task waited for its decision."""
        return self.decided_at - self.arrival


@dataclasses.dataclass(frozen=True)
class ReplanEvent:
    """One accepted tail re-plan: which flush, what it pulled back, and
    the combined makespans of the two candidates it chose between."""

    flush_id: int
    decided_at: float
    withdrawn: tuple[int, ...]      # task ids pulled back for re-planning
    makespan_replanned: float
    makespan_plain: float

    @property
    def win(self) -> float:
        """Makespan saved by re-planning at this flush."""
        return self.makespan_plain - self.makespan_replanned


@dataclasses.dataclass(frozen=True)
class CorrectionEvent:
    """One runtime-truth correction of the committed timeline."""

    task_id: int
    at: float                    # virtual time the correction landed
    kind: str                    # "stretch" | "shrink" | "straggler" | "failure"
    old_end: float               # projected end before the correction
    new_end: float               # corrected end (actual / projection / t_fail)
    withdrawn: tuple[int, ...]   # placements the forced re-plan pulled back


@dataclasses.dataclass(frozen=True)
class RetryEvent:
    """One failed attempt re-entering the queue through the RetryPolicy."""

    task_id: int
    attempt: int                 # the attempt number being released (2-based)
    failed_at: float             # when the previous attempt failed
    release: float               # backoff floor: the retry arrives here
    demoted: bool                # whether the retry carries a demoted profile


@dataclasses.dataclass(frozen=True)
class OutageEvent:
    """One device-loss window on a pool."""

    device: int
    lost_at: float
    recovered_at: float | None   # None while still quarantined
    withdrawn: tuple[int, ...]   # not-yet-started placements pulled off it
    died_running: tuple[int, ...]  # attempts that were running at the loss
    parked: tuple[int, ...]      # withdrawn tasks no surviving device fits


@dataclasses.dataclass(frozen=True)
class SpeculationEvent:
    """One straggler-speculation race: a backup attempt launched against
    a stretched primary.  ``winner`` stays ``None`` while the race is in
    flight, then records who finished first — ``"backup"`` (the backup's
    record was re-keyed to the logical task), ``"primary"`` (the backup
    was cancelled), or ``"cancelled"`` (the backup died or was withdrawn
    before either finished; the primary, or its retry, carries on)."""

    task_id: int                  # the straggling primary
    backup_id: int                # the backup attempt's committed id
    at: float                     # launch time
    primary_end: float            # the primary's stretched projection then
    backup_end: float             # the backup's planned end at launch
    winner: str | None = None     # "primary" | "backup" | "cancelled"
    resolved_at: float | None = None


@dataclasses.dataclass(frozen=True)
class CheckpointEvent:
    """One grant of partial-progress credit: a failed (or speculation-
    cancelled) attempt banked its completed checkpoint periods, so the
    task's next attempt resumes from that boundary."""

    task_id: int                  # the logical task earning credit
    attempt: int                  # attempt number current at the grant
    at: float                     # when the attempt ended
    credit_s: float               # completed-checkpoint seconds banked
    progress: float               # cumulative fraction of the ORIGINAL work


@dataclasses.dataclass
class ServiceStats:
    submitted: int = 0
    batches: int = 0
    online_placements: int = 0
    decisions: list[Decision] = dataclasses.field(default_factory=list)
    rejected: list[int] = dataclasses.field(default_factory=list)
    demoted: list[int] = dataclasses.field(default_factory=list)
    replan_attempts: int = 0     # flushes that had a tail to pull back
    replan_wins: int = 0         # flushes where the re-plan was kept
    withdrawn: int = 0           # placements pulled back by kept re-plans
    replan_events: list[ReplanEvent] = dataclasses.field(default_factory=list)
    # -- runtime feedback ---------------------------------------------------
    completed: int = 0           # completion reports received
    stragglers: int = 0          # implicit straggler detections
    failed: list[int] = dataclasses.field(default_factory=list)  # permanent
    corrections: list[CorrectionEvent] = dataclasses.field(default_factory=list)
    retries: list[RetryEvent] = dataclasses.field(default_factory=list)
    outages: list[OutageEvent] = dataclasses.field(default_factory=list)
    speculations: list[SpeculationEvent] = dataclasses.field(default_factory=list)
    checkpoints: list[CheckpointEvent] = dataclasses.field(default_factory=list)

    def queue_delays(self) -> list[float]:
        return [d.queue_delay for d in self.decisions]

    def plan_wall_s(self) -> list[float]:
        """Wall-clock decision latency of each flush (one entry per flush,
        not per task)."""
        seen: dict[int, float] = {}
        for d in self.decisions:
            seen[d.flush_id] = d.plan_wall_s
        return [seen[k] for k in sorted(seen)]


class SchedulingService:
    """Facade: arrival batching within a latency budget + online fallback,
    with optional deadlines/admission and tail re-planning.

    The service owns a :class:`MultiBatchScheduler` (the tail carrier);
    batch flushes go through its registered policy, online fallbacks are
    adopted into the same timeline via ``adopt_segment``.  Call ``drain()``
    when the stream ends to flush whatever is still pending.
    """

    def __init__(
        self,
        spec: DeviceSpec | ClusterSpec | None = None,
        policy: str = "far",
        config: SchedulerConfig | None = None,
        pool_size: int = 1,
        pool: DeviceSpec | ClusterSpec | None = None,
    ):
        """``spec`` is the classic single-device (or homogeneous
        ``pool_size``-GPU) entry point.  ``pool=`` supersedes it: pass a
        :class:`~repro.core.cluster.ClusterSpec` to serve a heterogeneous
        fleet (per-device seam tails, phase-0 flush partitioning), or a
        plain ``DeviceSpec`` as an alias for ``spec``."""
        if pool is not None:
            spec = pool
        if spec is None:
            raise ValueError("SchedulingService needs spec= or pool=")
        self.config = config or SchedulerConfig()
        self.policy = policy
        if isinstance(spec, ClusterSpec):
            self.cluster: ClusterSpec | None = spec
            self.spec = spec
            self.mb: MultiBatchScheduler | ClusterMultiBatchScheduler = \
                ClusterMultiBatchScheduler(
                    spec, policy=policy, config=self.config
                )
        else:
            self.cluster = None
            if pool_size > 1:
                spec = multi_gpu(spec, pool_size)
            self.spec = spec
            self.mb = MultiBatchScheduler(
                spec, policy=policy, config=self.config
            )
        # the never-replanned shadow chain: with replan on, every flush is
        # mirrored here exactly as replan=False would commit it, and the
        # reporting surface answers from whichever chain is ahead — the
        # makespan guarantee replan(stream) <= no-replan(stream) holds by
        # construction, not by hoping the per-flush heuristic composes.
        # Materialised lazily at the first accepted re-plan (until the
        # chains diverge the primary IS the shadow, so mirroring it would
        # just re-run the identical plan on every flush).
        self._baseline: MultiBatchScheduler | None = None
        self.pending: list[tuple[Task, float, float | None]] = []
        self.now = 0.0
        self.stats = ServiceStats()
        self._flush_id = 0
        self._deadlines: dict[int, float] = {}   # retained SLOs by task id
        self._arrivals: dict[int, float] = {}    # arrival stamps by task id
        # -- runtime feedback state -----------------------------------------
        self._tasks: dict[int, Task] = {}        # submitted tasks (for retry)
        self._completions: dict[int, float] = {}  # actual ends, as reported
        self._attempts: dict[int, int] = {}      # current attempt number
        self._requeue: list[tuple[float, int, Task, float | None]] = []
        self._rseq = 0                           # requeue heap tie-break
        self._parked: list[Task] = []            # awaiting device recovery
        # set on the first runtime deviation: the never-replanned shadow
        # is a counterfactual over profiled durations and cannot absorb
        # runtime truth, so it is dropped and never re-materialised
        self._fault_mode = False
        # -- speculation / checkpoint state ---------------------------------
        self._backups: dict[int, int] = {}       # primary id -> LIVE backup id
        self._backup_of: dict[int, int] = {}     # backup id -> primary (forever)
        self._spec_events: dict[int, int] = {}   # backup id -> stats index
        self._spec_seq = 0                       # backup id sequence
        self._progress: dict[int, float] = {}    # banked fraction of original
        self._attempt_base: dict[int, float] = {}  # progress the attempt began at
        self._primary_down: set[int] = set()     # primaries dead, backup racing

    # -- intake ------------------------------------------------------------
    def submit(
        self,
        task: Task,
        arrival: float | None = None,
        urgent: bool = False,
        deadline: float | None = None,
    ) -> str:
        """Queue ``task`` at virtual time ``arrival`` (default: now).

        Arrivals must be non-decreasing; ``urgent=True`` bypasses the
        batching budget and places the task immediately.  ``deadline``
        declares the task's SLO (absolute virtual time its completion is
        due); what an unmeetable one does depends on
        ``config.admission``.  Returns the intake verdict: ``"queued"``,
        ``"placed"`` (urgent), ``"demoted"`` or ``"rejected"``.
        """
        arrival = self.now if arrival is None else float(arrival)
        if arrival < self.now - 1e-9:
            raise ValueError(
                f"arrivals must be non-decreasing: {arrival} < {self.now}"
            )
        self._validate_task(task)
        task = self._maybe_transfer(task)
        if deadline is not None and float(deadline) < arrival - 1e-9:
            raise ValueError(
                f"task {task.id}: deadline {deadline} precedes its "
                f"arrival {arrival} — the SLO is unmeetable by "
                f"construction (pass deadline >= arrival)"
            )
        self.now = max(self.now, arrival)
        self._advance(self.now)
        self.stats.submitted += 1
        if self.cluster is not None and not self.cluster.supports(task):
            # no device of the pool fully covers the task's profile, so a
            # batch flush would fail mid-partitioning (and drop the whole
            # pending queue with it) — refuse at intake instead
            self.stats.rejected.append(task.id)
            return "rejected"
        verdict = "queued"
        if deadline is not None:
            deadline = float(deadline)
            verdict = self._admit(task, arrival, deadline)
            if verdict == "rejected":
                return verdict
            if verdict == "demoted":
                deadline = None
        self._arrivals[task.id] = arrival
        self._tasks[task.id] = task
        if deadline is not None:
            self._deadlines[task.id] = deadline
        if urgent:
            self._route_online([(task, arrival, deadline)],
                               decided_at=arrival)
            return "placed" if verdict == "queued" else verdict
        self.pending.append((task, arrival, deadline))
        if len(self.pending) >= self.config.max_batch:
            self._flush_pending(decided_at=arrival)
        return verdict

    def poll(self, now: float) -> None:
        """Advance virtual time with no submission (fires due flushes)."""
        if now < self.now - 1e-9:
            raise ValueError(f"time must be non-decreasing: {now} < {self.now}")
        self.now = max(self.now, now)
        self._advance(self.now)

    def flush(self) -> None:
        """Force-flush everything pending at the current virtual time."""
        if self.pending:
            self._flush_pending(decided_at=self.now)

    def drain(self) -> Schedule:
        """Flush pending tasks and return the combined schedule so far.

        Queued retries are played out first (virtual time advances to
        each backoff release), and tasks still parked on a quarantined
        device are reported **rejected** — a withdrawn task is never
        silently stranded."""
        while self._requeue:
            self.poll(max(self.now, self._requeue[0][0]))
            self.flush()
        self.flush()
        if self._parked:
            for task in self._parked:
                self.stats.rejected.append(task.id)
                # a rejected task has no completion and must not count
                # as a deadline miss (consistent with intake rejection)
                self._deadlines.pop(task.id, None)
            self._parked = []
        return self.combined_schedule()

    def _validate_task(self, task: Task) -> None:
        """API-boundary validation: an empty or non-positive profile
        would otherwise surface as an opaque failure deep inside a
        flush, taking the whole pending queue down with it."""
        entries = list(task.times.items())
        if not entries:
            raise ValueError(
                f"task {task.id} has an empty profile — no instance "
                f"type can host it"
            )
        for key, dur in entries:
            if not dur > 0.0:
                raise ValueError(
                    f"task {task.id} has non-positive duration {dur!r} "
                    f"for profile entry {key!r}; execution times must "
                    f"be strictly positive"
                )
        if task.checkpoint_period_s is not None \
                and not task.checkpoint_period_s > 0.0:
            raise ValueError(
                f"task {task.id} has non-positive checkpoint period "
                f"{task.checkpoint_period_s!r}"
            )

    # -- runtime feedback ---------------------------------------------------
    def report(
        self,
        task_id: int,
        event: str,
        t: float,
        end: float | None = None,
    ) -> None:
        """Feed runtime truth about a committed placement back in.

        ``event="completed"`` — the task actually finished at ``end``
        (default: ``t``, the report time).  An end matching the
        committed projection is a no-op; an early end frees capacity (a
        *shrink*, with an optional strict-win re-plan under
        ``config.replan``); a late end is a *stretch* — the conflicting
        tail is forced out and re-planned.  ``event="failed"`` — the
        attempt died at ``t``; its record is truncated into a failed
        occupancy slab and the task re-enters the queue through
        ``config.retry`` (or is reported permanently failed).  Either
        way the time advance runs straggler detection and fires any due
        flushes, exactly like :meth:`poll`.
        """
        t = float(t)
        if t < self.now - 1e-9:
            raise ValueError(f"time must be non-decreasing: {t} < {self.now}")
        self.now = max(self.now, t)
        if event not in ("completed", "failed"):
            raise ValueError(
                f"unknown runtime event {event!r}; expected 'completed' "
                f"or 'failed' (stragglers are detected implicitly via "
                f"config.straggler_factor)"
            )
        primary = self._backup_of.get(task_id)
        if primary is not None and self._backups.get(primary) == task_id:
            # runtime truth about a LIVE backup attempt resolves its race
            if event == "completed":
                self._backup_won(task_id, t, end)
            else:
                self._backup_failed(task_id, t)
            self._advance(self.now)
            return
        if event == "completed":
            bid = self._backups.get(task_id)
            if bid is not None:
                # the primary beat its backup: cancel the backup first so
                # the completion lands on a race-free timeline
                self._cancel_backup(bid, t, "primary")
            self._report_completed(task_id, t, end)
        else:
            if self._backups.get(task_id) is not None:
                self._primary_failed_racing(task_id, t)
            else:
                self._report_failed(task_id, t)
        self._advance(self.now)

    def _device_index(self, device) -> int:
        """Accept a pool index or the ``DeviceSpec`` itself."""
        if isinstance(device, int):
            return device
        for i, dev in enumerate(self.cluster.devices):
            if dev is device:
                return i
        raise ValueError(
            f"device {getattr(device, 'name', device)!r} is not in this "
            f"pool ({[d.name for d in self.cluster.devices]})"
        )

    def quarantine(self, device, t: float) -> list[int]:
        """Device(s) ``device`` of the pool are lost at time ``t``.

        ``device`` is a pool index, a ``DeviceSpec``, or — for a
        correlated failure *domain* — a sequence of either: every listed
        device is quarantined atomically before anything is re-planned,
        so a shared-shock outage exercises one joint survivor
        re-partition instead of N independent ones.

        Not-yet-started placements on the lost devices are withdrawn and
        re-partitioned onto the survivors via the flush partitioner
        (tasks no survivor supports are parked for :meth:`recover`);
        attempts RUNNING at ``t`` died and go through the retry path.
        Backup attempts caught in the outage are speculation-cancelled,
        never retried in their own right — the logical task's recovery
        routes through its primary.  Admission floors stop counting the
        devices until recovery.  Returns the ids of the attempts that
        died running.
        """
        t = float(t)
        if t < self.now - 1e-9:
            raise ValueError(f"time must be non-decreasing: {t} < {self.now}")
        if self.cluster is None:
            raise ValueError(
                "quarantine() needs a heterogeneous pool "
                "(SchedulingService(pool=cluster(...))): losing the only "
                "device leaves no surviving capacity to re-partition onto"
            )
        if isinstance(device, (list, tuple, set, frozenset)):
            # domain form: overlapping shocks may list an already-lost
            # device — skip it rather than refuse the whole domain
            devices = sorted({
                dev for dev in (self._device_index(d) for d in device)
                if self.mb.active[dev]
            })
            if not devices:
                return []
        else:
            devices = [self._device_index(device)]
        self.now = max(self.now, t)
        self._enter_fault_mode()
        # phase 1 — take every listed device down and truncate the
        # attempts that died on it, BEFORE any re-planning: the joint
        # re-partition must only see surviving capacity
        per_dev: list[tuple[int, list[Task], list[int]]] = []
        all_running: list[int] = []
        items_by_tid: dict[int, ScheduledTask] = {}
        for dev in devices:
            withdrawn, running = self.mb.quarantine_device(dev, t)
            per_dev.append((dev, withdrawn, running))
            for tid in running:
                it = self.mb.find_item(tid)
                items_by_tid[tid] = it
                self.mb.replace_item(
                    tid, end_override=max(t, it.begin), failed=True
                )
            all_running.extend(running)
        # phase 2 — resolve speculation races the outage decided.
        # Killed/withdrawn backups first: cancelling a backup routes its
        # down primary's retry, which must not race the primary's own
        # kill handling below.
        for _, _, running in per_dev:
            for tid in running:
                if tid in self._backup_of:
                    self._backup_caught_in_outage(
                        tid, t, item=items_by_tid[tid]
                    )
        replace: list[Task] = []
        for _, withdrawn, _ in per_dev:
            for task in withdrawn:
                if task.id in self._backup_of:
                    # a not-yet-started backup was withdrawn with the
                    # device: cancel the race, don't re-place it
                    if self._backups.get(self._backup_of[task.id]) \
                            == task.id:
                        self._backup_caught_in_outage(task.id, t, item=None)
                else:
                    replace.append(task)
        for _, _, running in per_dev:
            for tid in running:
                if tid in self._backup_of:
                    continue  # handled above
                if self._backups.get(tid) is not None:
                    # the primary died but its backup survives elsewhere:
                    # bank its checkpoints and let the backup carry the
                    # race — no retry unless the backup also dies
                    self._bank_checkpoints(tid, t, items_by_tid[tid])
                    self._primary_down.add(tid)
                else:
                    self._handle_failure(
                        tid, t, item=items_by_tid.get(tid)
                    )
        # phase 3 — one joint re-partition of everything withdrawn
        parked_before = len(self._parked)
        self._replace_tasks(replace, t)
        newly_parked = {
            task.id for task in self._parked[parked_before:]
        }
        for dev, withdrawn, running in per_dev:
            wd_ids = tuple(
                task.id for task in withdrawn
                if task.id not in self._backup_of
            )
            self.stats.outages.append(OutageEvent(
                dev, t, None,
                withdrawn=wd_ids,
                died_running=tuple(running),
                parked=tuple(
                    tid for tid in wd_ids if tid in newly_parked
                ),
            ))
        self._advance(self.now)
        return all_running

    def recover(self, device, t: float) -> None:
        """Quarantined device(s) ``device`` (index, ``DeviceSpec``, or a
        sequence of either — the same domain shape :meth:`quarantine`
        accepts) return to service at ``t``: each seam tail is floored at
        ``t`` (alive instances cleared — the outage reset the partition)
        and parked tasks that fit again are re-admitted and re-planned."""
        t = float(t)
        if t < self.now - 1e-9:
            raise ValueError(f"time must be non-decreasing: {t} < {self.now}")
        if self.cluster is None:
            raise ValueError("recover() needs a heterogeneous pool")
        if isinstance(device, (list, tuple, set, frozenset)):
            devices = sorted({
                dev for dev in (self._device_index(d) for d in device)
                if not self.mb.active[dev]
            })
            if not devices:
                return
        else:
            devices = [self._device_index(device)]
        self.now = max(self.now, t)
        for dev in devices:
            self.mb.recover_device(dev, t)
            for i in range(len(self.stats.outages) - 1, -1, -1):
                ev = self.stats.outages[i]
                if ev.device == dev and ev.recovered_at is None:
                    self.stats.outages[i] = dataclasses.replace(
                        ev, recovered_at=t
                    )
                    break
        if self._parked:
            still: list[Task] = []
            readmit: list[Task] = []
            for task in self._parked:
                (readmit if self._placeable_now(task)
                 else still).append(task)
            self._parked = still
            self._replace_tasks(readmit, t)
        self._advance(self.now)

    def committed_items(self) -> list[ScheduledTask]:
        """Live committed placements across all segments (failed
        occupancy records excluded)."""
        return [
            it for seg in self.mb.segments for it in seg.items
            if not it.failed
        ]

    def committed_item(self, task_id: int) -> ScheduledTask | None:
        """The live committed placement of ``task_id``, or None."""
        return self.mb.find_item(task_id)

    @property
    def completions(self) -> dict[int, float]:
        """Actual completion times reported so far (task id -> time)."""
        return dict(self._completions)

    def next_wakeup(self) -> float | None:
        """Earliest future virtual time at which internal state changes
        on its own — a budget flush coming due or a retry release.  The
        closed-loop harness idles to here when no runtime events are
        queued; None = nothing scheduled."""
        cands: list[float] = []
        if self.pending:
            cands.append(self.pending[0][1] + self.config.max_wait_s)
        if self._requeue:
            cands.append(self._requeue[0][0])
        return min(cands) if cands else None

    def _report_completed(
        self, task_id: int, t: float, end: float | None
    ) -> None:
        it = self.mb.find_item(task_id)
        if it is None:
            raise ValueError(
                f"task {task_id} has no live committed placement to "
                f"report on (never committed, withdrawn, or failed)"
            )
        if task_id in self._completions:
            raise ValueError(f"task {task_id} was already reported completed")
        actual = t if end is None else float(end)
        if actual > t + 1e-9:
            raise ValueError(
                f"completion end {actual} lies in the future of the "
                f"report time {t}"
            )
        if it.begin > t + EPS:
            raise ValueError(
                f"task {task_id} is not running at {t}: its committed "
                f"placement begins at {it.begin}"
            )
        if actual < it.begin - EPS:
            raise ValueError(
                f"completion end {actual} precedes task {task_id}'s "
                f"begin {it.begin}"
            )
        self._completions[task_id] = actual
        self.stats.completed += 1
        self._feed_calibration(it, actual)
        old_end = it.end  # current projection (may already carry a stretch)
        if abs(actual - old_end) <= 1e-9:
            return  # runtime matched the books exactly: nothing to correct
        self._enter_fault_mode()
        self.mb.replace_item(task_id, end_override=actual)
        if actual > old_end + EPS:
            withdrawn = self._forced_replan(t, task_id)
            kind = "stretch"
        else:
            withdrawn = ()
            kind = "shrink"
            if self.config.replan:
                self._strict_win_replan(t)
        self.stats.corrections.append(CorrectionEvent(
            task_id, t, kind, old_end, actual, withdrawn
        ))

    def _report_failed(self, task_id: int, t: float) -> None:
        it = self.mb.find_item(task_id)
        if it is None:
            raise ValueError(
                f"task {task_id} has no live committed placement to "
                f"report on (never committed, withdrawn, or failed)"
            )
        if task_id in self._completions:
            raise ValueError(f"task {task_id} was already reported completed")
        if it.begin > t + EPS:
            raise ValueError(
                f"task {task_id} is not running at {t}: its committed "
                f"placement begins at {it.begin}"
            )
        self._enter_fault_mode()
        old_end = it.end
        new_end = max(t, it.begin)
        self.mb.replace_item(task_id, end_override=new_end, failed=True)
        self.stats.corrections.append(CorrectionEvent(
            task_id, t, "failure", old_end, new_end, ()
        ))
        self._handle_failure(task_id, t, item=it)
        if self.config.replan:
            # the truncated attempt freed committed room — optional
            # strict-win reclaim, same rule as flush re-planning
            self._strict_win_replan(t)

    def _handle_failure(
        self, task_id: int, t: float, item: ScheduledTask | None = None
    ) -> None:
        """Route one failed attempt through the retry policy (or record
        it permanently failed).  ``item`` is the attempt's placement at
        the failure instant (when the caller has it): checkpoint
        credit earned by the dying attempt is banked from it, and the
        retry re-enters the queue as a *remainder* task resuming from
        the last checkpoint boundary."""
        progress = self._progress.get(task_id, 0.0)
        if item is not None:
            progress = self._bank_checkpoints(task_id, t, item)
        attempt = self._attempts.get(task_id, 1)
        retry = self.config.retry
        task = self._tasks.get(task_id)
        if retry is None or task is None or attempt >= retry.max_attempts:
            self.stats.failed.append(task_id)
            return
        nxt = attempt + 1
        self._attempts[task_id] = nxt
        base_prev = self._attempt_base.get(task_id, 0.0)
        if progress > base_prev + 1e-12:
            # the dying attempt carried the task from base_prev to
            # `progress` of the ORIGINAL work; its profile covered
            # (1 - base_prev), so the relative remainder shrinks the
            # CURRENT task (composing with any earlier demotion)
            rel = (1.0 - progress) / (1.0 - base_prev)
            task = remainder_task(task, rel)
            self._tasks[task_id] = task
            self._attempt_base[task_id] = progress
        demoted = False
        if retry.demote is not None:
            cand = retry.task_for_attempt(task, nxt)
            # demotion must keep the task placeable on the pool — a
            # shrunken profile that no device fully covers would blow
            # up the flush partitioner, so it is skipped
            if cand is not task and self._coverable(cand):
                task = cand
                demoted = True
                self._tasks[task_id] = task
        release = t + retry.backoff(attempt)
        self._rseq += 1
        heapq.heappush(
            self._requeue,
            (release, self._rseq, task, self._deadlines.get(task_id)),
        )
        self.stats.retries.append(RetryEvent(
            task_id, nxt, t, release, demoted
        ))

    def _check_stragglers(self, now: float) -> None:
        """Implicit straggler detection: a running placement whose
        observed runtime exceeds ``straggler_factor`` times its profiled
        duration without a completion report has its projected end
        stretched to ``now + (factor - 1) * profile`` and the
        conflicting tail force-re-planned.  Re-fires geometrically while
        the attempt keeps running past each new projection."""
        factor = self.config.straggler_factor
        candidates = [
            it.task.id for it in self.committed_items()
            if it.task.id not in self._completions
            and it.begin <= now - EPS
            and now > it.begin + factor * it.planned_duration + 1e-9
            and it.end <= now + 1e-9
        ]
        for tid in candidates:
            it = self.mb.find_item(tid)
            if it is None or it.failed:
                continue  # a previous iteration's re-plan resolved it
            if now <= it.begin + factor * it.planned_duration + 1e-9 \
                    or it.end > now + 1e-9:
                continue
            self._enter_fault_mode()
            old_end = it.end
            new_end = now + (factor - 1.0) * it.planned_duration
            self.mb.replace_item(tid, end_override=new_end)
            withdrawn = self._forced_replan(now, tid)
            self.stats.stragglers += 1
            self.stats.corrections.append(CorrectionEvent(
                tid, now, "straggler", old_end, new_end, withdrawn
            ))
            self._maybe_speculate(tid, now)

    def _forced_replan(self, t: float, corrected_tid: int) -> tuple[int, ...]:
        """After a stretch the committed tail may be invalid (successors
        of the stretched item were planned against its old end): pull
        back everything not yet started plus any *unreported* placement
        now overlapping the stretched record, and re-plan the lot at
        ``t``.  Placements already reported completed keep their records
        — runtime truth is never rewritten (the invariant harness
        sanctions overlapping pairs of *corrected* records as feedback
        races; planned records never overlap)."""
        wd = self.mb.withdraw_uncommitted(t)
        it = self.mb.find_item(corrected_tid)
        if it is not None:
            cells = set(it.node.blocked_cells)
            phantoms = {
                o.task.id for o in self.committed_items()
                if o.task.id != corrected_tid
                and o.task.id not in self._completions
                and o.begin < it.end - EPS and o.end > it.begin + EPS
                and cells & set(o.node.blocked_cells)
            }
            if phantoms:
                wd = wd + self.mb.remove_items(phantoms)
        self._replace_tasks(wd, t)
        return tuple(task.id for task in wd)

    def _replace_tasks(self, tasks: list[Task], t: float) -> None:
        """Re-plan withdrawn tasks at time ``t`` (the fault path: forced
        re-plans and device loss).  Tasks no active device supports are
        parked for recovery."""
        if not tasks:
            return
        placeable: list[Task] = []
        for task in tasks:
            if self._placeable_now(task):
                placeable.append(task)
            else:
                self._parked.append(task)
        if not placeable:
            return
        t0 = time.perf_counter()
        self.mb.add_batch(self._plan_tasks(placeable), not_before=t,
                          deadlines=self._edf_deadlines(placeable))
        wall = time.perf_counter() - t0
        fid = self._next_flush_id()
        for task in placeable:
            self.stats.decisions.append(Decision(
                task.id, self._arrivals.get(task.id, t), t, "fault",
                fid, wall, deadline=self._deadlines.get(task.id),
            ))
        self._attach_deadline_extras(placeable)

    def _placeable_now(self, task: Task) -> bool:
        if self.cluster is not None:
            return self.mb.supports_active(task)
        return True

    def _coverable(self, task: Task) -> bool:
        """Whether the (possibly demoted) task can still be planned —
        full profile coverage of some pool device, or of the single
        device's size set (FAR molds over the whole C_G)."""
        if self.cluster is not None:
            return self.cluster.supports(task)
        try:
            times = task.times_for(self.spec.device_kind)
        except KeyError:
            return False
        return all(s in times for s in self.spec.sizes)

    def _enter_fault_mode(self) -> None:
        if self._fault_mode:
            return
        self._fault_mode = True
        # the never-replanned shadow is a counterfactual over PROFILED
        # durations; once runtime truth lands it can no longer answer
        # for the stream — the primary chain carries the corrections
        self._baseline = None

    def _strict_win_replan(self, t: float) -> None:
        """Optional capacity-reclaim re-plan after a shrink/failure
        freed committed room, under the same strict-win rule as flush
        re-planning (only in fault mode, so no shadow mirroring)."""
        trial = self.mb.clone()
        wd = trial.withdraw_uncommitted(t)
        if not wd:
            return
        if any(not self._placeable_now(task) for task in wd):
            return  # mid-outage: the optional reclaim is not worth a park
        self.stats.replan_attempts += 1
        t0 = time.perf_counter()
        plain_makespan = self.mb.makespan
        trial.add_batch(self._plan_tasks(wd), not_before=t,
                        deadlines=self._edf_deadlines(wd))
        if trial.makespan >= plain_makespan - self.config.eps:
            return
        wall = time.perf_counter() - t0
        fid = self._next_flush_id()
        self.mb = trial
        self.stats.replan_wins += 1
        self.stats.withdrawn += len(wd)
        for task in wd:
            self.stats.decisions.append(Decision(
                task.id, self._arrivals.get(task.id, t), t, "replan",
                fid, wall, deadline=self._deadlines.get(task.id),
            ))
        self.stats.replan_events.append(ReplanEvent(
            fid, t, tuple(task.id for task in wd),
            trial.makespan, plain_makespan,
        ))

    # -- speculation / checkpoint credit / calibration ---------------------
    def true_duration(self, item: ScheduledTask) -> float:
        """The RAW profiled duration of ``item``'s placement — from the
        stored (uncalibrated) task, looked up by the placement's device
        kind and size.  The committed item may carry a calibrated task
        (``config.calibration`` rewrites profiles at the policy
        boundary), so harnesses that model ground truth must draw from
        here, not from ``item.planned_duration`` (the belief)."""
        task = self._tasks.get(item.task.id)
        if task is None:
            return item.planned_duration
        if self.cluster is not None:
            dev = self.cluster.devices[
                self.cluster.tree_device[item.node.tree]
            ]
            kind = dev.device_kind
        else:
            kind = self.spec.device_kind
        try:
            times = task.times_for(kind)
        except (KeyError, ValueError):
            return item.planned_duration
        dur = times.get(item.size)
        return item.planned_duration if dur is None else float(dur)

    def _plan_tasks(self, tasks: list[Task]) -> list[Task]:
        """Apply online profile calibration at the policy boundary: the
        planner sees EWMA-corrected durations, while the stored tasks
        (and therefore retries, ground-truth draws, and the exactly-once
        books) keep their raw profiles.  With ``config.calibration``
        unset this returns ``tasks`` unchanged — same list object, so
        the calibration-off service is bit-identical to PR 6."""
        cal = self.config.calibration
        if cal is None:
            return tasks
        kind = None if self.cluster is not None \
            else self.spec.device_kind
        return [
            cal.calibrate(self._tasks.get(task.id, task), kind=kind)
            for task in tasks
        ]

    def _calibrated_batch(self, batch):
        """The tuple-shaped sibling of :meth:`_plan_tasks` for the
        online-routing path (task, arrival, deadline)."""
        cal = self.config.calibration
        if cal is None:
            return batch
        kind = None if self.cluster is not None \
            else self.spec.device_kind
        return [
            (cal.calibrate(self._tasks.get(task.id, task), kind=kind),
             arrival, deadline)
            for task, arrival, deadline in batch
        ]

    def _feed_calibration(self, item: ScheduledTask, actual: float) -> None:
        """One completion report becomes one EWMA observation: the raw
        profiled duration vs the observed one, keyed by (task family,
        device kind, size)."""
        cal = self.config.calibration
        if cal is None:
            return
        task = self._tasks.get(item.task.id)
        if task is None:
            return
        if self.cluster is not None:
            dev = self.cluster.devices[
                self.cluster.tree_device[item.node.tree]
            ]
            kind = dev.device_kind
        else:
            kind = self.spec.device_kind
        planned = self.true_duration(item)
        observed = actual - item.begin
        if planned > 0.0 and observed > 0.0:
            cal.observe(task, kind, item.size, planned, observed)

    def _maybe_transfer(self, task: Task) -> Task:
        """Profile-transfer fallback at intake: derive the task's missing
        ``(device_kind, size)`` entries from its nearest measured kind,
        scaled by the per-kind relative speed (``config.profile_transfer``
        as a mapping; ``True`` = unit factors).  Measured entries always
        win; a task with nothing to transfer from still raises
        :class:`~repro.core.problem.ProfileCoverageError`."""
        if not self.config.profile_transfer:
            return task
        pt = self.config.profile_transfer
        speed = pt if isinstance(pt, dict) else None
        if self.cluster is not None:
            merged: dict[str, set] = {}
            for dev in self.cluster.devices:
                merged.setdefault(dev.device_kind, set()).update(dev.sizes)
            kind_sizes = {
                kind: tuple(sorted(sizes))
                for kind, sizes in merged.items()
            }
        else:
            kind_sizes = {
                self.spec.device_kind: tuple(self.spec.sizes)
            }
        return transfer_profile(task, kind_sizes, speed=speed)

    def _bank_checkpoints(
        self,
        attempt_id: int,
        t: float,
        item: ScheduledTask,
        target: int | None = None,
    ) -> float:
        """Bank the checkpoint credit a dying attempt earned and return
        the target task's cumulative progress fraction.

        ``attempt_id`` is the record that just died (a primary id or a
        backup id); ``target`` is the logical task the credit accrues to
        (defaults to the attempt itself).  Credit is the completed
        checkpoint periods of the attempt's RAW planned duration,
        composed onto the progress the attempt started from — and the
        cumulative fraction is monotone (a later bank never lowers it),
        so replayed or overlapping failure paths can never double-count.
        """
        if target is None:
            target = attempt_id
        old = self._progress.get(target, 0.0)
        task = self._tasks.get(attempt_id)
        if task is None or task.checkpoint_period_s is None:
            return old
        period = float(task.checkpoint_period_s)
        planned = self.true_duration(item)
        elapsed = max(0.0, t - item.begin)
        credit = math.floor((elapsed + 1e-9) / period) * period
        if credit <= 0.0 or planned <= 0.0:
            return old
        frac = min(credit / planned, 1.0 - 1e-9)
        base = self._attempt_base.get(attempt_id, 0.0)
        cand = base + (1.0 - base) * frac
        if cand <= old + 1e-12:
            return old
        self._progress[target] = cand
        self.stats.checkpoints.append(CheckpointEvent(
            target, self._attempts.get(target, 1), t,
            credit_s=credit, progress=cand,
        ))
        return cand

    def _maybe_speculate(self, tid: int, now: float) -> None:
        """Straggler hook: race a backup attempt against the stretched
        primary on the best alternative placement, if the books prove a
        gain of at least ``speculation.min_gain_s`` and the in-flight
        throttle has room.  First finisher wins; the loser's record is
        truncated into a failed occupancy slab."""
        pol = self.config.speculation
        if pol is None:
            return
        if tid in self._backup_of or tid in self._backups:
            return  # backups don't speculate; one race per task
        if len(self._backups) >= pol.max_inflight:
            return
        task = self._tasks.get(tid)
        if task is None or tid in self._completions:
            return
        it_p = self.mb.find_item(tid)
        if it_p is None or it_p.failed:
            return
        primary_end = it_p.end  # the just-stretched projection
        backup = task
        base = self._attempt_base.get(tid, 0.0)
        if task.checkpoint_period_s is not None:
            # the backup resumes from the primary's last checkpoint
            # boundary, not from zero: shrink its profile to the true
            # remainder and remember the progress it starts from
            period = float(task.checkpoint_period_s)
            planned = self.true_duration(it_p)
            elapsed = max(0.0, now - it_p.begin)
            credit = math.floor((elapsed + 1e-9) / period) * period
            if credit > 0.0 and planned > 0.0:
                frac = min(credit / planned, 1.0 - 1e-9)
                backup = remainder_task(task, 1.0 - frac)
                base = base + (1.0 - base) * frac
        if not (self._coverable(backup) and self._placeable_now(backup)):
            return
        # admissible pre-filter: if even the provable floor cannot beat
        # the stretched primary by min_gain_s, skip the trial plan
        if self.completion_lower_bound(backup, now) \
                >= primary_end - pol.min_gain_s:
            return
        self._spec_seq += 1
        bid = _BACKUP_ID_BASE + self._spec_seq
        backup = dataclasses.replace(backup, id=bid)
        self._tasks[bid] = backup
        t0 = time.perf_counter()
        trial = self.mb.clone()
        try:
            trial.online_place(
                self._calibrated_batch([(backup, now, None)]), now
            )
        except (AssertionError, ValueError):
            self._tasks.pop(bid, None)
            return
        it_b = trial.find_item(bid)
        if it_b is None or it_b.end >= primary_end - pol.min_gain_s:
            # the trial could not realise the provable gain (capacity is
            # busier than the floor): drop the clone, no race
            self._tasks.pop(bid, None)
            return
        wall = time.perf_counter() - t0
        self.mb = trial
        self._arrivals[bid] = now
        self._attempt_base[bid] = base
        self._backups[tid] = bid
        self._backup_of[bid] = tid
        self._spec_events[bid] = len(self.stats.speculations)
        self.stats.speculations.append(SpeculationEvent(
            tid, bid, now, primary_end, it_b.end
        ))
        self.stats.decisions.append(Decision(
            bid, now, now, "speculate", self._next_flush_id(), wall,
        ))

    def _resolve_spec_event(self, bid: int, t: float, winner: str) -> None:
        i = self._spec_events.get(bid)
        if i is None:
            return
        self.stats.speculations[i] = dataclasses.replace(
            self.stats.speculations[i], winner=winner, resolved_at=t
        )

    def _backup_won(self, bid: int, t: float, end: float | None) -> None:
        """The backup attempt finished first: its record is re-keyed to
        the logical task (exactly one completion record survives), the
        primary's record is truncated into a failed occupancy slab, and
        the correction machinery runs against the backup's projection."""
        primary = self._backup_of[bid]
        it_b = self.mb.find_item(bid)
        if it_b is None:
            raise ValueError(
                f"backup attempt {bid} has no live committed placement"
            )
        if primary in self._completions:
            raise ValueError(
                f"task {primary} was already reported completed"
            )
        actual = t if end is None else float(end)
        if actual > t + 1e-9:
            raise ValueError(
                f"completion end {actual} lies in the future of the "
                f"report time {t}"
            )
        if it_b.begin > t + EPS:
            raise ValueError(
                f"backup {bid} is not running at {t}: its committed "
                f"placement begins at {it_b.begin}"
            )
        if actual < it_b.begin - EPS:
            raise ValueError(
                f"completion end {actual} precedes backup {bid}'s "
                f"begin {it_b.begin}"
            )
        self._enter_fault_mode()
        self._feed_calibration(it_b, actual)
        it_p = self.mb.find_item(primary)
        if it_p is not None:
            # the loser: cancelled, kept as an occupancy record
            old_p = it_p.end
            new_p = max(t, it_p.begin)
            self.mb.replace_item(primary, end_override=new_p, failed=True)
            self.stats.corrections.append(CorrectionEvent(
                primary, t, "failure", old_p, new_p, ()
            ))
        old_end = it_b.end
        winner_task = dataclasses.replace(it_b.task, id=primary)
        self.mb.relabel_item(bid, winner_task, end_override=actual)
        self._completions[primary] = actual
        self.stats.completed += 1
        self._backups.pop(primary, None)
        self._primary_down.discard(primary)
        self._resolve_spec_event(bid, t, "backup")
        if abs(actual - old_end) <= 1e-9:
            return
        if actual > old_end + EPS:
            withdrawn = self._forced_replan(t, primary)
            kind = "stretch"
        else:
            withdrawn = ()
            kind = "shrink"
            if self.config.replan:
                self._strict_win_replan(t)
        self.stats.corrections.append(CorrectionEvent(
            primary, t, kind, old_end, actual, withdrawn
        ))

    def _backup_failed(self, bid: int, t: float) -> None:
        """The backup attempt itself died (execution failure): resolve
        the race as cancelled, bank any checkpoint credit it earned for
        the primary, and — if the primary already failed while racing —
        route the primary's retry now."""
        primary = self._backup_of[bid]
        it = self.mb.find_item(bid)
        if it is None:
            raise ValueError(
                f"backup attempt {bid} has no live committed placement"
            )
        self._enter_fault_mode()
        if it.begin > t + EPS:
            self.mb.remove_items({bid})
        else:
            old_end = it.end
            new_end = max(t, it.begin)
            self.mb.replace_item(bid, end_override=new_end, failed=True)
            self.stats.corrections.append(CorrectionEvent(
                bid, t, "failure", old_end, new_end, ()
            ))
            self._bank_checkpoints(bid, t, it, target=primary)
        self._backups.pop(primary, None)
        self._resolve_spec_event(bid, t, "cancelled")
        if primary in self._primary_down:
            self._primary_down.discard(primary)
            self._handle_failure(primary, t)
        if self.config.replan:
            self._strict_win_replan(t)

    def _cancel_backup(self, bid: int, t: float, winner: str) -> None:
        """Cancel a live backup because its race resolved elsewhere (the
        primary completed, or an outage withdrew the backup unstarted).
        A begun backup leaves a failed occupancy record and banks its
        checkpoint credit; an unstarted one is removed outright."""
        primary = self._backup_of[bid]
        it = self.mb.find_item(bid)
        if it is not None:
            if it.begin > t + EPS:
                self.mb.remove_items({bid})
            else:
                self._enter_fault_mode()
                self.mb.replace_item(
                    bid, end_override=max(t, it.begin), failed=True
                )
                self._bank_checkpoints(bid, t, it, target=primary)
        self._backups.pop(primary, None)
        self._resolve_spec_event(bid, t, winner)

    def _backup_caught_in_outage(
        self, bid: int, t: float, item: ScheduledTask | None
    ) -> None:
        """A device loss took the backup down (running — ``item`` is its
        pre-truncation record — or withdrawn unstarted).  The race
        resolves as cancelled; the backup is NEVER retried in its own
        right — if its primary already failed, the primary's retry is
        routed instead."""
        primary = self._backup_of[bid]
        if self._backups.get(primary) != bid:
            return  # a stale id from an already-resolved race
        if item is not None:
            self._bank_checkpoints(bid, t, item, target=primary)
        self._backups.pop(primary, None)
        self._resolve_spec_event(bid, t, "cancelled")
        if primary in self._primary_down:
            self._primary_down.discard(primary)
            self._handle_failure(primary, t)

    def _primary_failed_racing(self, tid: int, t: float) -> None:
        """The primary died while its backup races on: truncate the
        primary's record and bank its credit, but do NOT requeue — the
        backup is the recovery.  Only if the backup also dies does the
        task fall back to the retry path (see :meth:`_backup_failed`)."""
        it = self.mb.find_item(tid)
        if it is None:
            raise ValueError(
                f"task {tid} has no live committed placement to "
                f"report on (never committed, withdrawn, or failed)"
            )
        if tid in self._completions:
            raise ValueError(f"task {tid} was already reported completed")
        if it.begin > t + EPS:
            raise ValueError(
                f"task {tid} is not running at {t}: its committed "
                f"placement begins at {it.begin}"
            )
        self._enter_fault_mode()
        old_end = it.end
        new_end = max(t, it.begin)
        self.mb.replace_item(tid, end_override=new_end, failed=True)
        self.stats.corrections.append(CorrectionEvent(
            tid, t, "failure", old_end, new_end, ()
        ))
        self._bank_checkpoints(tid, t, it)
        self._primary_down.add(tid)
        if self.config.replan:
            self._strict_win_replan(t)

    # -- admission ---------------------------------------------------------
    def completion_lower_bound(self, task: Task, at: float) -> float:
        """Provable floor on ``task``'s completion if submitted at ``at``.

        Placements are causal (nothing begins before the decision that
        placed it, and the decision is no earlier than the arrival) and
        running work is never preempted, so a feasible instance cannot
        host the task before every slice it blocks clears of the work
        already *running* at ``at``.  Queued-but-unstarted placements are
        ignored (re-planning may pull them back), as are creation costs
        and queueing — the bound stays admissible.  With re-planning the
        service may report either the re-planning chain or the
        never-replanned shadow, so the bound is the minimum over both:
        no schedule the service can still produce finishes the task
        earlier, whichever chain wins.
        """
        best = self._chain_lower_bound(self.mb, task, at)
        if self._baseline is not None:
            best = min(
                best, self._chain_lower_bound(self._baseline, task, at)
            )
        return best

    def _node_candidates(self, task: Task):
        """(instance node, size-keyed times) pairs the task could run on —
        every node of the single device, or every supported device of the
        pool with the task's times lowered onto that device's kind."""
        if self.cluster is not None:
            devices = [
                dev for i, dev in enumerate(self.cluster.devices)
                if self.mb.active[i]  # quarantined capacity doesn't count
            ]
        else:
            devices = (self.spec,)
        for dev in devices:
            if not task.supports(dev.device_kind):
                continue
            times = task.times_for(dev.device_kind)
            for node in dev.nodes:
                if node.size in times:
                    yield node, times

    def _chain_lower_bound(self, mb, task: Task, at: float) -> float:
        busy: dict[tuple[int, int], float] = {}
        for seg in mb.segments:
            if seg.makespan <= at:
                continue  # fully finished by `at`: nothing still running
            for it in seg.items:
                if it.begin <= at + EPS and it.end > at:
                    for cell in it.node.blocked_cells:
                        if it.end > busy.get(cell, 0.0):
                            busy[cell] = it.end
        return completion_floor(self._node_candidates(task), busy, at)

    def _admit(self, task: Task, arrival: float, deadline: float) -> str:
        if self.config.admission == "none":
            return "queued"
        if self.completion_lower_bound(task, arrival) <= deadline + EPS:
            return "queued"
        if self.config.admission == "reject":
            self.stats.rejected.append(task.id)
            return "rejected"
        self.stats.demoted.append(task.id)
        return "demoted"

    # -- internals ---------------------------------------------------------
    def _advance(self, now: float) -> None:
        if self.config.straggler_factor is not None:
            self._check_stragglers(now)
        self._release_due(now)
        self._advance_budget(now)

    def _advance_budget(self, now: float) -> None:
        # every pending task arrived within max_wait_s of the oldest (any
        # later arrival would have fired this flush first), so one deadline
        # empties the whole queue
        if self.pending and now - self.pending[0][1] >= self.config.max_wait_s:
            deadline = self.pending[0][1] + self.config.max_wait_s
            self._flush_pending(decided_at=deadline)

    def _release_due(self, now: float) -> None:
        """Move retries whose backoff floor has passed into the pending
        queue, in release order, firing any budget flush due *before*
        each release — the same discipline ``submit`` follows, so the
        flush-decision invariant (every pending task arrived within
        max_wait_s of the oldest) keeps holding."""
        while self._requeue and self._requeue[0][0] <= now + 1e-12:
            release, _, task, deadline = heapq.heappop(self._requeue)
            self._advance_budget(release)
            self._arrivals[task.id] = release  # the retry's re-arrival
            self.pending.append((task, release, deadline))
            if len(self.pending) >= self.config.max_batch:
                self._flush_pending(decided_at=release)

    def _flush_pending(self, decided_at: float) -> None:
        batch, self.pending = self.pending, []
        batch = self._park_unplaceable(batch)
        if not batch:
            return
        if len(batch) < self.config.min_batch:
            # slow trickle: too few tasks accumulated within the budget for
            # an offline batch to pay off — place them greedily instead
            self._route_online(batch, decided_at)
            return
        t0 = time.perf_counter()
        arrivals = self._plan_tasks([task for task, _, _ in batch])
        if self._baseline is not None:  # chains diverged: mirror the flush
            self._baseline.add_batch(
                arrivals, not_before=decided_at,
                deadlines=self._edf_deadlines(arrivals),
            )
        # nothing may start before the flush decision that placed it
        withdrawn, plain_makespan = self._flush_batch(arrivals, decided_at)
        wall = time.perf_counter() - t0
        fid = self._next_flush_id()
        self.stats.batches += 1
        for task, arrival, deadline in batch:
            self.stats.decisions.append(Decision(
                task.id, arrival, decided_at, "batch", fid, wall,
                deadline=deadline,
            ))
        for task in withdrawn:
            self.stats.decisions.append(Decision(
                task.id, self._arrivals.get(task.id, decided_at), decided_at,
                "replan", fid, wall,
                deadline=self._deadlines.get(task.id),
            ))
        self._attach_deadline_extras(arrivals + withdrawn)
        if withdrawn:
            self.stats.replan_events.append(ReplanEvent(
                fid, decided_at, tuple(t.id for t in withdrawn),
                self.mb.makespan, plain_makespan,
            ))

    def _flush_batch(self, arrivals: list[Task], decided_at: float
                     ) -> tuple[list[Task], float]:
        """Commit one batch flush on the primary chain; returns the tasks
        a kept re-plan pulled back (empty without ``config.replan``) and
        the plain candidate's combined makespan for the event log."""
        if not self.config.replan:
            self.mb.add_batch(arrivals, not_before=decided_at,
                              deadlines=self._edf_deadlines(arrivals))
            return [], 0.0
        # candidate A — the plain flush: arrivals against the committed tail
        plain = self.mb.clone()
        plain.add_batch(arrivals, not_before=decided_at,
                        deadlines=self._edf_deadlines(arrivals))
        # candidate B — the re-plan: pull the not-yet-started tail back and
        # schedule it together with the arrivals under the same policy
        trial = self.mb.clone()
        withdrawn = trial.withdraw_uncommitted(decided_at)
        if not withdrawn:
            # nothing to revisit: the flush is bit-identical to replan=False
            self.mb = plain
            return [], 0.0
        self.stats.replan_attempts += 1
        replanned = self._plan_tasks(withdrawn) + arrivals
        trial.add_batch(replanned, not_before=decided_at,
                        deadlines=self._edf_deadlines(replanned))
        if trial.makespan < plain.makespan - self.config.eps:
            if self._baseline is None and not self._fault_mode:
                # first divergence: the plain candidate IS the
                # never-replanned continuation — it becomes the shadow
                # (not in fault mode: the shadow is a profiled-duration
                # counterfactual and runtime truth has already landed)
                self._baseline = plain
            self.mb = trial
            self.stats.replan_wins += 1
            self.stats.withdrawn += len(withdrawn)
            return withdrawn, plain.makespan
        self.mb = plain
        return [], 0.0

    def _edf_deadlines(self, tasks: Sequence[Task]) -> dict[int, float] | None:
        """The deadline map a flush hands to ``add_batch`` when EDF
        within-batch ordering is on — ``None`` (bit-identical commit
        order) when ``config.edf`` is off or no task of the batch
        retained an SLO."""
        if not self.config.edf:
            return None
        deadlines = {
            t.id: self._deadlines[t.id] for t in tasks
            if t.id in self._deadlines
        }
        return deadlines or None

    def _attach_deadline_extras(self, tasks: Sequence[Task]) -> None:
        """Record the flushed batch's SLO picture on its PlanResult: the
        retained deadlines and each one's slack against the planned
        completion (negative slack = the plan already misses it)."""
        deadlines = {
            t.id: self._deadlines[t.id] for t in tasks
            if t.id in self._deadlines
        }
        if not deadlines or not self.mb.results:
            return
        # only the just-flushed placements are needed (the deadlines dict
        # is restricted to this batch) — rebuilding the whole combined
        # schedule here would make a long-running service O(F^2)
        ends: dict[int, float] = {}
        for it in self.mb.last_flush_items():
            ends[it.task.id] = it.end
        plan = self.mb.results[-1]
        plan.extras["deadlines"] = deadlines
        plan.extras["deadline_slack"] = {
            tid: dl - ends[tid] for tid, dl in deadlines.items()
            if tid in ends
        }

    def _route_online(
        self,
        batch: Sequence[tuple[Task, float, float | None]],
        decided_at: float,
    ) -> None:
        batch = self._park_unplaceable(batch)
        if not batch:
            return
        batch = self._calibrated_batch(batch)
        t0 = time.perf_counter()
        withdrawn: list[Task] = []
        plain_makespan = 0.0
        mirror_batch = True  # whether the shadow still needs this trickle
        if self.config.replan:
            # the same two-candidate strict-win rule as a batch flush: a
            # trickle with a withdrawable tail behind it can still pull
            # the tail back (the fault path depends on this — a
            # straggler report during a trickle can rescue deadline
            # work).  With no tail to pull back this reduces to the
            # plain greedy placement, bit-identically.
            plain = self.mb.clone()
            plain.online_place(batch, decided_at)
            trial = self.mb.clone()
            wd = trial.withdraw_uncommitted(decided_at)
            if wd:
                self.stats.replan_attempts += 1
                replanned = self._plan_tasks(wd) + [
                    task for task, _, _ in batch
                ]
                trial.add_batch(replanned, not_before=decided_at,
                                deadlines=self._edf_deadlines(replanned))
                if trial.makespan < plain.makespan - self.config.eps:
                    if self._baseline is None and not self._fault_mode:
                        self._baseline = plain
                        mirror_batch = False  # plain already carries it
                    self.mb = trial
                    withdrawn = wd
                    plain_makespan = plain.makespan
                    self.stats.replan_wins += 1
                    self.stats.withdrawn += len(wd)
                else:
                    self.mb = plain
            else:
                self.mb = plain
        else:
            # polymorphic: MultiBatchScheduler floors its single tail and
            # greedy-places; ClusterMultiBatchScheduler additionally picks
            # a device per task via speculative greedy previews
            self.mb.online_place(batch, decided_at)
        if self._baseline is not None and mirror_batch:
            self._baseline.online_place(batch, decided_at)
        wall = time.perf_counter() - t0
        fid = self._next_flush_id()
        if withdrawn:
            # the trickle was absorbed into a batch re-plan
            self.stats.batches += 1
            for task, arrival, deadline in batch:
                self.stats.decisions.append(Decision(
                    task.id, arrival, decided_at, "batch", fid, wall,
                    deadline=deadline,
                ))
            for task in withdrawn:
                self.stats.decisions.append(Decision(
                    task.id, self._arrivals.get(task.id, decided_at),
                    decided_at, "replan", fid, wall,
                    deadline=self._deadlines.get(task.id),
                ))
            self._attach_deadline_extras(
                [task for task, _, _ in batch] + withdrawn
            )
            self.stats.replan_events.append(ReplanEvent(
                fid, decided_at, tuple(t.id for t in withdrawn),
                self.mb.makespan, plain_makespan,
            ))
            return
        self.stats.online_placements += len(batch)
        for task, arrival, deadline in batch:
            self.stats.decisions.append(Decision(
                task.id, arrival, decided_at, "online", fid, wall,
                deadline=deadline,
            ))

    def _park_unplaceable(
        self, batch: Sequence[tuple[Task, float, float | None]]
    ) -> list[tuple[Task, float, float | None]]:
        """During a pool outage, hold back tasks no *surviving* device
        supports (they passed intake against the full pool): they park
        until the device recovers instead of blowing up the flush."""
        if self.cluster is None or all(self.mb.active):
            return list(batch)
        live: list[tuple[Task, float, float | None]] = []
        for item in batch:
            if self.mb.supports_active(item[0]):
                live.append(item)
            else:
                self._parked.append(item[0])
        return live

    def _next_flush_id(self) -> int:
        self._flush_id += 1
        return self._flush_id

    # -- reporting ---------------------------------------------------------
    @property
    def _winner(self) -> MultiBatchScheduler:
        """The chain every report answers from: the re-planning chain,
        unless the never-replanned shadow is strictly ahead."""
        if self._baseline is not None \
                and self._baseline.makespan < self.mb.makespan:
            return self._baseline
        return self.mb

    @property
    def makespan(self) -> float:
        return self._winner.makespan

    @property
    def tail(self):
        return self._winner.tail

    def combined_schedule(self) -> Schedule:
        return self._winner.combined_schedule()

    def deadline_report(self) -> dict:
        """Score the retained deadlines against the combined schedule —
        meaningful after :meth:`drain` (a task still pending counts as a
        miss: it has no completion).  Demoted and rejected tasks are
        reported separately and never count as misses.  Runtime truth
        wins: reported completions overlay the projections, and
        permanently failed tasks always count as misses."""
        ends: dict[int, float] = {}
        for it in self.combined_schedule().items:
            if not it.failed:
                ends[it.task.id] = it.end
        ends.update(self._completions)
        failed = set(self.stats.failed)
        missed = sorted(
            tid for tid, dl in self._deadlines.items()
            if tid in failed or ends.get(tid, math.inf) > dl + EPS
        )
        tracked = len(self._deadlines)
        return {
            "tracked": tracked,
            "missed": missed,
            "miss_rate": len(missed) / tracked if tracked else 0.0,
            "rejected": sorted(self.stats.rejected),
            "demoted": sorted(self.stats.demoted),
            "failed": sorted(failed),
        }


__all__ = [
    "SchedulingService",
    "ServiceStats",
    "Decision",
    "ReplanEvent",
    "CorrectionEvent",
    "RetryEvent",
    "OutageEvent",
    "SpeculationEvent",
    "CheckpointEvent",
]
