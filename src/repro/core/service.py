"""Arrival-driven scheduling service with a latency budget, per-task
deadlines and tail re-planning (ROADMAP "online serving at scale"; cf.
Tan et al., serving DNN models on MIG, arXiv:2109.11067).

The paper's offline formulation needs batches; a serving frontend has
arrivals.  :class:`SchedulingService` bridges the two with a classic
latency-budget accumulator:

* ``submit(task, arrival)`` queues the task.  Virtual time advances with
  the (non-decreasing) arrival stamps;
* once the **oldest** queued task has waited ``config.max_wait_s`` — or
  ``config.max_batch`` tasks have queued up — the pending set is flushed
  as one batch through a :class:`~repro.core.multibatch.MultiBatchScheduler`
  under any registered policy, with tail-aware seam concatenation (§4);
* a deadline flush smaller than ``config.min_batch`` (a slow trickle) and
  ``urgent=True`` submits skip batching entirely: they are placed
  immediately by the :class:`~repro.core.online.OnlineScheduler` greedy,
  seeded with the committed tail's ``release``/``alive`` context so the
  fallback lands in the same timeline as the batches;
* multi-GPU pools come for free: ``pool_size=k`` schedules onto
  ``device_spec.multi_gpu(spec, k)``.

Two serving extensions ride on top of that accumulator:

**Deadlines and admission control.**  ``submit(task, deadline=d)`` tracks
the task's SLO; :meth:`deadline_report` scores misses against the final
combined schedule.  With ``config.admission`` set to ``"reject"`` or
``"demote"``, a submit whose deadline is *provably* unmeetable —
:meth:`completion_lower_bound`, an admissible floor built from the
running (never-preemptible) work on the committed timeline — is refused
outright or accepted best-effort with the deadline dropped.

**Tail re-planning.**  The batch-concatenation scheme normally commits
placements forever, but a placement that has not *started* is not
physically committed.  With ``config.replan=True`` every batch flush
first pulls the not-yet-started tail back
(:meth:`~repro.core.multibatch.MultiBatchScheduler.withdraw_uncommitted`)
and re-plans it together with the arrivals; the re-planned candidate is
kept only when it strictly beats the plain arrivals-only flush on the
combined makespan.  Running tasks keep their exact begin times — the
no-preemption model holds.  The service also carries the never-replanned
chain as a shadow, and every report (``makespan`` / ``drain`` /
``combined_schedule``) answers from whichever chain is ahead, so
``replan=True`` can never end a stream worse than ``replan=False`` —
the fragmentation-aware-scheduler observation (arXiv:2512.16099) that
online decisions degrade without revisiting queued placements, made safe
by construction.

Everything is deterministic given the submission sequence — there is no
RNG and no wall-clock dependence in any placement decision (wall time is
only *measured*, for the decision-latency statistics).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Sequence

from repro.core.cluster import ClusterMultiBatchScheduler, ClusterSpec
from repro.core.device_spec import DeviceSpec, multi_gpu
from repro.core.multibatch import MultiBatchScheduler
from repro.core.policy import SchedulerConfig
from repro.core.problem import EPS, Schedule, Task


@dataclasses.dataclass(frozen=True)
class Decision:
    """How and when one task's placement was decided."""

    task_id: int
    arrival: float        # virtual time the task was submitted
    decided_at: float     # virtual time the placement decision fired
    route: str            # "batch" | "online" | "replan"
    flush_id: int         # which flush carried it
    plan_wall_s: float    # wall-clock seconds the scheduler spent deciding
    deadline: float | None = None  # the task's SLO, if it kept one

    @property
    def queue_delay(self) -> float:
        """Virtual seconds the task waited for its decision."""
        return self.decided_at - self.arrival


@dataclasses.dataclass(frozen=True)
class ReplanEvent:
    """One accepted tail re-plan: which flush, what it pulled back, and
    the combined makespans of the two candidates it chose between."""

    flush_id: int
    decided_at: float
    withdrawn: tuple[int, ...]      # task ids pulled back for re-planning
    makespan_replanned: float
    makespan_plain: float

    @property
    def win(self) -> float:
        """Makespan saved by re-planning at this flush."""
        return self.makespan_plain - self.makespan_replanned


@dataclasses.dataclass
class ServiceStats:
    submitted: int = 0
    batches: int = 0
    online_placements: int = 0
    decisions: list[Decision] = dataclasses.field(default_factory=list)
    rejected: list[int] = dataclasses.field(default_factory=list)
    demoted: list[int] = dataclasses.field(default_factory=list)
    replan_attempts: int = 0     # flushes that had a tail to pull back
    replan_wins: int = 0         # flushes where the re-plan was kept
    withdrawn: int = 0           # placements pulled back by kept re-plans
    replan_events: list[ReplanEvent] = dataclasses.field(default_factory=list)

    def queue_delays(self) -> list[float]:
        return [d.queue_delay for d in self.decisions]

    def plan_wall_s(self) -> list[float]:
        """Wall-clock decision latency of each flush (one entry per flush,
        not per task)."""
        seen: dict[int, float] = {}
        for d in self.decisions:
            seen[d.flush_id] = d.plan_wall_s
        return [seen[k] for k in sorted(seen)]


class SchedulingService:
    """Facade: arrival batching within a latency budget + online fallback,
    with optional deadlines/admission and tail re-planning.

    The service owns a :class:`MultiBatchScheduler` (the tail carrier);
    batch flushes go through its registered policy, online fallbacks are
    adopted into the same timeline via ``adopt_segment``.  Call ``drain()``
    when the stream ends to flush whatever is still pending.
    """

    def __init__(
        self,
        spec: DeviceSpec | ClusterSpec | None = None,
        policy: str = "far",
        config: SchedulerConfig | None = None,
        pool_size: int = 1,
        pool: DeviceSpec | ClusterSpec | None = None,
    ):
        """``spec`` is the classic single-device (or homogeneous
        ``pool_size``-GPU) entry point.  ``pool=`` supersedes it: pass a
        :class:`~repro.core.cluster.ClusterSpec` to serve a heterogeneous
        fleet (per-device seam tails, phase-0 flush partitioning), or a
        plain ``DeviceSpec`` as an alias for ``spec``."""
        if pool is not None:
            spec = pool
        if spec is None:
            raise ValueError("SchedulingService needs spec= or pool=")
        self.config = config or SchedulerConfig()
        self.policy = policy
        if isinstance(spec, ClusterSpec):
            self.cluster: ClusterSpec | None = spec
            self.spec = spec
            self.mb: MultiBatchScheduler | ClusterMultiBatchScheduler = \
                ClusterMultiBatchScheduler(
                    spec, policy=policy, config=self.config
                )
        else:
            self.cluster = None
            if pool_size > 1:
                spec = multi_gpu(spec, pool_size)
            self.spec = spec
            self.mb = MultiBatchScheduler(
                spec, policy=policy, config=self.config
            )
        # the never-replanned shadow chain: with replan on, every flush is
        # mirrored here exactly as replan=False would commit it, and the
        # reporting surface answers from whichever chain is ahead — the
        # makespan guarantee replan(stream) <= no-replan(stream) holds by
        # construction, not by hoping the per-flush heuristic composes.
        # Materialised lazily at the first accepted re-plan (until the
        # chains diverge the primary IS the shadow, so mirroring it would
        # just re-run the identical plan on every flush).
        self._baseline: MultiBatchScheduler | None = None
        self.pending: list[tuple[Task, float, float | None]] = []
        self.now = 0.0
        self.stats = ServiceStats()
        self._flush_id = 0
        self._deadlines: dict[int, float] = {}   # retained SLOs by task id
        self._arrivals: dict[int, float] = {}    # arrival stamps by task id

    # -- intake ------------------------------------------------------------
    def submit(
        self,
        task: Task,
        arrival: float | None = None,
        urgent: bool = False,
        deadline: float | None = None,
    ) -> str:
        """Queue ``task`` at virtual time ``arrival`` (default: now).

        Arrivals must be non-decreasing; ``urgent=True`` bypasses the
        batching budget and places the task immediately.  ``deadline``
        declares the task's SLO (absolute virtual time its completion is
        due); what an unmeetable one does depends on
        ``config.admission``.  Returns the intake verdict: ``"queued"``,
        ``"placed"`` (urgent), ``"demoted"`` or ``"rejected"``.
        """
        arrival = self.now if arrival is None else float(arrival)
        if arrival < self.now - 1e-9:
            raise ValueError(
                f"arrivals must be non-decreasing: {arrival} < {self.now}"
            )
        self.now = max(self.now, arrival)
        self._advance(self.now)
        self.stats.submitted += 1
        if self.cluster is not None and not self.cluster.supports(task):
            # no device of the pool fully covers the task's profile, so a
            # batch flush would fail mid-partitioning (and drop the whole
            # pending queue with it) — refuse at intake instead
            self.stats.rejected.append(task.id)
            return "rejected"
        verdict = "queued"
        if deadline is not None:
            deadline = float(deadline)
            verdict = self._admit(task, arrival, deadline)
            if verdict == "rejected":
                return verdict
            if verdict == "demoted":
                deadline = None
        self._arrivals[task.id] = arrival
        if deadline is not None:
            self._deadlines[task.id] = deadline
        if urgent:
            self._route_online([(task, arrival, deadline)],
                               decided_at=arrival)
            return "placed" if verdict == "queued" else verdict
        self.pending.append((task, arrival, deadline))
        if len(self.pending) >= self.config.max_batch:
            self._flush_pending(decided_at=arrival)
        return verdict

    def poll(self, now: float) -> None:
        """Advance virtual time with no submission (fires due flushes)."""
        if now < self.now - 1e-9:
            raise ValueError(f"time must be non-decreasing: {now} < {self.now}")
        self.now = max(self.now, now)
        self._advance(self.now)

    def flush(self) -> None:
        """Force-flush everything pending at the current virtual time."""
        if self.pending:
            self._flush_pending(decided_at=self.now)

    def drain(self) -> Schedule:
        """Flush pending tasks and return the combined schedule so far."""
        self.flush()
        return self.combined_schedule()

    # -- admission ---------------------------------------------------------
    def completion_lower_bound(self, task: Task, at: float) -> float:
        """Provable floor on ``task``'s completion if submitted at ``at``.

        Placements are causal (nothing begins before the decision that
        placed it, and the decision is no earlier than the arrival) and
        running work is never preempted, so a feasible instance cannot
        host the task before every slice it blocks clears of the work
        already *running* at ``at``.  Queued-but-unstarted placements are
        ignored (re-planning may pull them back), as are creation costs
        and queueing — the bound stays admissible.  With re-planning the
        service may report either the re-planning chain or the
        never-replanned shadow, so the bound is the minimum over both:
        no schedule the service can still produce finishes the task
        earlier, whichever chain wins.
        """
        best = self._chain_lower_bound(self.mb, task, at)
        if self._baseline is not None:
            best = min(
                best, self._chain_lower_bound(self._baseline, task, at)
            )
        return best

    def _node_candidates(self, task: Task):
        """(instance node, size-keyed times) pairs the task could run on —
        every node of the single device, or every supported device of the
        pool with the task's times lowered onto that device's kind."""
        if self.cluster is not None:
            devices = self.cluster.devices
        else:
            devices = (self.spec,)
        for dev in devices:
            if not task.supports(dev.device_kind):
                continue
            times = task.times_for(dev.device_kind)
            for node in dev.nodes:
                if node.size in times:
                    yield node, times

    def _chain_lower_bound(self, mb, task: Task, at: float) -> float:
        busy: dict[tuple[int, int], float] = {}
        for seg in mb.segments:
            if seg.makespan <= at:
                continue  # fully finished by `at`: nothing still running
            for it in seg.items:
                if it.begin <= at + EPS and it.end > at:
                    for cell in it.node.blocked_cells:
                        if it.end > busy.get(cell, 0.0):
                            busy[cell] = it.end
        best = math.inf
        for node, times in self._node_candidates(task):
            floor = at
            for cell in node.blocked_cells:
                b = busy.get(cell, 0.0)
                if b > floor:
                    floor = b
            done = floor + times[node.size]
            if done < best:
                best = done
        return best

    def _admit(self, task: Task, arrival: float, deadline: float) -> str:
        if self.config.admission == "none":
            return "queued"
        if self.completion_lower_bound(task, arrival) <= deadline + EPS:
            return "queued"
        if self.config.admission == "reject":
            self.stats.rejected.append(task.id)
            return "rejected"
        self.stats.demoted.append(task.id)
        return "demoted"

    # -- internals ---------------------------------------------------------
    def _advance(self, now: float) -> None:
        # every pending task arrived within max_wait_s of the oldest (any
        # later arrival would have fired this flush first), so one deadline
        # empties the whole queue
        if self.pending and now - self.pending[0][1] >= self.config.max_wait_s:
            deadline = self.pending[0][1] + self.config.max_wait_s
            self._flush_pending(decided_at=deadline)

    def _flush_pending(self, decided_at: float) -> None:
        batch, self.pending = self.pending, []
        if len(batch) < self.config.min_batch:
            # slow trickle: too few tasks accumulated within the budget for
            # an offline batch to pay off — place them greedily instead
            self._route_online(batch, decided_at)
            return
        t0 = time.perf_counter()
        arrivals = [task for task, _, _ in batch]
        if self._baseline is not None:  # chains diverged: mirror the flush
            self._baseline.add_batch(arrivals, not_before=decided_at)
        # nothing may start before the flush decision that placed it
        withdrawn, plain_makespan = self._flush_batch(arrivals, decided_at)
        wall = time.perf_counter() - t0
        fid = self._next_flush_id()
        self.stats.batches += 1
        for task, arrival, deadline in batch:
            self.stats.decisions.append(Decision(
                task.id, arrival, decided_at, "batch", fid, wall,
                deadline=deadline,
            ))
        for task in withdrawn:
            self.stats.decisions.append(Decision(
                task.id, self._arrivals.get(task.id, decided_at), decided_at,
                "replan", fid, wall,
                deadline=self._deadlines.get(task.id),
            ))
        self._attach_deadline_extras(arrivals + withdrawn)
        if withdrawn:
            self.stats.replan_events.append(ReplanEvent(
                fid, decided_at, tuple(t.id for t in withdrawn),
                self.mb.makespan, plain_makespan,
            ))

    def _flush_batch(self, arrivals: list[Task], decided_at: float
                     ) -> tuple[list[Task], float]:
        """Commit one batch flush on the primary chain; returns the tasks
        a kept re-plan pulled back (empty without ``config.replan``) and
        the plain candidate's combined makespan for the event log."""
        if not self.config.replan:
            self.mb.add_batch(arrivals, not_before=decided_at)
            return [], 0.0
        # candidate A — the plain flush: arrivals against the committed tail
        plain = self.mb.clone()
        plain.add_batch(arrivals, not_before=decided_at)
        # candidate B — the re-plan: pull the not-yet-started tail back and
        # schedule it together with the arrivals under the same policy
        trial = self.mb.clone()
        withdrawn = trial.withdraw_uncommitted(decided_at)
        if not withdrawn:
            # nothing to revisit: the flush is bit-identical to replan=False
            self.mb = plain
            return [], 0.0
        self.stats.replan_attempts += 1
        trial.add_batch(withdrawn + arrivals, not_before=decided_at)
        if trial.makespan < plain.makespan - self.config.eps:
            if self._baseline is None:
                # first divergence: the plain candidate IS the
                # never-replanned continuation — it becomes the shadow
                self._baseline = plain
            self.mb = trial
            self.stats.replan_wins += 1
            self.stats.withdrawn += len(withdrawn)
            return withdrawn, plain.makespan
        self.mb = plain
        return [], 0.0

    def _attach_deadline_extras(self, tasks: Sequence[Task]) -> None:
        """Record the flushed batch's SLO picture on its PlanResult: the
        retained deadlines and each one's slack against the planned
        completion (negative slack = the plan already misses it)."""
        deadlines = {
            t.id: self._deadlines[t.id] for t in tasks
            if t.id in self._deadlines
        }
        if not deadlines or not self.mb.results:
            return
        # only the just-flushed placements are needed (the deadlines dict
        # is restricted to this batch) — rebuilding the whole combined
        # schedule here would make a long-running service O(F^2)
        ends: dict[int, float] = {}
        for it in self.mb.last_flush_items():
            ends[it.task.id] = it.end
        plan = self.mb.results[-1]
        plan.extras["deadlines"] = deadlines
        plan.extras["deadline_slack"] = {
            tid: dl - ends[tid] for tid, dl in deadlines.items()
            if tid in ends
        }

    def _route_online(
        self,
        batch: Sequence[tuple[Task, float, float | None]],
        decided_at: float,
    ) -> None:
        if not batch:
            return
        t0 = time.perf_counter()
        # polymorphic: MultiBatchScheduler floors its single tail and
        # greedy-places; ClusterMultiBatchScheduler additionally picks a
        # device per task via speculative greedy previews
        self.mb.online_place(batch, decided_at)
        if self._baseline is not None:
            self._baseline.online_place(batch, decided_at)
        wall = time.perf_counter() - t0
        fid = self._next_flush_id()
        self.stats.online_placements += len(batch)
        for task, arrival, deadline in batch:
            self.stats.decisions.append(Decision(
                task.id, arrival, decided_at, "online", fid, wall,
                deadline=deadline,
            ))

    def _next_flush_id(self) -> int:
        self._flush_id += 1
        return self._flush_id

    # -- reporting ---------------------------------------------------------
    @property
    def _winner(self) -> MultiBatchScheduler:
        """The chain every report answers from: the re-planning chain,
        unless the never-replanned shadow is strictly ahead."""
        if self._baseline is not None \
                and self._baseline.makespan < self.mb.makespan:
            return self._baseline
        return self.mb

    @property
    def makespan(self) -> float:
        return self._winner.makespan

    @property
    def tail(self):
        return self._winner.tail

    def combined_schedule(self) -> Schedule:
        return self._winner.combined_schedule()

    def deadline_report(self) -> dict:
        """Score the retained deadlines against the combined schedule —
        meaningful after :meth:`drain` (a task still pending counts as a
        miss: it has no completion).  Demoted and rejected tasks are
        reported separately and never count as misses."""
        ends: dict[int, float] = {}
        for it in self.combined_schedule().items:
            ends[it.task.id] = it.end
        missed = sorted(
            tid for tid, dl in self._deadlines.items()
            if ends.get(tid, math.inf) > dl + EPS
        )
        tracked = len(self._deadlines)
        return {
            "tracked": tracked,
            "missed": missed,
            "miss_rate": len(missed) / tracked if tracked else 0.0,
            "rejected": sorted(self.stats.rejected),
            "demoted": sorted(self.stats.demoted),
        }


__all__ = [
    "SchedulingService",
    "ServiceStats",
    "Decision",
    "ReplanEvent",
]
