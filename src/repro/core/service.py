"""Arrival-driven scheduling service with a latency budget (ROADMAP
"online serving at scale"; cf. Tan et al., serving DNN models on MIG).

The paper's offline formulation needs batches; a serving frontend has
arrivals.  :class:`SchedulingService` bridges the two with a classic
latency-budget accumulator:

* ``submit(task, arrival)`` queues the task.  Virtual time advances with
  the (non-decreasing) arrival stamps;
* once the **oldest** queued task has waited ``config.max_wait_s`` — or
  ``config.max_batch`` tasks have queued up — the pending set is flushed
  as one batch through a :class:`~repro.core.multibatch.MultiBatchScheduler`
  under any registered policy, with tail-aware seam concatenation (§4);
* a deadline flush smaller than ``config.min_batch`` (a slow trickle) and
  ``urgent=True`` submits skip batching entirely: they are placed
  immediately by the :class:`~repro.core.online.OnlineScheduler` greedy,
  seeded with the committed tail's ``release``/``alive`` context so the
  fallback lands in the same timeline as the batches;
* multi-GPU pools come for free: ``pool_size=k`` schedules onto
  ``device_spec.multi_gpu(spec, k)``.

Everything is deterministic given the submission sequence — there is no
RNG and no wall-clock dependence in any placement decision (wall time is
only *measured*, for the decision-latency statistics).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

from repro.core.device_spec import DeviceSpec, multi_gpu
from repro.core.multibatch import MultiBatchScheduler
from repro.core.online import OnlineScheduler
from repro.core.policy import SchedulerConfig
from repro.core.problem import Schedule, Task


@dataclasses.dataclass(frozen=True)
class Decision:
    """How and when one task's placement was decided."""

    task_id: int
    arrival: float        # virtual time the task was submitted
    decided_at: float     # virtual time the placement decision fired
    route: str            # "batch" | "online"
    flush_id: int         # which flush carried it
    plan_wall_s: float    # wall-clock seconds the scheduler spent deciding

    @property
    def queue_delay(self) -> float:
        """Virtual seconds the task waited for its decision."""
        return self.decided_at - self.arrival


@dataclasses.dataclass
class ServiceStats:
    submitted: int = 0
    batches: int = 0
    online_placements: int = 0
    decisions: list[Decision] = dataclasses.field(default_factory=list)

    def queue_delays(self) -> list[float]:
        return [d.queue_delay for d in self.decisions]

    def plan_wall_s(self) -> list[float]:
        """Wall-clock decision latency of each flush (one entry per flush,
        not per task)."""
        seen: dict[int, float] = {}
        for d in self.decisions:
            seen[d.flush_id] = d.plan_wall_s
        return [seen[k] for k in sorted(seen)]


class SchedulingService:
    """Facade: arrival batching within a latency budget + online fallback.

    The service owns a :class:`MultiBatchScheduler` (the tail carrier);
    batch flushes go through its registered policy, online fallbacks are
    adopted into the same timeline via ``adopt_segment``.  Call ``drain()``
    when the stream ends to flush whatever is still pending.
    """

    def __init__(
        self,
        spec: DeviceSpec,
        policy: str = "far",
        config: SchedulerConfig | None = None,
        pool_size: int = 1,
    ):
        if pool_size > 1:
            spec = multi_gpu(spec, pool_size)
        self.spec = spec
        self.config = config or SchedulerConfig()
        self.policy = policy
        self.mb = MultiBatchScheduler(spec, policy=policy, config=self.config)
        self.pending: list[tuple[Task, float]] = []
        self.now = 0.0
        self.stats = ServiceStats()
        self._flush_id = 0

    # -- intake ------------------------------------------------------------
    def submit(
        self, task: Task, arrival: float | None = None, urgent: bool = False
    ) -> None:
        """Queue ``task`` at virtual time ``arrival`` (default: now).

        Arrivals must be non-decreasing; ``urgent=True`` bypasses the
        batching budget and places the task immediately.
        """
        arrival = self.now if arrival is None else float(arrival)
        if arrival < self.now - 1e-9:
            raise ValueError(
                f"arrivals must be non-decreasing: {arrival} < {self.now}"
            )
        self.now = max(self.now, arrival)
        self._advance(self.now)
        self.stats.submitted += 1
        if urgent:
            self._route_online([(task, arrival)], decided_at=arrival)
            return
        self.pending.append((task, arrival))
        if len(self.pending) >= self.config.max_batch:
            self._flush_pending(decided_at=arrival)

    def poll(self, now: float) -> None:
        """Advance virtual time with no submission (fires due flushes)."""
        if now < self.now - 1e-9:
            raise ValueError(f"time must be non-decreasing: {now} < {self.now}")
        self.now = max(self.now, now)
        self._advance(self.now)

    def flush(self) -> None:
        """Force-flush everything pending at the current virtual time."""
        if self.pending:
            self._flush_pending(decided_at=self.now)

    def drain(self) -> Schedule:
        """Flush pending tasks and return the combined schedule so far."""
        self.flush()
        return self.mb.combined_schedule()

    # -- internals ---------------------------------------------------------
    def _advance(self, now: float) -> None:
        # every pending task arrived within max_wait_s of the oldest (any
        # later arrival would have fired this flush first), so one deadline
        # empties the whole queue
        if self.pending and now - self.pending[0][1] >= self.config.max_wait_s:
            deadline = self.pending[0][1] + self.config.max_wait_s
            self._flush_pending(decided_at=deadline)

    def _flush_pending(self, decided_at: float) -> None:
        batch, self.pending = self.pending, []
        if len(batch) < self.config.min_batch:
            # slow trickle: too few tasks accumulated within the budget for
            # an offline batch to pay off — place them greedily instead
            self._route_online(batch, decided_at)
            return
        t0 = time.perf_counter()
        # nothing may start before the flush decision that placed it
        self.mb.add_batch([task for task, _ in batch], not_before=decided_at)
        wall = time.perf_counter() - t0
        fid = self._next_flush_id()
        self.stats.batches += 1
        for task, arrival in batch:
            self.stats.decisions.append(Decision(
                task.id, arrival, decided_at, "batch", fid, wall,
            ))

    def _route_online(
        self, batch: Sequence[tuple[Task, float]], decided_at: float
    ) -> None:
        if not batch:
            return
        t0 = time.perf_counter()
        # floor the release context at the decision time: every placement
        # begins >= decided_at >= its task's arrival, keeping the combined
        # timeline causal (an unfloored release would let the greedy place
        # work on idle slices before the task even arrived)
        floored = self.mb.tail.floored(decided_at)
        online = OnlineScheduler(
            self.spec, release=floored.release, alive=floored.alive,
        )
        for task, arrival in batch:
            online.submit(task, arrival=arrival)
        self.mb.adopt_segment(online.schedule())
        wall = time.perf_counter() - t0
        fid = self._next_flush_id()
        self.stats.online_placements += len(batch)
        for task, arrival in batch:
            self.stats.decisions.append(Decision(
                task.id, arrival, decided_at, "online", fid, wall,
            ))

    def _next_flush_id(self) -> int:
        self._flush_id += 1
        return self._flush_id

    # -- reporting ---------------------------------------------------------
    @property
    def makespan(self) -> float:
        return self.mb.makespan

    @property
    def tail(self):
        return self.mb.tail

    def combined_schedule(self) -> Schedule:
        return self.mb.combined_schedule()


__all__ = ["SchedulingService", "ServiceStats", "Decision"]
