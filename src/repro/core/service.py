"""Arrival-driven scheduling service with a latency budget, per-task
deadlines and tail re-planning (ROADMAP "online serving at scale"; cf.
Tan et al., serving DNN models on MIG, arXiv:2109.11067).

The paper's offline formulation needs batches; a serving frontend has
arrivals.  :class:`SchedulingService` bridges the two with a classic
latency-budget accumulator:

* ``submit(task, arrival)`` queues the task.  Virtual time advances with
  the (non-decreasing) arrival stamps;
* once the **oldest** queued task has waited ``config.max_wait_s`` — or
  ``config.max_batch`` tasks have queued up — the pending set is flushed
  as one batch through a :class:`~repro.core.multibatch.MultiBatchScheduler`
  under any registered policy, with tail-aware seam concatenation (§4);
* a deadline flush smaller than ``config.min_batch`` (a slow trickle) and
  ``urgent=True`` submits skip batching entirely: they are placed
  immediately by the :class:`~repro.core.online.OnlineScheduler` greedy,
  seeded with the committed tail's ``release``/``alive`` context so the
  fallback lands in the same timeline as the batches;
* multi-GPU pools come for free: ``pool_size=k`` schedules onto
  ``device_spec.multi_gpu(spec, k)``.

Two serving extensions ride on top of that accumulator:

**Deadlines and admission control.**  ``submit(task, deadline=d)`` tracks
the task's SLO; :meth:`deadline_report` scores misses against the final
combined schedule.  With ``config.admission`` set to ``"reject"`` or
``"demote"``, a submit whose deadline is *provably* unmeetable —
:meth:`completion_lower_bound`, an admissible floor built from the
running (never-preemptible) work on the committed timeline — is refused
outright or accepted best-effort with the deadline dropped.

**Tail re-planning.**  The batch-concatenation scheme normally commits
placements forever, but a placement that has not *started* is not
physically committed.  With ``config.replan=True`` every batch flush
first pulls the not-yet-started tail back
(:meth:`~repro.core.multibatch.MultiBatchScheduler.withdraw_uncommitted`)
and re-plans it together with the arrivals; the re-planned candidate is
kept only when it strictly beats the plain arrivals-only flush on the
combined makespan.  Running tasks keep their exact begin times — the
no-preemption model holds.  The service also carries the never-replanned
chain as a shadow, and every report (``makespan`` / ``drain`` /
``combined_schedule``) answers from whichever chain is ahead, so
``replan=True`` can never end a stream worse than ``replan=False`` —
the fragmentation-aware-scheduler observation (arXiv:2512.16099) that
online decisions degrade without revisiting queued placements, made safe
by construction.

**Runtime feedback (closed-loop fault tolerance).**  The committed
timeline is a *belief* built from profiled durations; ``report(task_id,
event, t)`` feeds it runtime truth.  A ``completed`` report replaces the
profiled end with the actual one (an early finish frees capacity, a late
one forces the conflicting tail out for re-planning); a ``failed``
report truncates the attempt into an occupancy record and re-releases
the task through ``config.retry`` (:class:`~repro.core.faults.RetryPolicy`
— capped exponential backoff, optional demotion).  With
``config.straggler_factor`` set, any time advance scans the running
placements and *stretches* those whose observed runtime exceeds the
factor without a completion report — the serving analogue of the timing
engine's logged ``apply_stretch``.  ``quarantine(device, t)`` /
``recover(device, t)`` handle device loss on a pool: every not-yet-
started placement on the lost device is withdrawn and re-partitioned
onto the survivors (tasks only the lost device supports are *parked*
and re-admitted on recovery; still parked at ``drain`` they are
reported rejected, never silently stranded), and admission floors
(:meth:`completion_lower_bound`) see only the surviving capacity.  The
first runtime deviation drops the never-replanned shadow — it is a
counterfactual over profiled durations and cannot absorb truth.

Everything is deterministic given the submission sequence — there is no
RNG and no wall-clock dependence in any placement decision (wall time is
only *measured*, for the decision-latency statistics).
"""

from __future__ import annotations

import dataclasses
import heapq
import math
import time
from typing import Sequence

from repro.core.cluster import ClusterMultiBatchScheduler, ClusterSpec
from repro.core.device_spec import DeviceSpec, multi_gpu
from repro.core.multibatch import MultiBatchScheduler
from repro.core.policy import SchedulerConfig
from repro.core.problem import EPS, Schedule, ScheduledTask, Task


@dataclasses.dataclass(frozen=True)
class Decision:
    """How and when one task's placement was decided."""

    task_id: int
    arrival: float        # virtual time the task was submitted
    decided_at: float     # virtual time the placement decision fired
    route: str            # "batch" | "online" | "replan" | "fault"
    flush_id: int         # which flush carried it
    plan_wall_s: float    # wall-clock seconds the scheduler spent deciding
    deadline: float | None = None  # the task's SLO, if it kept one

    @property
    def queue_delay(self) -> float:
        """Virtual seconds the task waited for its decision."""
        return self.decided_at - self.arrival


@dataclasses.dataclass(frozen=True)
class ReplanEvent:
    """One accepted tail re-plan: which flush, what it pulled back, and
    the combined makespans of the two candidates it chose between."""

    flush_id: int
    decided_at: float
    withdrawn: tuple[int, ...]      # task ids pulled back for re-planning
    makespan_replanned: float
    makespan_plain: float

    @property
    def win(self) -> float:
        """Makespan saved by re-planning at this flush."""
        return self.makespan_plain - self.makespan_replanned


@dataclasses.dataclass(frozen=True)
class CorrectionEvent:
    """One runtime-truth correction of the committed timeline."""

    task_id: int
    at: float                    # virtual time the correction landed
    kind: str                    # "stretch" | "shrink" | "straggler" | "failure"
    old_end: float               # projected end before the correction
    new_end: float               # corrected end (actual / projection / t_fail)
    withdrawn: tuple[int, ...]   # placements the forced re-plan pulled back


@dataclasses.dataclass(frozen=True)
class RetryEvent:
    """One failed attempt re-entering the queue through the RetryPolicy."""

    task_id: int
    attempt: int                 # the attempt number being released (2-based)
    failed_at: float             # when the previous attempt failed
    release: float               # backoff floor: the retry arrives here
    demoted: bool                # whether the retry carries a demoted profile


@dataclasses.dataclass(frozen=True)
class OutageEvent:
    """One device-loss window on a pool."""

    device: int
    lost_at: float
    recovered_at: float | None   # None while still quarantined
    withdrawn: tuple[int, ...]   # not-yet-started placements pulled off it
    died_running: tuple[int, ...]  # attempts that were running at the loss
    parked: tuple[int, ...]      # withdrawn tasks no surviving device fits


@dataclasses.dataclass
class ServiceStats:
    submitted: int = 0
    batches: int = 0
    online_placements: int = 0
    decisions: list[Decision] = dataclasses.field(default_factory=list)
    rejected: list[int] = dataclasses.field(default_factory=list)
    demoted: list[int] = dataclasses.field(default_factory=list)
    replan_attempts: int = 0     # flushes that had a tail to pull back
    replan_wins: int = 0         # flushes where the re-plan was kept
    withdrawn: int = 0           # placements pulled back by kept re-plans
    replan_events: list[ReplanEvent] = dataclasses.field(default_factory=list)
    # -- runtime feedback ---------------------------------------------------
    completed: int = 0           # completion reports received
    stragglers: int = 0          # implicit straggler detections
    failed: list[int] = dataclasses.field(default_factory=list)  # permanent
    corrections: list[CorrectionEvent] = dataclasses.field(default_factory=list)
    retries: list[RetryEvent] = dataclasses.field(default_factory=list)
    outages: list[OutageEvent] = dataclasses.field(default_factory=list)

    def queue_delays(self) -> list[float]:
        return [d.queue_delay for d in self.decisions]

    def plan_wall_s(self) -> list[float]:
        """Wall-clock decision latency of each flush (one entry per flush,
        not per task)."""
        seen: dict[int, float] = {}
        for d in self.decisions:
            seen[d.flush_id] = d.plan_wall_s
        return [seen[k] for k in sorted(seen)]


class SchedulingService:
    """Facade: arrival batching within a latency budget + online fallback,
    with optional deadlines/admission and tail re-planning.

    The service owns a :class:`MultiBatchScheduler` (the tail carrier);
    batch flushes go through its registered policy, online fallbacks are
    adopted into the same timeline via ``adopt_segment``.  Call ``drain()``
    when the stream ends to flush whatever is still pending.
    """

    def __init__(
        self,
        spec: DeviceSpec | ClusterSpec | None = None,
        policy: str = "far",
        config: SchedulerConfig | None = None,
        pool_size: int = 1,
        pool: DeviceSpec | ClusterSpec | None = None,
    ):
        """``spec`` is the classic single-device (or homogeneous
        ``pool_size``-GPU) entry point.  ``pool=`` supersedes it: pass a
        :class:`~repro.core.cluster.ClusterSpec` to serve a heterogeneous
        fleet (per-device seam tails, phase-0 flush partitioning), or a
        plain ``DeviceSpec`` as an alias for ``spec``."""
        if pool is not None:
            spec = pool
        if spec is None:
            raise ValueError("SchedulingService needs spec= or pool=")
        self.config = config or SchedulerConfig()
        self.policy = policy
        if isinstance(spec, ClusterSpec):
            self.cluster: ClusterSpec | None = spec
            self.spec = spec
            self.mb: MultiBatchScheduler | ClusterMultiBatchScheduler = \
                ClusterMultiBatchScheduler(
                    spec, policy=policy, config=self.config
                )
        else:
            self.cluster = None
            if pool_size > 1:
                spec = multi_gpu(spec, pool_size)
            self.spec = spec
            self.mb = MultiBatchScheduler(
                spec, policy=policy, config=self.config
            )
        # the never-replanned shadow chain: with replan on, every flush is
        # mirrored here exactly as replan=False would commit it, and the
        # reporting surface answers from whichever chain is ahead — the
        # makespan guarantee replan(stream) <= no-replan(stream) holds by
        # construction, not by hoping the per-flush heuristic composes.
        # Materialised lazily at the first accepted re-plan (until the
        # chains diverge the primary IS the shadow, so mirroring it would
        # just re-run the identical plan on every flush).
        self._baseline: MultiBatchScheduler | None = None
        self.pending: list[tuple[Task, float, float | None]] = []
        self.now = 0.0
        self.stats = ServiceStats()
        self._flush_id = 0
        self._deadlines: dict[int, float] = {}   # retained SLOs by task id
        self._arrivals: dict[int, float] = {}    # arrival stamps by task id
        # -- runtime feedback state -----------------------------------------
        self._tasks: dict[int, Task] = {}        # submitted tasks (for retry)
        self._completions: dict[int, float] = {}  # actual ends, as reported
        self._attempts: dict[int, int] = {}      # current attempt number
        self._requeue: list[tuple[float, int, Task, float | None]] = []
        self._rseq = 0                           # requeue heap tie-break
        self._parked: list[Task] = []            # awaiting device recovery
        # set on the first runtime deviation: the never-replanned shadow
        # is a counterfactual over profiled durations and cannot absorb
        # runtime truth, so it is dropped and never re-materialised
        self._fault_mode = False

    # -- intake ------------------------------------------------------------
    def submit(
        self,
        task: Task,
        arrival: float | None = None,
        urgent: bool = False,
        deadline: float | None = None,
    ) -> str:
        """Queue ``task`` at virtual time ``arrival`` (default: now).

        Arrivals must be non-decreasing; ``urgent=True`` bypasses the
        batching budget and places the task immediately.  ``deadline``
        declares the task's SLO (absolute virtual time its completion is
        due); what an unmeetable one does depends on
        ``config.admission``.  Returns the intake verdict: ``"queued"``,
        ``"placed"`` (urgent), ``"demoted"`` or ``"rejected"``.
        """
        arrival = self.now if arrival is None else float(arrival)
        if arrival < self.now - 1e-9:
            raise ValueError(
                f"arrivals must be non-decreasing: {arrival} < {self.now}"
            )
        self._validate_task(task)
        if deadline is not None and float(deadline) < arrival - 1e-9:
            raise ValueError(
                f"task {task.id}: deadline {deadline} precedes its "
                f"arrival {arrival} — the SLO is unmeetable by "
                f"construction (pass deadline >= arrival)"
            )
        self.now = max(self.now, arrival)
        self._advance(self.now)
        self.stats.submitted += 1
        if self.cluster is not None and not self.cluster.supports(task):
            # no device of the pool fully covers the task's profile, so a
            # batch flush would fail mid-partitioning (and drop the whole
            # pending queue with it) — refuse at intake instead
            self.stats.rejected.append(task.id)
            return "rejected"
        verdict = "queued"
        if deadline is not None:
            deadline = float(deadline)
            verdict = self._admit(task, arrival, deadline)
            if verdict == "rejected":
                return verdict
            if verdict == "demoted":
                deadline = None
        self._arrivals[task.id] = arrival
        self._tasks[task.id] = task
        if deadline is not None:
            self._deadlines[task.id] = deadline
        if urgent:
            self._route_online([(task, arrival, deadline)],
                               decided_at=arrival)
            return "placed" if verdict == "queued" else verdict
        self.pending.append((task, arrival, deadline))
        if len(self.pending) >= self.config.max_batch:
            self._flush_pending(decided_at=arrival)
        return verdict

    def poll(self, now: float) -> None:
        """Advance virtual time with no submission (fires due flushes)."""
        if now < self.now - 1e-9:
            raise ValueError(f"time must be non-decreasing: {now} < {self.now}")
        self.now = max(self.now, now)
        self._advance(self.now)

    def flush(self) -> None:
        """Force-flush everything pending at the current virtual time."""
        if self.pending:
            self._flush_pending(decided_at=self.now)

    def drain(self) -> Schedule:
        """Flush pending tasks and return the combined schedule so far.

        Queued retries are played out first (virtual time advances to
        each backoff release), and tasks still parked on a quarantined
        device are reported **rejected** — a withdrawn task is never
        silently stranded."""
        while self._requeue:
            self.poll(max(self.now, self._requeue[0][0]))
            self.flush()
        self.flush()
        if self._parked:
            for task in self._parked:
                self.stats.rejected.append(task.id)
                # a rejected task has no completion and must not count
                # as a deadline miss (consistent with intake rejection)
                self._deadlines.pop(task.id, None)
            self._parked = []
        return self.combined_schedule()

    def _validate_task(self, task: Task) -> None:
        """API-boundary validation: an empty or non-positive profile
        would otherwise surface as an opaque failure deep inside a
        flush, taking the whole pending queue down with it."""
        entries = list(task.times.items())
        if not entries:
            raise ValueError(
                f"task {task.id} has an empty profile — no instance "
                f"type can host it"
            )
        for key, dur in entries:
            if not dur > 0.0:
                raise ValueError(
                    f"task {task.id} has non-positive duration {dur!r} "
                    f"for profile entry {key!r}; execution times must "
                    f"be strictly positive"
                )

    # -- runtime feedback ---------------------------------------------------
    def report(
        self,
        task_id: int,
        event: str,
        t: float,
        end: float | None = None,
    ) -> None:
        """Feed runtime truth about a committed placement back in.

        ``event="completed"`` — the task actually finished at ``end``
        (default: ``t``, the report time).  An end matching the
        committed projection is a no-op; an early end frees capacity (a
        *shrink*, with an optional strict-win re-plan under
        ``config.replan``); a late end is a *stretch* — the conflicting
        tail is forced out and re-planned.  ``event="failed"`` — the
        attempt died at ``t``; its record is truncated into a failed
        occupancy slab and the task re-enters the queue through
        ``config.retry`` (or is reported permanently failed).  Either
        way the time advance runs straggler detection and fires any due
        flushes, exactly like :meth:`poll`.
        """
        t = float(t)
        if t < self.now - 1e-9:
            raise ValueError(f"time must be non-decreasing: {t} < {self.now}")
        self.now = max(self.now, t)
        if event == "completed":
            self._report_completed(task_id, t, end)
        elif event == "failed":
            self._report_failed(task_id, t)
        else:
            raise ValueError(
                f"unknown runtime event {event!r}; expected 'completed' "
                f"or 'failed' (stragglers are detected implicitly via "
                f"config.straggler_factor)"
            )
        self._advance(self.now)

    def _device_index(self, device) -> int:
        """Accept a pool index or the ``DeviceSpec`` itself."""
        if isinstance(device, int):
            return device
        for i, dev in enumerate(self.cluster.devices):
            if dev is device:
                return i
        raise ValueError(
            f"device {getattr(device, 'name', device)!r} is not in this "
            f"pool ({[d.name for d in self.cluster.devices]})"
        )

    def quarantine(self, device, t: float) -> list[int]:
        """Device ``device`` of the pool (index or ``DeviceSpec``) is
        lost at time ``t``.

        Not-yet-started placements on it are withdrawn and re-partitioned
        onto the surviving devices via the flush partitioner (tasks no
        survivor supports are parked for :meth:`recover`); attempts
        RUNNING on it at ``t`` died with it and go through the retry
        path.  Admission floors stop counting the device until recovery.
        Returns the ids of the attempts that died running.
        """
        t = float(t)
        if t < self.now - 1e-9:
            raise ValueError(f"time must be non-decreasing: {t} < {self.now}")
        if self.cluster is None:
            raise ValueError(
                "quarantine() needs a heterogeneous pool "
                "(SchedulingService(pool=cluster(...))): losing the only "
                "device leaves no surviving capacity to re-partition onto"
            )
        device = self._device_index(device)
        self.now = max(self.now, t)
        self._enter_fault_mode()
        withdrawn, running = self.mb.quarantine_device(device, t)
        for tid in running:
            it = self.mb.find_item(tid)
            self.mb.replace_item(
                tid, end_override=max(t, it.begin), failed=True
            )
            self._handle_failure(tid, t)
        parked_before = len(self._parked)
        self._replace_tasks(withdrawn, t)
        self.stats.outages.append(OutageEvent(
            device, t, None,
            withdrawn=tuple(task.id for task in withdrawn),
            died_running=tuple(running),
            parked=tuple(
                task.id for task in self._parked[parked_before:]
            ),
        ))
        self._advance(self.now)
        return list(running)

    def recover(self, device, t: float) -> None:
        """Quarantined device ``device`` (index or ``DeviceSpec``)
        returns to service at ``t``: its seam tail is floored at ``t``
        (alive instances cleared — the outage reset the partition) and
        parked tasks that fit again are re-admitted and re-planned."""
        t = float(t)
        if t < self.now - 1e-9:
            raise ValueError(f"time must be non-decreasing: {t} < {self.now}")
        if self.cluster is None:
            raise ValueError("recover() needs a heterogeneous pool")
        device = self._device_index(device)
        self.now = max(self.now, t)
        self.mb.recover_device(device, t)
        for i in range(len(self.stats.outages) - 1, -1, -1):
            ev = self.stats.outages[i]
            if ev.device == device and ev.recovered_at is None:
                self.stats.outages[i] = dataclasses.replace(
                    ev, recovered_at=t
                )
                break
        if self._parked:
            still: list[Task] = []
            readmit: list[Task] = []
            for task in self._parked:
                (readmit if self._placeable_now(task)
                 else still).append(task)
            self._parked = still
            self._replace_tasks(readmit, t)
        self._advance(self.now)

    def committed_items(self) -> list[ScheduledTask]:
        """Live committed placements across all segments (failed
        occupancy records excluded)."""
        return [
            it for seg in self.mb.segments for it in seg.items
            if not it.failed
        ]

    def committed_item(self, task_id: int) -> ScheduledTask | None:
        """The live committed placement of ``task_id``, or None."""
        return self.mb.find_item(task_id)

    @property
    def completions(self) -> dict[int, float]:
        """Actual completion times reported so far (task id -> time)."""
        return dict(self._completions)

    def next_wakeup(self) -> float | None:
        """Earliest future virtual time at which internal state changes
        on its own — a budget flush coming due or a retry release.  The
        closed-loop harness idles to here when no runtime events are
        queued; None = nothing scheduled."""
        cands: list[float] = []
        if self.pending:
            cands.append(self.pending[0][1] + self.config.max_wait_s)
        if self._requeue:
            cands.append(self._requeue[0][0])
        return min(cands) if cands else None

    def _report_completed(
        self, task_id: int, t: float, end: float | None
    ) -> None:
        it = self.mb.find_item(task_id)
        if it is None:
            raise ValueError(
                f"task {task_id} has no live committed placement to "
                f"report on (never committed, withdrawn, or failed)"
            )
        if task_id in self._completions:
            raise ValueError(f"task {task_id} was already reported completed")
        actual = t if end is None else float(end)
        if actual > t + 1e-9:
            raise ValueError(
                f"completion end {actual} lies in the future of the "
                f"report time {t}"
            )
        if it.begin > t + EPS:
            raise ValueError(
                f"task {task_id} is not running at {t}: its committed "
                f"placement begins at {it.begin}"
            )
        if actual < it.begin - EPS:
            raise ValueError(
                f"completion end {actual} precedes task {task_id}'s "
                f"begin {it.begin}"
            )
        self._completions[task_id] = actual
        self.stats.completed += 1
        old_end = it.end  # current projection (may already carry a stretch)
        if abs(actual - old_end) <= 1e-9:
            return  # runtime matched the books exactly: nothing to correct
        self._enter_fault_mode()
        self.mb.replace_item(task_id, end_override=actual)
        if actual > old_end + EPS:
            withdrawn = self._forced_replan(t, task_id)
            kind = "stretch"
        else:
            withdrawn = ()
            kind = "shrink"
            if self.config.replan:
                self._strict_win_replan(t)
        self.stats.corrections.append(CorrectionEvent(
            task_id, t, kind, old_end, actual, withdrawn
        ))

    def _report_failed(self, task_id: int, t: float) -> None:
        it = self.mb.find_item(task_id)
        if it is None:
            raise ValueError(
                f"task {task_id} has no live committed placement to "
                f"report on (never committed, withdrawn, or failed)"
            )
        if task_id in self._completions:
            raise ValueError(f"task {task_id} was already reported completed")
        if it.begin > t + EPS:
            raise ValueError(
                f"task {task_id} is not running at {t}: its committed "
                f"placement begins at {it.begin}"
            )
        self._enter_fault_mode()
        old_end = it.end
        new_end = max(t, it.begin)
        self.mb.replace_item(task_id, end_override=new_end, failed=True)
        self.stats.corrections.append(CorrectionEvent(
            task_id, t, "failure", old_end, new_end, ()
        ))
        self._handle_failure(task_id, t)
        if self.config.replan:
            # the truncated attempt freed committed room — optional
            # strict-win reclaim, same rule as flush re-planning
            self._strict_win_replan(t)

    def _handle_failure(self, task_id: int, t: float) -> None:
        """Route one failed attempt through the retry policy (or record
        it permanently failed)."""
        attempt = self._attempts.get(task_id, 1)
        retry = self.config.retry
        task = self._tasks.get(task_id)
        if retry is None or task is None or attempt >= retry.max_attempts:
            self.stats.failed.append(task_id)
            return
        nxt = attempt + 1
        self._attempts[task_id] = nxt
        demoted = False
        if retry.demote is not None:
            cand = retry.task_for_attempt(task, nxt)
            # demotion must keep the task placeable on the pool — a
            # shrunken profile that no device fully covers would blow
            # up the flush partitioner, so it is skipped
            if cand is not task and self._coverable(cand):
                task = cand
                demoted = True
                self._tasks[task_id] = task
        release = t + retry.backoff(attempt)
        self._rseq += 1
        heapq.heappush(
            self._requeue,
            (release, self._rseq, task, self._deadlines.get(task_id)),
        )
        self.stats.retries.append(RetryEvent(
            task_id, nxt, t, release, demoted
        ))

    def _check_stragglers(self, now: float) -> None:
        """Implicit straggler detection: a running placement whose
        observed runtime exceeds ``straggler_factor`` times its profiled
        duration without a completion report has its projected end
        stretched to ``now + (factor - 1) * profile`` and the
        conflicting tail force-re-planned.  Re-fires geometrically while
        the attempt keeps running past each new projection."""
        factor = self.config.straggler_factor
        candidates = [
            it.task.id for it in self.committed_items()
            if it.task.id not in self._completions
            and it.begin <= now - EPS
            and now > it.begin + factor * it.planned_duration + 1e-9
            and it.end <= now + 1e-9
        ]
        for tid in candidates:
            it = self.mb.find_item(tid)
            if it is None or it.failed:
                continue  # a previous iteration's re-plan resolved it
            if now <= it.begin + factor * it.planned_duration + 1e-9 \
                    or it.end > now + 1e-9:
                continue
            self._enter_fault_mode()
            old_end = it.end
            new_end = now + (factor - 1.0) * it.planned_duration
            self.mb.replace_item(tid, end_override=new_end)
            withdrawn = self._forced_replan(now, tid)
            self.stats.stragglers += 1
            self.stats.corrections.append(CorrectionEvent(
                tid, now, "straggler", old_end, new_end, withdrawn
            ))

    def _forced_replan(self, t: float, corrected_tid: int) -> tuple[int, ...]:
        """After a stretch the committed tail may be invalid (successors
        of the stretched item were planned against its old end): pull
        back everything not yet started plus any *unreported* placement
        now overlapping the stretched record, and re-plan the lot at
        ``t``.  Placements already reported completed keep their records
        — runtime truth is never rewritten (the invariant harness
        sanctions overlapping pairs of *corrected* records as feedback
        races; planned records never overlap)."""
        wd = self.mb.withdraw_uncommitted(t)
        it = self.mb.find_item(corrected_tid)
        if it is not None:
            cells = set(it.node.blocked_cells)
            phantoms = {
                o.task.id for o in self.committed_items()
                if o.task.id != corrected_tid
                and o.task.id not in self._completions
                and o.begin < it.end - EPS and o.end > it.begin + EPS
                and cells & set(o.node.blocked_cells)
            }
            if phantoms:
                wd = wd + self.mb.remove_items(phantoms)
        self._replace_tasks(wd, t)
        return tuple(task.id for task in wd)

    def _replace_tasks(self, tasks: list[Task], t: float) -> None:
        """Re-plan withdrawn tasks at time ``t`` (the fault path: forced
        re-plans and device loss).  Tasks no active device supports are
        parked for recovery."""
        if not tasks:
            return
        placeable: list[Task] = []
        for task in tasks:
            if self._placeable_now(task):
                placeable.append(task)
            else:
                self._parked.append(task)
        if not placeable:
            return
        t0 = time.perf_counter()
        self.mb.add_batch(placeable, not_before=t)
        wall = time.perf_counter() - t0
        fid = self._next_flush_id()
        for task in placeable:
            self.stats.decisions.append(Decision(
                task.id, self._arrivals.get(task.id, t), t, "fault",
                fid, wall, deadline=self._deadlines.get(task.id),
            ))
        self._attach_deadline_extras(placeable)

    def _placeable_now(self, task: Task) -> bool:
        if self.cluster is not None:
            return self.mb.supports_active(task)
        return True

    def _coverable(self, task: Task) -> bool:
        """Whether the (possibly demoted) task can still be planned —
        full profile coverage of some pool device, or of the single
        device's size set (FAR molds over the whole C_G)."""
        if self.cluster is not None:
            return self.cluster.supports(task)
        try:
            times = task.times_for(self.spec.device_kind)
        except KeyError:
            return False
        return all(s in times for s in self.spec.sizes)

    def _enter_fault_mode(self) -> None:
        if self._fault_mode:
            return
        self._fault_mode = True
        # the never-replanned shadow is a counterfactual over PROFILED
        # durations; once runtime truth lands it can no longer answer
        # for the stream — the primary chain carries the corrections
        self._baseline = None

    def _strict_win_replan(self, t: float) -> None:
        """Optional capacity-reclaim re-plan after a shrink/failure
        freed committed room, under the same strict-win rule as flush
        re-planning (only in fault mode, so no shadow mirroring)."""
        trial = self.mb.clone()
        wd = trial.withdraw_uncommitted(t)
        if not wd:
            return
        if any(not self._placeable_now(task) for task in wd):
            return  # mid-outage: the optional reclaim is not worth a park
        self.stats.replan_attempts += 1
        t0 = time.perf_counter()
        plain_makespan = self.mb.makespan
        trial.add_batch(wd, not_before=t)
        if trial.makespan >= plain_makespan - self.config.eps:
            return
        wall = time.perf_counter() - t0
        fid = self._next_flush_id()
        self.mb = trial
        self.stats.replan_wins += 1
        self.stats.withdrawn += len(wd)
        for task in wd:
            self.stats.decisions.append(Decision(
                task.id, self._arrivals.get(task.id, t), t, "replan",
                fid, wall, deadline=self._deadlines.get(task.id),
            ))
        self.stats.replan_events.append(ReplanEvent(
            fid, t, tuple(task.id for task in wd),
            trial.makespan, plain_makespan,
        ))

    # -- admission ---------------------------------------------------------
    def completion_lower_bound(self, task: Task, at: float) -> float:
        """Provable floor on ``task``'s completion if submitted at ``at``.

        Placements are causal (nothing begins before the decision that
        placed it, and the decision is no earlier than the arrival) and
        running work is never preempted, so a feasible instance cannot
        host the task before every slice it blocks clears of the work
        already *running* at ``at``.  Queued-but-unstarted placements are
        ignored (re-planning may pull them back), as are creation costs
        and queueing — the bound stays admissible.  With re-planning the
        service may report either the re-planning chain or the
        never-replanned shadow, so the bound is the minimum over both:
        no schedule the service can still produce finishes the task
        earlier, whichever chain wins.
        """
        best = self._chain_lower_bound(self.mb, task, at)
        if self._baseline is not None:
            best = min(
                best, self._chain_lower_bound(self._baseline, task, at)
            )
        return best

    def _node_candidates(self, task: Task):
        """(instance node, size-keyed times) pairs the task could run on —
        every node of the single device, or every supported device of the
        pool with the task's times lowered onto that device's kind."""
        if self.cluster is not None:
            devices = [
                dev for i, dev in enumerate(self.cluster.devices)
                if self.mb.active[i]  # quarantined capacity doesn't count
            ]
        else:
            devices = (self.spec,)
        for dev in devices:
            if not task.supports(dev.device_kind):
                continue
            times = task.times_for(dev.device_kind)
            for node in dev.nodes:
                if node.size in times:
                    yield node, times

    def _chain_lower_bound(self, mb, task: Task, at: float) -> float:
        busy: dict[tuple[int, int], float] = {}
        for seg in mb.segments:
            if seg.makespan <= at:
                continue  # fully finished by `at`: nothing still running
            for it in seg.items:
                if it.begin <= at + EPS and it.end > at:
                    for cell in it.node.blocked_cells:
                        if it.end > busy.get(cell, 0.0):
                            busy[cell] = it.end
        best = math.inf
        for node, times in self._node_candidates(task):
            floor = at
            for cell in node.blocked_cells:
                b = busy.get(cell, 0.0)
                if b > floor:
                    floor = b
            done = floor + times[node.size]
            if done < best:
                best = done
        return best

    def _admit(self, task: Task, arrival: float, deadline: float) -> str:
        if self.config.admission == "none":
            return "queued"
        if self.completion_lower_bound(task, arrival) <= deadline + EPS:
            return "queued"
        if self.config.admission == "reject":
            self.stats.rejected.append(task.id)
            return "rejected"
        self.stats.demoted.append(task.id)
        return "demoted"

    # -- internals ---------------------------------------------------------
    def _advance(self, now: float) -> None:
        if self.config.straggler_factor is not None:
            self._check_stragglers(now)
        self._release_due(now)
        self._advance_budget(now)

    def _advance_budget(self, now: float) -> None:
        # every pending task arrived within max_wait_s of the oldest (any
        # later arrival would have fired this flush first), so one deadline
        # empties the whole queue
        if self.pending and now - self.pending[0][1] >= self.config.max_wait_s:
            deadline = self.pending[0][1] + self.config.max_wait_s
            self._flush_pending(decided_at=deadline)

    def _release_due(self, now: float) -> None:
        """Move retries whose backoff floor has passed into the pending
        queue, in release order, firing any budget flush due *before*
        each release — the same discipline ``submit`` follows, so the
        flush-decision invariant (every pending task arrived within
        max_wait_s of the oldest) keeps holding."""
        while self._requeue and self._requeue[0][0] <= now + 1e-12:
            release, _, task, deadline = heapq.heappop(self._requeue)
            self._advance_budget(release)
            self._arrivals[task.id] = release  # the retry's re-arrival
            self.pending.append((task, release, deadline))
            if len(self.pending) >= self.config.max_batch:
                self._flush_pending(decided_at=release)

    def _flush_pending(self, decided_at: float) -> None:
        batch, self.pending = self.pending, []
        batch = self._park_unplaceable(batch)
        if not batch:
            return
        if len(batch) < self.config.min_batch:
            # slow trickle: too few tasks accumulated within the budget for
            # an offline batch to pay off — place them greedily instead
            self._route_online(batch, decided_at)
            return
        t0 = time.perf_counter()
        arrivals = [task for task, _, _ in batch]
        if self._baseline is not None:  # chains diverged: mirror the flush
            self._baseline.add_batch(arrivals, not_before=decided_at)
        # nothing may start before the flush decision that placed it
        withdrawn, plain_makespan = self._flush_batch(arrivals, decided_at)
        wall = time.perf_counter() - t0
        fid = self._next_flush_id()
        self.stats.batches += 1
        for task, arrival, deadline in batch:
            self.stats.decisions.append(Decision(
                task.id, arrival, decided_at, "batch", fid, wall,
                deadline=deadline,
            ))
        for task in withdrawn:
            self.stats.decisions.append(Decision(
                task.id, self._arrivals.get(task.id, decided_at), decided_at,
                "replan", fid, wall,
                deadline=self._deadlines.get(task.id),
            ))
        self._attach_deadline_extras(arrivals + withdrawn)
        if withdrawn:
            self.stats.replan_events.append(ReplanEvent(
                fid, decided_at, tuple(t.id for t in withdrawn),
                self.mb.makespan, plain_makespan,
            ))

    def _flush_batch(self, arrivals: list[Task], decided_at: float
                     ) -> tuple[list[Task], float]:
        """Commit one batch flush on the primary chain; returns the tasks
        a kept re-plan pulled back (empty without ``config.replan``) and
        the plain candidate's combined makespan for the event log."""
        if not self.config.replan:
            self.mb.add_batch(arrivals, not_before=decided_at)
            return [], 0.0
        # candidate A — the plain flush: arrivals against the committed tail
        plain = self.mb.clone()
        plain.add_batch(arrivals, not_before=decided_at)
        # candidate B — the re-plan: pull the not-yet-started tail back and
        # schedule it together with the arrivals under the same policy
        trial = self.mb.clone()
        withdrawn = trial.withdraw_uncommitted(decided_at)
        if not withdrawn:
            # nothing to revisit: the flush is bit-identical to replan=False
            self.mb = plain
            return [], 0.0
        self.stats.replan_attempts += 1
        trial.add_batch(withdrawn + arrivals, not_before=decided_at)
        if trial.makespan < plain.makespan - self.config.eps:
            if self._baseline is None and not self._fault_mode:
                # first divergence: the plain candidate IS the
                # never-replanned continuation — it becomes the shadow
                # (not in fault mode: the shadow is a profiled-duration
                # counterfactual and runtime truth has already landed)
                self._baseline = plain
            self.mb = trial
            self.stats.replan_wins += 1
            self.stats.withdrawn += len(withdrawn)
            return withdrawn, plain.makespan
        self.mb = plain
        return [], 0.0

    def _attach_deadline_extras(self, tasks: Sequence[Task]) -> None:
        """Record the flushed batch's SLO picture on its PlanResult: the
        retained deadlines and each one's slack against the planned
        completion (negative slack = the plan already misses it)."""
        deadlines = {
            t.id: self._deadlines[t.id] for t in tasks
            if t.id in self._deadlines
        }
        if not deadlines or not self.mb.results:
            return
        # only the just-flushed placements are needed (the deadlines dict
        # is restricted to this batch) — rebuilding the whole combined
        # schedule here would make a long-running service O(F^2)
        ends: dict[int, float] = {}
        for it in self.mb.last_flush_items():
            ends[it.task.id] = it.end
        plan = self.mb.results[-1]
        plan.extras["deadlines"] = deadlines
        plan.extras["deadline_slack"] = {
            tid: dl - ends[tid] for tid, dl in deadlines.items()
            if tid in ends
        }

    def _route_online(
        self,
        batch: Sequence[tuple[Task, float, float | None]],
        decided_at: float,
    ) -> None:
        batch = self._park_unplaceable(batch)
        if not batch:
            return
        t0 = time.perf_counter()
        withdrawn: list[Task] = []
        plain_makespan = 0.0
        mirror_batch = True  # whether the shadow still needs this trickle
        if self.config.replan:
            # the same two-candidate strict-win rule as a batch flush: a
            # trickle with a withdrawable tail behind it can still pull
            # the tail back (the fault path depends on this — a
            # straggler report during a trickle can rescue deadline
            # work).  With no tail to pull back this reduces to the
            # plain greedy placement, bit-identically.
            plain = self.mb.clone()
            plain.online_place(batch, decided_at)
            trial = self.mb.clone()
            wd = trial.withdraw_uncommitted(decided_at)
            if wd:
                self.stats.replan_attempts += 1
                trial.add_batch(
                    wd + [task for task, _, _ in batch],
                    not_before=decided_at,
                )
                if trial.makespan < plain.makespan - self.config.eps:
                    if self._baseline is None and not self._fault_mode:
                        self._baseline = plain
                        mirror_batch = False  # plain already carries it
                    self.mb = trial
                    withdrawn = wd
                    plain_makespan = plain.makespan
                    self.stats.replan_wins += 1
                    self.stats.withdrawn += len(wd)
                else:
                    self.mb = plain
            else:
                self.mb = plain
        else:
            # polymorphic: MultiBatchScheduler floors its single tail and
            # greedy-places; ClusterMultiBatchScheduler additionally picks
            # a device per task via speculative greedy previews
            self.mb.online_place(batch, decided_at)
        if self._baseline is not None and mirror_batch:
            self._baseline.online_place(batch, decided_at)
        wall = time.perf_counter() - t0
        fid = self._next_flush_id()
        if withdrawn:
            # the trickle was absorbed into a batch re-plan
            self.stats.batches += 1
            for task, arrival, deadline in batch:
                self.stats.decisions.append(Decision(
                    task.id, arrival, decided_at, "batch", fid, wall,
                    deadline=deadline,
                ))
            for task in withdrawn:
                self.stats.decisions.append(Decision(
                    task.id, self._arrivals.get(task.id, decided_at),
                    decided_at, "replan", fid, wall,
                    deadline=self._deadlines.get(task.id),
                ))
            self._attach_deadline_extras(
                [task for task, _, _ in batch] + withdrawn
            )
            self.stats.replan_events.append(ReplanEvent(
                fid, decided_at, tuple(t.id for t in withdrawn),
                self.mb.makespan, plain_makespan,
            ))
            return
        self.stats.online_placements += len(batch)
        for task, arrival, deadline in batch:
            self.stats.decisions.append(Decision(
                task.id, arrival, decided_at, "online", fid, wall,
                deadline=deadline,
            ))

    def _park_unplaceable(
        self, batch: Sequence[tuple[Task, float, float | None]]
    ) -> list[tuple[Task, float, float | None]]:
        """During a pool outage, hold back tasks no *surviving* device
        supports (they passed intake against the full pool): they park
        until the device recovers instead of blowing up the flush."""
        if self.cluster is None or all(self.mb.active):
            return list(batch)
        live: list[tuple[Task, float, float | None]] = []
        for item in batch:
            if self.mb.supports_active(item[0]):
                live.append(item)
            else:
                self._parked.append(item[0])
        return live

    def _next_flush_id(self) -> int:
        self._flush_id += 1
        return self._flush_id

    # -- reporting ---------------------------------------------------------
    @property
    def _winner(self) -> MultiBatchScheduler:
        """The chain every report answers from: the re-planning chain,
        unless the never-replanned shadow is strictly ahead."""
        if self._baseline is not None \
                and self._baseline.makespan < self.mb.makespan:
            return self._baseline
        return self.mb

    @property
    def makespan(self) -> float:
        return self._winner.makespan

    @property
    def tail(self):
        return self._winner.tail

    def combined_schedule(self) -> Schedule:
        return self._winner.combined_schedule()

    def deadline_report(self) -> dict:
        """Score the retained deadlines against the combined schedule —
        meaningful after :meth:`drain` (a task still pending counts as a
        miss: it has no completion).  Demoted and rejected tasks are
        reported separately and never count as misses.  Runtime truth
        wins: reported completions overlay the projections, and
        permanently failed tasks always count as misses."""
        ends: dict[int, float] = {}
        for it in self.combined_schedule().items:
            if not it.failed:
                ends[it.task.id] = it.end
        ends.update(self._completions)
        failed = set(self.stats.failed)
        missed = sorted(
            tid for tid, dl in self._deadlines.items()
            if tid in failed or ends.get(tid, math.inf) > dl + EPS
        )
        tracked = len(self._deadlines)
        return {
            "tracked": tracked,
            "missed": missed,
            "miss_rate": len(missed) / tracked if tracked else 0.0,
            "rejected": sorted(self.stats.rejected),
            "demoted": sorted(self.stats.demoted),
            "failed": sorted(failed),
        }


__all__ = [
    "SchedulingService",
    "ServiceStats",
    "Decision",
    "ReplanEvent",
    "CorrectionEvent",
    "RetryEvent",
    "OutageEvent",
]
