"""Synthetic task-time generator (paper §6.3), reimplemented exactly.

Each task "exploits well" up to a target instance size ``s_max`` drawn from
the configured percentages ``p_s``.  Times are generated for every integer
slice count, then restricted to ``C_G``:

    t(1) ~ U(t_min, t_max)
    t(s+1) = (s + r) / (s + 1) * t(s)

with ``r`` drawn per increment from clipped normals by speedup type —
super-linear  N(-0.25, 0.25) clipped to [-0.5, 0]
near-linear   N( 0.10, 0.10) clipped to [ 0.0, 0.2]
sub-linear    N( 0.75, 0.25) clipped to [ 0.5, 1.0]

A ``p_sup`` fraction of each group starts memory-bound: super-linear
increments until a Bernoulli(0.3)-per-slice transition to compute-bound,
after which increments are sub-linear (paper §6.3's A30 walkthrough);
compute-bound tasks scale near-linearly up to ``s_max``; all increments
beyond ``s_max`` are sub-linear.  ``r <= 1`` guarantees monotone times
(paper monotony point 1).

Workload presets mirror the paper: PoorScaling / MixedScaling / GoodScaling
× WideTimes / NarrowTimes.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from repro.core.device_spec import DeviceSpec
from repro.core.problem import Task

TRANSITION_P = 0.3  # memory-bound -> compute-bound, per slice increment


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    name: str
    p_exploit: Mapping[int, float]   # instance size -> % of tasks (sums to 100)
    p_sup: float = 50.0              # % of each group starting memory-bound
    t_min: float = 1.0
    t_max: float = 100.0


def _r_super(rng: np.random.Generator) -> float:
    return float(np.clip(rng.normal(-0.25, 0.25), -0.5, 0.0))


def _r_near(rng: np.random.Generator) -> float:
    return float(np.clip(rng.normal(0.10, 0.10), 0.0, 0.2))


def _r_sub(rng: np.random.Generator) -> float:
    return float(np.clip(rng.normal(0.75, 0.25), 0.5, 1.0))


def _group_counts(n: int, cfg: WorkloadConfig) -> dict[int, int]:
    """Floor the percentages, then iteratively bump the size farthest from
    its exact share (paper §6.3 footnote 8)."""
    sizes = sorted(cfg.p_exploit)
    counts = {s: int(np.floor(n * cfg.p_exploit[s] / 100.0)) for s in sizes}
    while sum(counts.values()) < n:
        j = max(sizes, key=lambda s: n * cfg.p_exploit[s] / 100.0 - counts[s])
        counts[j] += 1
    return counts


def generate_tasks(
    n: int,
    spec: DeviceSpec,
    cfg: WorkloadConfig,
    seed: int = 0,
    id_offset: int = 0,
) -> list[Task]:
    rng = np.random.default_rng(seed)
    max_size = max(spec.sizes)
    counts = _group_counts(n, cfg)

    tasks: list[Task] = []
    tid = id_offset
    for s_max, count in sorted(counts.items()):
        n_sup = int(np.ceil(cfg.p_sup / 100.0 * count)) if s_max >= 2 else 0
        for k in range(count):
            memory_bound = k < n_sup
            t = float(rng.uniform(cfg.t_min, cfg.t_max))
            times = {1: t}
            mb = memory_bound
            for s in range(1, max_size):
                if s + 1 > s_max:
                    r = _r_sub(rng)
                elif memory_bound:
                    if mb:
                        r = _r_super(rng)
                        if rng.uniform() < TRANSITION_P:
                            mb = False  # becomes compute-bound from next size
                    else:
                        r = _r_sub(rng)
                else:
                    r = _r_near(rng)
                t = (s + r) / (s + 1) * t
                times[s + 1] = t
            profile = {s: times[s] for s in spec.sizes}
            tasks.append(Task(id=tid, times=profile, name=f"synth{tid}"))
            tid += 1
    # deterministic shuffle so FIFO baselines do not see grouped sizes
    order = rng.permutation(len(tasks))
    return [
        dataclasses.replace(tasks[i], id=id_offset + j)
        for j, i in enumerate(order)
    ]


# --- paper workload presets (A100/H100 percentages, §6.3) -------------------

def poor_scaling(spec: DeviceSpec) -> dict[int, float]:
    sizes = spec.sizes
    out = {s: 0.0 for s in sizes}
    out[sizes[0]] = 50.0
    out[sizes[1]] = 50.0
    return out


def mixed_scaling(spec: DeviceSpec) -> dict[int, float]:
    share = 100.0 / len(spec.sizes)
    return {s: share for s in spec.sizes}


def good_scaling(spec: DeviceSpec) -> dict[int, float]:
    sizes = spec.sizes
    out = {s: 0.0 for s in sizes}
    out[sizes[-2]] = 50.0
    out[sizes[-1]] = 50.0
    return out


def workload(
    scaling: str, times: str, spec: DeviceSpec, p_sup: float = 50.0
) -> WorkloadConfig:
    """Build one of the six paper workloads, e.g. ("mixed", "wide")."""
    p = {
        "poor": poor_scaling,
        "mixed": mixed_scaling,
        "good": good_scaling,
    }[scaling](spec)
    t_min, t_max = {"wide": (1.0, 100.0), "narrow": (90.0, 100.0)}[times]
    return WorkloadConfig(
        name=f"{scaling.capitalize()}Scaling,{times.capitalize()}Times",
        p_exploit=p,
        p_sup=p_sup,
        t_min=t_min,
        t_max=t_max,
    )


ALL_WORKLOADS: Sequence[tuple[str, str]] = (
    ("poor", "narrow"), ("poor", "wide"),
    ("mixed", "narrow"), ("mixed", "wide"),
    ("good", "narrow"), ("good", "wide"),
)


# --- heterogeneous-cluster workloads (instance-type-keyed Profiles) ---------

#: default per-slice speed of each device kind relative to A30 == 1.0
#: (rough public-spec compute ratios; benchmark knob, not a measurement)
KIND_SPEED: Mapping[str, float] = {
    "A30": 1.0,
    "A100": 1.6,
    "H100": 2.6,
    "TPU_POD_256": 4.0,
}


def generate_cluster_tasks(
    n: int,
    cspec,
    scaling: str,
    times: str,
    seed: int = 0,
    id_offset: int = 0,
    speed: Mapping[str, float] | None = None,
):
    """Profile-keyed tasks for a heterogeneous cluster.

    One paper-recurrence base profile is drawn per task over the union of
    all devices' instance sizes, then each device kind sees it restricted
    to that kind's ``C_G`` and divided by the kind's per-slice ``speed``
    factor (default :data:`KIND_SPEED`) — so an A100 slice runs the same
    task faster than an A30 slice, which is what makes device choice a
    real scheduling decision.  ``cspec`` is a
    :class:`~repro.core.cluster.ClusterSpec` (duck-typed: only
    ``.devices`` is read).
    """
    from repro.core.problem import Profile

    devices = list(cspec.devices)
    union = tuple(sorted({s for d in devices for s in d.sizes}))
    pseudo = dataclasses.replace(devices[0], sizes=union)
    base = generate_tasks(
        n, pseudo, workload(scaling, times, pseudo), seed=seed,
        id_offset=id_offset,
    )
    speed = dict(KIND_SPEED) | dict(speed or {})
    kinds: dict[str, object] = {}
    for d in devices:
        kinds.setdefault(d.device_kind, d)
    out = []
    for t in base:
        table = {
            kind: {s: t.times[s] / float(speed.get(kind, 1.0))
                   for s in d.sizes}
            for kind, d in kinds.items()
        }
        out.append(dataclasses.replace(t, times=Profile(table)))
    return out
