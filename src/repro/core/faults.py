"""Deterministic fault model for closed-loop serving (beyond-paper;
cf. MIG-Serving, arXiv:2109.11067 — *reconfigurable machine scheduling*
where the plan must survive runtime change — and scheduler-driven job
atomization, arXiv:2509.19086, which makes recovery granularity a
scheduler-level concern).

The paper's schedules are open-loop: FAR plans from profiled durations
and assumes every instance, reconfiguration and task completes exactly as
modeled.  This module provides the pieces that let the serving facade
close the loop and lets tests/benchmarks exercise it *deterministically*:

* :class:`RetryPolicy` — capped exponential backoff on the re-release
  time of a failed task, with optional demotion (any
  ``demote(task, attempt) -> Task`` hook; :func:`demote_shrink` drops the
  largest profile size per kind, using the PR 5 instance-typed
  :class:`~repro.core.problem.Profile` machinery);
* :class:`FaultSpec` / :class:`FaultInjector` — a seeded fault source:
  per-task lognormal profile noise, straggler inflation, Poisson task
  failures (rate per second of runtime) and per-device MTBF outage
  windows.  Every draw is keyed on ``(seed, stream, task_id, attempt)``
  (integers only, so the draws are stable across processes and across
  re-planning — a withdrawn-and-replaced placement keeps its fate);
* :func:`run_with_faults` — the closed-loop harness: an event loop that
  feeds a :class:`~repro.core.service.SchedulingService` arrival +
  runtime-truth events (completions, failures, device losses/recoveries)
  in virtual-time order and keeps the service's committed bookkeeping in
  sync with what the injector says actually happened;
* :func:`execute_open_loop` — the no-feedback baseline executor: the
  same faults applied to a *final frozen plan* (per-cell work-conserving
  dispatch, no retries, no corrections), so benchmarks can score the
  closed loop against exactly the counterfactual the paper assumes.

With ``FaultSpec()`` (all rates zero) the injector draws every duration
exactly at profile and never fails anything — the harness then reports
every completion at its planned end and the service's plans stay
bit-identical to the pre-feedback behaviour (pinned by the differential
tests in ``tests/test_faults.py``).
"""

from __future__ import annotations

import dataclasses
import heapq
import math
import random
from typing import Callable, Sequence

from repro.core.problem import EPS, Profile, Task


# ---------------------------------------------------------------------------
# RetryPolicy
# ---------------------------------------------------------------------------


def demote_shrink(task: Task, attempt: int) -> Task | None:
    """Demotion hook: drop the largest profile size of every instance
    kind (the failed attempt's biggest slice is the prime suspect for the
    failure — OOM, thermals — so the retry molds onto smaller slices).
    Returns ``None`` when every kind is already down to one size (no
    demotion left; the retry keeps the previous profile)."""
    times = task.times
    if isinstance(times, Profile):
        table = {}
        shrunk = False
        for kind in times.kinds:
            per = dict(times.for_kind(kind))
            if len(per) > 1:
                per.pop(max(per))
                shrunk = True
            table[kind] = per
        if not shrunk:
            return None
        return dataclasses.replace(task, times=Profile(table))
    per = dict(times)
    if len(per) <= 1:
        return None
    per.pop(max(per))
    return dataclasses.replace(task, times=per)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """What happens to a task reported ``failed``.

    The next attempt is re-released ``backoff(attempt)`` seconds after
    the failure report: ``min(backoff_cap, backoff_base * 2**(attempt-1))``
    for the failure of attempt number ``attempt`` (1-based) — capped
    exponential backoff.  ``demote`` is an optional
    ``(task, next_attempt) -> Task | None`` hook applied to the retried
    task (e.g. :func:`demote_shrink`); returning ``None`` keeps the
    task unchanged.  ``max_attempts`` bounds the total number of
    attempts; the failure of attempt ``max_attempts`` is permanent.
    """

    max_attempts: int = 3
    backoff_base: float = 0.5
    backoff_cap: float = 8.0
    demote: Callable[[Task, int], Task | None] | None = None

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(
                f"RetryPolicy.max_attempts must be >= 1, got "
                f"{self.max_attempts}"
            )
        if self.backoff_base < 0.0 or self.backoff_cap < 0.0:
            raise ValueError("RetryPolicy backoff times must be >= 0")

    def backoff(self, attempt: int) -> float:
        """Delay before re-releasing the attempt after ``attempt`` fails."""
        if attempt < 1:
            raise ValueError(f"attempt numbers are 1-based, got {attempt}")
        return min(self.backoff_cap, self.backoff_base * 2.0 ** (attempt - 1))

    def task_for_attempt(self, task: Task, attempt: int) -> Task:
        """The task object attempt number ``attempt`` should submit
        (demoted when the hook applies, otherwise unchanged)."""
        if self.demote is None:
            return task
        out = self.demote(task, attempt)
        return task if out is None else out


# ---------------------------------------------------------------------------
# SpeculationPolicy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SpeculationPolicy:
    """Throttle for straggler speculation (``SchedulerConfig(speculation=)``).

    When the service flags a straggler it may launch a *backup attempt*
    on the best alternative placement instead of only stretching the
    plan; the first finisher wins and the loser is cancelled.  At most
    ``max_inflight`` backup attempts race at any instant, and a backup is
    only launched when its planned completion beats the straggler's
    stretched projection by at least ``min_gain_s`` seconds (checked
    twice: against the admission lower bound first, then against the
    actual trial placement)."""

    max_inflight: int = 1
    min_gain_s: float = 0.0

    def __post_init__(self):
        if self.max_inflight < 1:
            raise ValueError(
                f"SpeculationPolicy.max_inflight must be >= 1, got "
                f"{self.max_inflight}"
            )
        if self.min_gain_s < 0.0:
            raise ValueError("SpeculationPolicy.min_gain_s must be >= 0")


# ---------------------------------------------------------------------------
# ProfileCalibration
# ---------------------------------------------------------------------------


class ProfileCalibration:
    """Online EWMA calibration of profiled durations from runtime truth
    (``SchedulerConfig(calibration=)``).

    ``report(end=)`` corrections feed actual/planned duration ratios into
    exponentially-weighted running means keyed, most-specific first, by
    ``(task family, device_kind, size)``, then ``(family, device_kind)``,
    then ``family`` alone (the task family is ``task.name``); lookups
    fall through that hierarchy and default to 1.0.  :meth:`calibrate`
    returns a task whose profile entries are scaled by their learned
    ratios — the service applies it at the *policy boundary* only, so the
    stored task (and therefore the fault injector's ground truth and the
    exactly-once bookkeeping) always keeps the raw submitted profile.

    Determinism: the state is an explicit input evolved solely by the
    ``observe`` call sequence — never wall-clock — so plan bytes remain a
    pure function of (tasks, spec, config, seed, reports).  A freshly
    constructed instance calibrates every task to itself, which is what
    makes ``calibration=ProfileCalibration()`` a no-op layer until the
    first report lands."""

    def __init__(self, alpha: float = 0.25):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(
                f"ProfileCalibration.alpha must be in (0, 1], got {alpha}"
            )
        self.alpha = alpha
        self._exact: dict[tuple[str, str, int], float] = {}
        self._kind: dict[tuple[str, str], float] = {}
        self._family: dict[str, float] = {}
        self._n_obs = 0

    @staticmethod
    def family(task: Task) -> str:
        return task.name or ""

    @property
    def observations(self) -> int:
        return self._n_obs

    def observe(
        self, task: Task, kind: str, size: int, planned: float, actual: float
    ) -> None:
        """Fold one completed attempt's actual/planned ratio into the
        running means at every key level."""
        if planned <= 0.0 or actual <= 0.0:
            return
        ratio = actual / planned
        fam = self.family(task)
        a = self.alpha
        for key, store in (
            ((fam, str(kind), int(size)), self._exact),
            ((fam, str(kind)), self._kind),
            (fam, self._family),
        ):
            old = store.get(key)
            store[key] = ratio if old is None else (1.0 - a) * old + a * ratio
        self._n_obs += 1

    def factor(self, family: str, kind: str | None, size: int | None) -> float:
        """The learned correction ratio, most-specific key first."""
        if kind is not None and size is not None:
            f = self._exact.get((family, str(kind), int(size)))
            if f is not None:
                return f
        if kind is not None:
            f = self._kind.get((family, str(kind)))
            if f is not None:
                return f
        return self._family.get(family, 1.0)

    def calibrate(self, task: Task, kind: str | None = None) -> Task:
        """``task`` with every profile entry scaled by its learned ratio.

        For a plain size-keyed task ``kind`` names the device kind the
        caller plans for (``None`` falls back to family-level ratios).
        Identity — the very same object — when nothing has been learned,
        or when every applicable ratio is exactly 1.0."""
        if not self._n_obs:
            return task
        fam = self.family(task)
        times = task.times
        changed = False
        if isinstance(times, Profile):
            table: dict[tuple[str, int], float] = {}
            for k in times.kinds:
                for s, t in times.for_kind(k).items():
                    f = self.factor(fam, k, s)
                    table[(k, s)] = t * f
                    changed = changed or f != 1.0
            if not changed:
                return task
            return dataclasses.replace(task, times=Profile(table))
        plain: dict[int, float] = {}
        for s, t in times.items():
            f = self.factor(fam, kind, s)
            plain[int(s)] = t * f
            changed = changed or f != 1.0
        if not changed:
            return task
        return dataclasses.replace(task, times=plain)


# ---------------------------------------------------------------------------
# FaultSpec / FaultInjector
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Rates and distributions of the seeded fault model.  All-zero
    defaults = a perfect machine (the injector becomes a no-op)."""

    seed: int = 0
    # lognormal sigma on actual durations (0 = exactly at profile)
    noise_sigma: float = 0.0
    # probability a given attempt runs `straggler_factor` x its profile
    straggler_prob: float = 0.0
    straggler_factor: float = 3.0
    # Poisson failure rate per second of (actual) runtime
    task_fail_rate: float = 0.0
    # per-device mean time between losses (None = devices never fail)
    device_mtbf_s: float | None = None
    device_repair_s: float = 30.0
    max_device_losses: int = 2
    # correlated failure domains: groups of device indices that share a
    # failure source (rack PDU, driver host, NVSwitch plane).  A domain
    # shock takes every member down *together* — one shared draw per
    # (seed, domain, epoch), not independent per-device Poisson — so the
    # survivor re-partition path is exercised at realistic scale.
    domains: tuple = ()
    domain_mtbf_s: float | None = None
    domain_repair_s: float = 30.0
    max_domain_shocks: int = 2

    def __post_init__(self):
        if self.straggler_factor <= 1.0:
            raise ValueError("FaultSpec.straggler_factor must exceed 1.0")
        for f in ("noise_sigma", "straggler_prob", "task_fail_rate",
                  "device_repair_s", "domain_repair_s"):
            if getattr(self, f) < 0.0:
                raise ValueError(f"FaultSpec.{f} must be >= 0")
        if self.domain_mtbf_s is not None and self.domain_mtbf_s <= 0.0:
            raise ValueError("FaultSpec.domain_mtbf_s must be > 0")
        if self.max_domain_shocks < 0:
            raise ValueError("FaultSpec.max_domain_shocks must be >= 0")
        domains = tuple(
            tuple(int(d) for d in dom) for dom in self.domains
        )
        if any(not dom for dom in domains):
            raise ValueError("FaultSpec.domains entries must be non-empty")
        object.__setattr__(self, "domains", domains)


@dataclasses.dataclass(frozen=True)
class ExecutionDraw:
    """The injector's verdict on one attempt: how long it actually runs
    and whether (and when, relative to its start) it fails."""

    duration: float            # actual runtime if it completes
    fail_after: float | None   # seconds after start the attempt dies

    @property
    def fails(self) -> bool:
        return self.fail_after is not None


# integer stream tags: draw keys must stay hash-stable across processes,
# so they are tuples of ints only (str hashing is randomized per run)
_STREAM_EXEC = 1
_STREAM_DEVICE = 2
_STREAM_DOMAIN = 3


class FaultInjector:
    """Deterministic fault source: every draw is a pure function of
    ``(spec.seed, stream, id, attempt)``, independent of draw order —
    re-planning, withdrawal and re-admission never change a task's fate,
    which is what makes closed-loop runs reproducible and comparable
    against the open-loop baseline under the *same* faults."""

    def __init__(self, spec: FaultSpec | None = None, **kw):
        self.spec = spec if spec is not None else FaultSpec(**kw)

    def _rng(self, stream: int, *key: int) -> random.Random:
        # fold the key into one integer seed (fnv-style) — deterministic
        # across processes, unlike tuple hashing, and draw-order-free
        x = 0xCBF29CE484222325
        for v in (self.spec.seed, stream) + key:
            x = ((x ^ (int(v) & 0xFFFFFFFFFFFFFFFF)) * 0x100000001B3) \
                & 0xFFFFFFFFFFFFFFFF
        return random.Random(x)

    @property
    def enabled(self) -> bool:
        """Whether any fault channel is active (False = perfect machine)."""
        s = self.spec
        return bool(
            s.noise_sigma > 0.0 or s.straggler_prob > 0.0
            or s.task_fail_rate > 0.0 or s.device_mtbf_s is not None
            or (s.domain_mtbf_s is not None and s.domains)
        )

    def draw_execution(
        self, task_id: int, attempt: int, planned: float
    ) -> ExecutionDraw:
        """Actual runtime (and failure point, if any) for one attempt of
        a task whose profile promises ``planned`` seconds."""
        s = self.spec
        if not self.enabled:
            return ExecutionDraw(duration=planned, fail_after=None)
        rng = self._rng(_STREAM_EXEC, task_id, attempt)
        dur = planned
        if s.noise_sigma > 0.0:
            dur *= rng.lognormvariate(0.0, s.noise_sigma)
        if s.straggler_prob > 0.0 and rng.random() < s.straggler_prob:
            dur *= s.straggler_factor
        fail_after = None
        if s.task_fail_rate > 0.0:
            # Poisson process over the attempt's actual runtime: the
            # first arrival lands inside [0, dur) with p = 1 - e^(-r*dur)
            x = rng.expovariate(s.task_fail_rate)
            if x < dur:
                fail_after = x
        return ExecutionDraw(duration=dur, fail_after=fail_after)

    def device_outages(
        self, device: int, horizon: float
    ) -> list[tuple[float, float]]:
        """Seeded ``(lost_at, recovered_at)`` windows for one device over
        ``[0, horizon)`` — exponential inter-loss times with mean
        ``device_mtbf_s``, fixed repair time, at most
        ``max_device_losses`` windows, non-overlapping."""
        s = self.spec
        if s.device_mtbf_s is None:
            return []
        rng = self._rng(_STREAM_DEVICE, device)
        out: list[tuple[float, float]] = []
        t = 0.0
        while len(out) < s.max_device_losses:
            t += rng.expovariate(1.0 / s.device_mtbf_s)
            if t >= horizon:
                break
            rec = t + s.device_repair_s
            out.append((t, rec))
            t = rec
        return out

    def domain_outages(
        self, domain: int, horizon: float
    ) -> list[tuple[float, float]]:
        """Seeded ``(shock_at, recovered_at)`` windows for failure domain
        index ``domain`` over ``[0, horizon)``.  Every member device of
        the domain goes down and comes back *together* at these instants.
        Each epoch's inter-shock gap is an independent pure draw keyed
        ``(seed, _STREAM_DOMAIN, domain, epoch)`` — still a function of
        integers only, so domain fates survive re-planning and processes
        exactly like task fates do."""
        s = self.spec
        if s.domain_mtbf_s is None or not s.domains:
            return []
        out: list[tuple[float, float]] = []
        t = 0.0
        for epoch in range(s.max_domain_shocks):
            rng = self._rng(_STREAM_DOMAIN, domain, epoch)
            t += rng.expovariate(1.0 / s.domain_mtbf_s)
            if t >= horizon:
                break
            rec = t + s.domain_repair_s
            out.append((t, rec))
            t = rec
        return out


# ---------------------------------------------------------------------------
# Closed-loop harness
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FaultRunReport:
    """What one closed-loop run produced: actual completion times (task
    id -> virtual time), permanently-failed ids, and per-withdrawal
    recovery latencies (seconds between a device loss pulling a placement
    back and the re-plan committing it again)."""

    completions: dict[int, float]
    failed: list[int]
    recovery_latency: list[float]
    events: int = 0

    def miss_rate(self, deadlines: dict[int, float]) -> float:
        if not deadlines:
            return 0.0
        missed = sum(
            1 for tid, dl in deadlines.items()
            if self.completions.get(tid, math.inf) > dl + EPS
        )
        return missed / len(deadlines)


def run_with_faults(
    svc,
    stream: Sequence[tuple[float, Task, float | None]],
    injector: FaultInjector | None = None,
    horizon: float | None = None,
) -> FaultRunReport:
    """Drive a :class:`~repro.core.service.SchedulingService` closed-loop.

    ``stream`` is ``(arrival, task, deadline-or-None)`` in non-decreasing
    arrival order.  The harness submits arrivals, watches the service's
    committed placements, and — using the injector's deterministic draws
    — reports each placement's actual fate (``completed`` at its drawn
    end, ``failed`` at its drawn failure point) back through
    ``svc.report``; device outage windows fire ``svc.quarantine`` /
    ``svc.recover``.  Straggler *detection* is the service's own job
    (``config.straggler_factor``): the harness merely polls at the
    detection boundary of every straggling attempt so the service gets a
    chance to notice before the (late) completion report arrives.

    Returns a :class:`FaultRunReport`; the service is left drained.
    """
    injector = injector or FaultInjector()
    heap: list[tuple[float, int, int, tuple]] = []  # (t, prio, seq, payload)
    seq = 0

    # event kinds, ordered by priority at equal times: recoveries before
    # submissions (capacity returns first), runtime truth before losses
    K_RECOVER, K_SUBMIT, K_POLL, K_DONE, K_FAIL, K_LOSS = range(6)

    def push(t: float, kind: int, payload: tuple) -> None:
        nonlocal seq
        seq += 1
        heapq.heappush(heap, (t, kind, seq, payload))

    deadlines: dict[int, float] = {}
    for arrival, task, dl in stream:
        push(float(arrival), K_SUBMIT, (task, dl))
        if dl is not None:
            deadlines[task.id] = float(dl)

    ispec = injector.spec
    if svc.cluster is not None and (
        ispec.device_mtbf_s is not None
        or (ispec.domain_mtbf_s is not None and ispec.domains)
    ):
        if horizon is None:
            last = max((float(a) for a, _, _ in stream), default=0.0)
            horizon = last + 10.0 * svc.config.max_wait_s + 100.0
        if ispec.device_mtbf_s is not None:
            for i in range(len(svc.cluster.devices)):
                for lost, rec in injector.device_outages(i, horizon):
                    push(lost, K_LOSS, (i,))
                    push(rec, K_RECOVER, (i,))
        if ispec.domain_mtbf_s is not None:
            # correlated shocks: every member of the domain goes down and
            # comes back together (payload carries the whole group)
            for di, dom in enumerate(ispec.domains):
                for lost, rec in injector.domain_outages(di, horizon):
                    push(lost, K_LOSS, (tuple(dom),))
                    push(rec, K_RECOVER, (tuple(dom),))

    factor = svc.config.straggler_factor
    attempts: dict[int, int] = {}       # task id -> current attempt number
    registered: dict[int, tuple[int, float]] = {}  # tid -> (attempt, begin)
    reported: set[tuple[int, int]] = set()         # (tid, attempt) resolved
    loss_pending: dict[int, float] = {}  # tid -> time its placement was lost
    recovery_latency: list[float] = []
    # device -> count of outage windows currently holding it dark: an
    # independent MTBF loss can overlap a correlated domain shock on the
    # same device, and the device only physically returns when its LAST
    # overlapping window ends
    down: dict[int, int] = {}
    n_events = 0

    def true_planned(it) -> float:
        # ground truth for the injector's draws: the *stored* profile's
        # duration at the item's (kind, size).  With calibration on, the
        # committed item carries corrected (belief) times — drawing from
        # them would let the service's own beliefs bend physical reality.
        f = getattr(svc, "true_duration", None)
        return it.planned_duration if f is None else f(it)

    def sync(now: float) -> None:
        """Register runtime events for every committed placement whose
        (attempt, begin) the harness has not seen yet."""
        done = svc.completions
        for it in svc.committed_items():
            tid = it.task.id
            if it.failed or tid in done:
                continue
            att = attempts.setdefault(tid, 1)
            if (tid, att) in reported:
                continue
            key = (att, it.begin)
            if registered.get(tid) == key:
                continue
            registered[tid] = key
            if tid in loss_pending:
                # parked through the outage: recovered when re-committed
                recovery_latency.append(it.begin - loss_pending.pop(tid))
            draw = injector.draw_execution(tid, att, true_planned(it))
            if draw.fails:
                push(it.begin + draw.fail_after, K_FAIL,
                     (tid, att, it.begin))
            else:
                push(it.begin + draw.duration, K_DONE,
                     (tid, att, it.begin))
                if factor is not None \
                        and draw.duration > factor * it.planned_duration:
                    # poll just past the detection boundary so the
                    # service can flag the straggler before its (late)
                    # completion report lands
                    push(it.begin + factor * it.planned_duration + 1e-6,
                         K_POLL, ())

    def current(tid: int, att: int, begin: float):
        """The live placement a queued runtime event refers to, or None
        when a re-plan moved/withdrew it (the event is stale — sync
        pushed, or will push, a fresh one)."""
        if attempts.get(tid) != att or (tid, att) in reported:
            return None
        it = svc.committed_item(tid)
        if it is None or it.failed or abs(it.begin - begin) > 1e-9:
            return None
        return it

    now = 0.0
    while True:
        if not heap:
            wake = svc.next_wakeup()
            if wake is not None:
                now = max(now, wake)
                svc.poll(now)
            elif svc.pending:
                svc.flush()
            else:
                break
            sync(now)
            continue
        t, kind, _, payload = heapq.heappop(heap)
        now = max(now, t)
        n_events += 1
        if kind == K_SUBMIT:
            task, dl = payload
            svc.submit(task, arrival=now, deadline=dl)
        elif kind == K_POLL:
            svc.poll(now)
        elif kind == K_DONE:
            tid, att, begin = payload
            if current(tid, att, begin) is not None:
                svc.report(tid, "completed", now)
                reported.add((tid, att))
        elif kind == K_FAIL:
            tid, att, begin = payload
            if current(tid, att, begin) is not None:
                svc.report(tid, "failed", now)
                reported.add((tid, att))
                attempts[tid] = att + 1
        elif kind == K_LOSS:
            target = payload[0]
            devs = target if isinstance(target, tuple) else (target,)
            # only devices this window newly darkens: an overlapping
            # independent loss + domain shock must not double-quarantine
            fresh = tuple(d for d in devs if down.get(d, 0) == 0)
            for d in devs:
                down[d] = down.get(d, 0) + 1
            if fresh:
                tree_dev = svc.cluster.tree_device
                for it in svc.committed_items():
                    tid = it.task.id
                    if tree_dev[it.node.tree] not in fresh \
                            or it.begin > now:
                        continue
                    if tid in svc.completions:
                        # resolved under another attempt's key (a backup
                        # win relabels to the primary id): truly done
                        continue
                    att = attempts.get(tid, 1)
                    if (tid, att) in reported or it.end > now + 1e-9:
                        continue
                    draw = injector.draw_execution(tid, att,
                                                   true_planned(it))
                    actual = it.begin + (draw.fail_after if draw.fails
                                         else draw.duration)
                    if actual > now:
                        # the books project it done, but it is physically
                        # still running on the dying device: it dies now
                        # (quarantine below only sees books-running work)
                        svc.report(tid, "failed", now)
                        reported.add((tid, att))
                        attempts[tid] = att + 1
                n0 = len(svc.stats.outages)
                lost = svc.quarantine(
                    list(fresh) if isinstance(target, tuple)
                    else fresh[0], now)
                for tid in lost:
                    # running attempts died with the device: the service
                    # already routed them through the retry path
                    att = attempts.get(tid, 1)
                    reported.add((tid, att))
                    attempts[tid] = att + 1
                # recovery latency: loss pulling a placement back -> the
                # begin of its re-committed placement (re-planning itself
                # is synchronous; the latency is how far the outage
                # pushed it).  A domain shock records one OutageEvent per
                # member device.
                for ev in svc.stats.outages[n0:]:
                    for tid in ev.withdrawn:
                        it = svc.committed_item(tid)
                        if it is not None:
                            recovery_latency.append(
                                max(0.0, it.begin - now))
                        else:
                            loss_pending.setdefault(tid, now)
        elif kind == K_RECOVER:
            target = payload[0]
            devs = target if isinstance(target, tuple) else (target,)
            freed = [d for d in devs if down.get(d, 0) == 1]
            for d in devs:
                down[d] = max(0, down.get(d, 0) - 1)
            if freed:
                svc.recover(freed if isinstance(target, tuple)
                            else freed[0], now)
        sync(now)

    svc.drain()
    sync(now)
    # any placement committed by the final drain still completes: replay
    # remaining runtime events in order without advancing service time
    while heap:
        t, kind, _, payload = heapq.heappop(heap)
        if kind == K_DONE:
            tid, att, begin = payload
            if current(tid, att, begin) is not None:
                svc.report(tid, "completed", max(now, t))
                now = max(now, t)
                reported.add((tid, att))
                sync(now)
        elif kind == K_FAIL:
            tid, att, begin = payload
            if current(tid, att, begin) is not None:
                svc.report(tid, "failed", max(now, t))
                now = max(now, t)
                reported.add((tid, att))
                attempts[tid] = att + 1
                sync(now)
        if not heap:
            wake = svc.next_wakeup()
            if wake is not None:
                now = max(now, wake)
                svc.poll(now)
                sync(now)
            elif svc.pending:
                svc.flush()
                sync(now)

    return FaultRunReport(
        completions=dict(svc.completions),
        failed=sorted(svc.stats.failed),
        recovery_latency=recovery_latency,
        events=n_events,
    )


# ---------------------------------------------------------------------------
# Open-loop baseline executor
# ---------------------------------------------------------------------------


def execute_open_loop(
    schedule, injector: FaultInjector | None = None
) -> FaultRunReport:
    """Execute a frozen plan under the same faults, with no feedback.

    The dispatcher follows the plan: each placement starts at
    ``max(planned begin, all its blocked cells free)`` — work-conserving
    within the planned order, but never replanning.  Failed attempts are
    never retried (open loop has no failure signal), stragglers push
    their cells' successors back.  Draws use ``attempt=1``: the same fate
    the closed loop sees for each task's first attempt, so the two runs
    are comparable under identical faults."""
    injector = injector or FaultInjector()
    items = sorted(
        (it for it in schedule.items if not it.failed),
        key=lambda it: (it.begin, it.task.id),
    )
    free: dict[tuple, float] = {}
    completions: dict[int, float] = {}
    failed: list[int] = []
    for it in items:
        start = it.begin
        for cell in it.node.blocked_cells:
            start = max(start, free.get(cell, 0.0))
        draw = injector.draw_execution(it.task.id, 1, it.planned_duration)
        if draw.fails:
            end = start + draw.fail_after
            failed.append(it.task.id)
        else:
            end = start + draw.duration
            completions[it.task.id] = end
        for cell in it.node.blocked_cells:
            free[cell] = end
    return FaultRunReport(
        completions=completions, failed=sorted(failed),
        recovery_latency=[], events=len(items),
    )


__all__ = [
    "RetryPolicy",
    "SpeculationPolicy",
    "ProfileCalibration",
    "FaultSpec",
    "FaultInjector",
    "ExecutionDraw",
    "FaultRunReport",
    "demote_shrink",
    "run_with_faults",
    "execute_open_loop",
]
