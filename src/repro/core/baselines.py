"""Comparison schedulers (paper §6.5): MISO-OPT and FixPart.

* ``miso_opt`` — the MISO optimizer of Li et al. [31] as described by the
  paper: tasks are taken in FIFO order; at each round the scheduler picks
  the valid partition ``P = {I_0, …, I_{|P|-1}}`` maximising the *sum of
  speedups* of the next ``|P|`` FIFO tasks on those instances, runs them,
  and repartitions when the round completes.  Partition changes pay the
  sequentialised create/destroy costs.  Its weakness (paper Fig. 12): the
  partition choice ignores task durations, so long and short tasks co-run
  and instances idle waiting for the round's stragglers.

* ``fix_part`` — a fixed partition chosen before execution; FIFO tasks run
  on the first instance to free up.  No reconfiguration at all (and no
  reconfiguration cost).  ``fix_part_best`` scans every valid partition.

All three are also registered scheduling policies (``"miso"``,
``"fix-part"``, ``"fix-part-best"``) so baseline comparisons are one loop
over :func:`~repro.core.policy.get_policy` names; ``"fix-part"`` reads its
partition from ``SchedulerConfig.partition`` (default: all-ones).
"""

from __future__ import annotations

from typing import Sequence

from repro.core.device_spec import DeviceSpec, InstanceNode
from repro.core.policy import (
    BasePolicy,
    PlanResult,
    SchedulerConfig,
    assignment_from_schedule,
    register_policy,
)
from repro.core.problem import (
    ReconfigEvent,
    Schedule,
    ScheduledTask,
    Task,
)


def speedup(task: Task, size: int, base: int) -> float:
    return task.times[base] / task.times[size]


def miso_opt(tasks: Sequence[Task], spec: DeviceSpec) -> Schedule:
    """Round-based MISO-OPT (paper §6.5 description of [31])."""
    from repro.core.problem import bind_tasks

    base = min(spec.sizes)
    fifo = list(bind_tasks(tasks, spec))
    items: list[ScheduledTask] = []
    reconfigs: list[ReconfigEvent] = []
    now = 0.0
    reconfig_end = 0.0
    current: tuple[InstanceNode, ...] | None = None

    def ordered(p: tuple[InstanceNode, ...]) -> list[InstanceNode]:
        return sorted(p, key=lambda n: (n.tree, n.start))

    while fifo:
        # choose the partition maximising the sum of speedups of the next
        # |P| FIFO tasks (tasks beyond the queue contribute nothing)
        best_p = None
        best_gain = float("-inf")
        for p in spec.valid_partitions:
            inst = ordered(p)
            gain = sum(
                speedup(t, i.size, base)
                for t, i in zip(fifo, inst)
            )
            # normalise nothing: the paper states the plain sum
            if gain > best_gain + 1e-12:
                best_gain = gain
                best_p = inst
        assert best_p is not None
        # reconfigure: destroy instances that disappear, create the new ones
        if current is None:
            prev_keys = set()
        else:
            prev_keys = {n.key for n in current}
        new_keys = {n.key for n in best_p}
        if current is not None:
            for n in current:
                if n.key not in new_keys:
                    reconfig_end = max(reconfig_end, now)
                    b = reconfig_end
                    reconfig_end += spec.t_destroy[n.size]
                    reconfigs.append(ReconfigEvent("destroy", n, b, reconfig_end))
        for n in best_p:
            if n.key not in prev_keys:
                reconfig_end = max(reconfig_end, now)
                b = reconfig_end
                reconfig_end += spec.t_create[n.size]
                reconfigs.append(ReconfigEvent("create", n, b, reconfig_end))
        start = max(now, reconfig_end)
        current = tuple(best_p)
        # run one task per instance; the round ends when all of them finish
        round_end = start
        for inst in best_p:
            if not fifo:
                break
            task = fifo.pop(0)
            items.append(ScheduledTask(task, inst, start, inst.size))
            round_end = max(round_end, start + task.times[inst.size])
        now = round_end

    return Schedule(spec=spec, items=items, reconfigs=reconfigs)


def fix_part(
    tasks: Sequence[Task],
    spec: DeviceSpec,
    partition: Sequence[InstanceNode],
) -> Schedule:
    """FIFO on a fixed partition; no reconfiguration cost (paper §6.5)."""
    import heapq

    from repro.core.problem import bind_tasks

    tasks = bind_tasks(tasks, spec)
    items: list[ScheduledTask] = []
    heap: list[tuple[float, int, InstanceNode]] = []
    for i, inst in enumerate(
        sorted(partition, key=lambda n: (n.tree, n.start))
    ):
        heapq.heappush(heap, (0.0, i, inst))
    seq = len(heap)
    for task in tasks:
        end, _, inst = heapq.heappop(heap)
        items.append(ScheduledTask(task, inst, end, inst.size))
        heapq.heappush(heap, (end + task.times[inst.size], seq, inst))
        seq += 1
    return Schedule(spec=spec, items=items, reconfigs=[])


def fix_part_best(
    tasks: Sequence[Task], spec: DeviceSpec
) -> tuple[Schedule, tuple[InstanceNode, ...]]:
    """FixPartBest: the fixed partition with the smallest makespan."""
    best: tuple[Schedule, tuple[InstanceNode, ...]] | None = None
    for p in spec.valid_partitions:
        sched = fix_part(tasks, spec, p)
        if best is None or sched.makespan < best[0].makespan:
            best = (sched, p)
    assert best is not None
    return best


def partition_of_ones(spec: DeviceSpec) -> tuple[InstanceNode, ...]:
    """FixPart(1,...,1): every slice its own instance (where valid)."""
    for p in spec.valid_partitions:
        if all(n.size == 1 for n in p):
            return p
    raise ValueError(f"{spec.name} has no all-ones partition")


def partition_whole(spec: DeviceSpec) -> tuple[InstanceNode, ...]:
    """FixPart(#slices): one instance per tree root (whole device)."""
    return tuple(spec.roots)


def _bare(policy: str, schedule: Schedule, **extras) -> PlanResult:
    """Adapt a bare baseline Schedule into the unified PlanResult."""
    return PlanResult(
        policy=policy,
        schedule=schedule,
        makespan=schedule.makespan,
        assignment=assignment_from_schedule(schedule),
        extras=extras,
    )


@register_policy("miso")
class MISOPolicy(BasePolicy):
    """MISO-OPT [31] as a registry policy."""

    def _plan_fresh(
        self, tasks: Sequence[Task], spec: DeviceSpec, config: SchedulerConfig
    ) -> PlanResult:
        return _bare(self.name, miso_opt(tasks, spec))


@register_policy("fix-part")
class FixPartPolicy(BasePolicy):
    """FIFO on ``config.partition`` (default: the all-ones partition)."""

    def _plan_fresh(
        self, tasks: Sequence[Task], spec: DeviceSpec, config: SchedulerConfig
    ) -> PlanResult:
        partition = (
            tuple(config.partition) if config.partition is not None
            else partition_of_ones(spec)
        )
        return _bare(
            self.name, fix_part(tasks, spec, partition), partition=partition
        )


@register_policy("fix-part-best")
class FixPartBestPolicy(BasePolicy):
    """FixPartBest: the fixed partition with the smallest makespan."""

    def _plan_fresh(
        self, tasks: Sequence[Task], spec: DeviceSpec, config: SchedulerConfig
    ) -> PlanResult:
        schedule, partition = fix_part_best(tasks, spec)
        return _bare(self.name, schedule, partition=partition)
