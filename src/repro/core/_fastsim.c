/* Algorithm 1's heap phase as a compiled, resumable state machine.
 *
 * A bit-exact replica of `_list_schedule_arrays` in repartition.py,
 * restricted to what the incremental phase-2 evaluator needs: the visit
 * trace (node, slice start, slice end) and the end-of-run state.  Every
 * floating-point operation (`reconfig_end` maxing, `end += dur` chain
 * additions) is the same IEEE double op in the same order as the Python
 * loop, and the heap tie-break is the lexicographic (end, seq) order the
 * Python tuples give, so the emitted visit trace is identical pop for
 * pop.  Compiled with -ffp-contract=off so no FMA contraction can change
 * a rounding (see fastsim.py, which owns the build line).
 *
 * The state (cursors, created flags, heap, counters) lives in
 * caller-owned arrays so the caller can snapshot it mid-run with plain
 * memcpy and resume from a snapshot later — that is the delta-replay
 * mechanism.  `fastsim_run` takes a *trigger* derived from the next
 * family candidate's one-task delta (the LPT ranks the moved task
 * leaves and enters): while the live trajectory is still a shared
 * prefix of the next candidate's, the state is copied into the snapshot
 * buffers before every visit that could cross the divergence point, and
 * the snapshot freezes on the visit that actually crosses.  Evaluating
 * candidate i+1 then means: restore the snapshot, swap in the patched
 * duration rows, and run to completion.
 *
 * Divergence rules (sizes are size-axis indices, ranks are positions in
 * the *current* candidate's LPT rows; the delta removes the moved task
 * at `rank_a` of row `size_a` and inserts it at `rank_b` of `size_b`):
 *   - a prefix visit only placing row slots < rank_a of size_a and
 *     < rank_b of size_b is identical under both candidates (removal /
 *     insertion shifts only the slots at or past the rank);
 *   - so a placement visit of size_a entering with cursor <= rank_a (or
 *     size_b with cursor <= rank_b) *may* cross: snapshot before it,
 *     and freeze once its placed range actually covers the rank;
 *   - when rank_b equals the size_b row length (tail append), no
 *     size_b placement covers it — the first *non-placement* visit of a
 *     size_b node is where the trajectories part (the next candidate
 *     places there); `trig_visit_b` arms that case.
 */

#include <math.h>
#include <string.h>

typedef struct {
    double end;
    long long seq;
    int nidx;
    int pad;
} Ent;

/* strict lexicographic (end, seq) — seqs are unique, so this is total */
static int ent_lt(const Ent *a, const Ent *b)
{
    if (a->end != b->end)
        return a->end < b->end;
    return a->seq < b->seq;
}

static void heap_swap(Ent *h, int i, int j)
{
    Ent t = h[i];
    h[i] = h[j];
    h[j] = t;
}

static void sift_down(Ent *h, int n, int i)
{
    for (;;) {
        int l = 2 * i + 1, r = l + 1, m = i;
        if (l < n && ent_lt(&h[l], &h[m])) m = l;
        if (r < n && ent_lt(&h[r], &h[m])) m = r;
        if (m == i) return;
        heap_swap(h, i, m);
        i = m;
    }
}

static void sift_up(Ent *h, int i)
{
    while (i > 0) {
        int p = (i - 1) / 2;
        if (!ent_lt(&h[i], &h[p])) return;
        heap_swap(h, i, p);
        i = p;
    }
}

/* Resumable simulation state, caller-owned flat arrays:
 *   cursor   int32[S]       per-size-index group cursor
 *   created  int8[N]        node has a chain already (charged creation)
 *   exh      int8[S]        a node of this size ever popped with its row
 *                           exhausted (the caller's start-validity check
 *                           needs this to rule out prefix divergence on
 *                           tail-append deltas)
 *   heap     Ent[N]         live heap entries (count in *heap_len)
 *   scalars  double[1]      reconfig_end
 *   counters int64[3]       {seq, remaining, visit_count}
 *
 * Spec context (constant across a family):
 *   ns       int32[N]       size index of node n
 *   tc, td   double[S]      creation / destruction charges per size index
 *   ch_off   int32[N+1]     CSR offsets into ch_idx
 *   ch_idx   int32[...]     children node indices, in spec order
 *
 * Candidate data:
 *   gdurs    double[S*lmax] per-size LPT duration rows (row stride lmax)
 *   glens    int32[S]       row lengths
 *
 * Trigger (-1 sizes disarm):  see the divergence rules above.
 *
 * Snapshot out: mirrors of the state arrays plus
 *   snap_flags int32[2]     {snapshot recorded, snapshot frozen}
 *
 * Visits out (appended from counters[2], which is updated):
 *   v_node, v_start, v_end  int32[max_visits]
 *
 * Returns 0 on completion, -1 if max_visits would overflow.
 */
int fastsim_run(
    /* state (in/out) */
    int *cursor, signed char *created, signed char *exh,
    Ent *heap, int *heap_len,
    double *scalars, long long *counters,
    /* spec context */
    int n_nodes, int n_sizes,
    const int *ns, const double *tc, const double *td,
    const int *ch_off, const int *ch_idx,
    /* candidate data */
    const double *gdurs, const int *glens, int lmax,
    /* trigger */
    int trig_size_a, int trig_rank_a,
    int trig_size_b, int trig_rank_b, int trig_visit_b,
    /* snapshot out */
    int *s_cursor, signed char *s_created, signed char *s_exh,
    Ent *s_heap, int *s_heap_len,
    double *s_scalars, long long *s_counters, int *snap_flags,
    /* visits out */
    int *v_node, int *v_start, int *v_end, long long max_visits)
{
    double reconfig_end = scalars[0];
    long long seq = counters[0];
    long long remaining = counters[1];
    long long nv = counters[2];
    int hlen = *heap_len;
    int frozen = snap_flags[1];

#define TAKE_SNAPSHOT() do { \
        memcpy(s_cursor, cursor, sizeof(int) * n_sizes); \
        memcpy(s_created, created, sizeof(signed char) * n_nodes); \
        memcpy(s_exh, exh, sizeof(signed char) * n_sizes); \
        memcpy(s_heap, heap, sizeof(Ent) * hlen); \
        *s_heap_len = hlen; \
        s_scalars[0] = reconfig_end; \
        s_counters[0] = seq; \
        s_counters[1] = remaining; \
        s_counters[2] = nv; \
        snap_flags[0] = 1; \
    } while (0)

    while (hlen > 0) {
        Ent top = heap[0];
        double end = top.end;
        int nidx = top.nidx;
        int si = ns[nidx];
        int cur = cursor[si];
        int n_grp = glens[si];
        if (cur < n_grp) {
            /* placement visit — snapshot before mutating anything when
             * this visit could cross the divergence point (overwritten
             * by later candidates until the crossing freezes it) */
            int qual_a = si == trig_size_a && cur <= trig_rank_a;
            int qual_b = si == trig_size_b && cur <= trig_rank_b;
            if (!frozen && (qual_a || qual_b))
                TAKE_SNAPSHOT();
            if (!created[nidx]) {
                if (end > reconfig_end)
                    reconfig_end = end;
                reconfig_end += tc[si];
                end = reconfig_end;
                created[nidx] = 1;
            }
            /* back-to-back run while strictly earliest (repartition.py's
             * runs-with-shortcut loop): `nxt` = min end among the other
             * heap entries = min over the root's two children */
            double nxt;
            if (hlen > 2) {
                double t1 = heap[1].end, t2 = heap[2].end;
                nxt = t2 < t1 ? t2 : t1;
            } else if (hlen == 2) {
                nxt = heap[1].end;
            } else {
                nxt = INFINITY;
            }
            const double *gd = gdurs + (size_t)si * (size_t)lmax;
            int start = cur;
            for (;;) {
                end += gd[cur];
                cur += 1;
                if (cur >= n_grp || end >= nxt)
                    break;
            }
            cursor[si] = cur;
            /* freeze on the crossing visit; a tail-append delta also
             * freezes when a qualifying visit exhausts the row — under
             * the patched row the run would continue into the appended
             * slot, so divergence can sit inside this very visit */
            if ((qual_a && cur > trig_rank_a) ||
                (qual_b && (cur > trig_rank_b ||
                            (trig_visit_b && cur >= n_grp))))
                frozen = 1;
            if (nv >= max_visits)
                return -1;
            v_node[nv] = nidx;
            v_start[nv] = start;
            v_end[nv] = cur;
            nv += 1;
            remaining -= cur - start;
            if (remaining == 0)
                break;  /* drain pops place nothing: early stop */
            heap[0].end = end;
            heap[0].seq = seq;
            seq += 1;
            sift_down(heap, hlen, 0);
        } else if (remaining > 0) {
            if (trig_visit_b && !frozen && si == trig_size_b) {
                /* tail-append delta: this pop repartitions/retires under
                 * the current rows but would place under the patched
                 * ones — the shared prefix ends exactly here */
                TAKE_SNAPSHOT();
                frozen = 1;
            }
            exh[si] = 1;
            if (created[nidx]) {
                if (end > reconfig_end)
                    reconfig_end = end;
                reconfig_end += td[si];
            }
            int c0 = ch_off[nidx], c1 = ch_off[nidx + 1];
            if (c1 > c0) {
                heap[0].end = end;
                heap[0].seq = seq;
                heap[0].nidx = ch_idx[c0];
                seq += 1;
                sift_down(heap, hlen, 0);
                for (int c = c0 + 1; c < c1; c++) {
                    heap[hlen].end = end;
                    heap[hlen].seq = seq;
                    heap[hlen].nidx = ch_idx[c];
                    seq += 1;
                    hlen += 1;
                    sift_up(heap, hlen - 1);
                }
            } else {
                heap[0] = heap[hlen - 1];
                hlen -= 1;
                if (hlen > 0)
                    sift_down(heap, hlen, 0);
            }
        } else {
            break;  /* every task placed: remaining pops only retire */
        }
    }

#undef TAKE_SNAPSHOT
    scalars[0] = reconfig_end;
    counters[0] = seq;
    counters[1] = remaining;
    counters[2] = nv;
    *heap_len = hlen;
    snap_flags[1] = frozen;
    return 0;
}

/* ------------------------------------------------------------------ */
/* `chains_makespan` (timing.py) as a compiled scorer over the visit
 * trace `fastsim_run` emits.  Same event heap — (when, seq) is a total
 * order because seqs are unique, so any correct binary heap pops in
 * exactly the order Python's heapq does on the (when, seq, what, node)
 * tuples — and the chain fold `sum(node_durs[key], r)` is the same
 * left-to-right double additions over the same row values (the rows
 * back both the Python duration lists and `gdurs`).  One call per
 * candidate replaces the O(n)-visit Python chain rebuild that would
 * otherwise dominate the delta-replay path. */

typedef struct {
    double when;
    long long seq;
    int what;   /* 0 = visit, 1 = done */
    int nidx;
} Evt;

static int evt_lt(const Evt *a, const Evt *b)
{
    if (a->when != b->when)
        return a->when < b->when;
    return a->seq < b->seq;
}

static void evt_sift_down(Evt *h, int n, int i)
{
    for (;;) {
        int l = 2 * i + 1, r = l + 1, m = i;
        if (l < n && evt_lt(&h[l], &h[m])) m = l;
        if (r < n && evt_lt(&h[r], &h[m])) m = r;
        if (m == i) return;
        Evt t = h[i]; h[i] = h[m]; h[m] = t;
        i = m;
    }
}

static void evt_push(Evt *h, int *n, Evt e)
{
    int i = (*n)++;
    h[i] = e;
    while (i > 0) {
        int p = (i - 1) / 2;
        if (!evt_lt(&h[i], &h[p])) return;
        Evt t = h[i]; h[i] = h[p]; h[p] = t;
        i = p;
    }
}

/* Scratch (caller-owned): act/sub_act int8[N]; head/tail int32[N];
 * nxt int32[>=nv] (per-node visit chains); heap Evt[N] (each node is in
 * the event heap at most once); rc_end double[n_trees or 1].  Returns
 * the makespan. */
double fastsim_score(
    int n_nodes, int n_sizes,
    const int *ns, const int *tree, int per_tree, int n_trees,
    const double *tc, const double *td,
    const int *ch_off, const int *ch_idx,
    const int *roots, int n_roots,
    const double *gdurs, int lmax,
    const int *v_node, const int *v_start, const int *v_end, long long nv,
    signed char *act, signed char *sub_act,
    int *head, int *tail, int *nxt,
    Evt *heap, double *rc_end)
{
    (void)n_sizes;
    if (nv == 0)
        return 0.0;
    memset(act, 0, (size_t)n_nodes);
    for (int i = 0; i < n_nodes; i++)
        head[i] = -1;
    for (long long v = 0; v < nv; v++) {
        int nidx = v_node[v];
        act[nidx] = 1;  /* every visit places >= 1 slot */
        if (head[nidx] < 0)
            head[nidx] = (int)v;
        else
            nxt[tail[nidx]] = (int)v;
        tail[nidx] = (int)v;
        nxt[v] = -1;
    }
    /* children follow parents in spec.nodes order, so a reverse sweep
     * sees every child's sub_act before its parent's */
    for (int i = n_nodes - 1; i >= 0; i--) {
        int sub = act[i];
        for (int c = ch_off[i]; !sub && c < ch_off[i + 1]; c++)
            sub = sub_act[ch_idx[c]];
        sub_act[i] = (signed char)sub;
    }
    for (int t = 0; t < (per_tree ? n_trees : 1); t++)
        rc_end[t] = 0.0;
    int hlen = 0;
    long long seq = 0;
    double makespan = 0.0;
    for (int r = 0; r < n_roots; r++)
        if (sub_act[roots[r]]) {
            Evt e = {0.0, seq++, 0, roots[r]};
            evt_push(heap, &hlen, e);
        }
    while (hlen > 0) {
        Evt top = heap[0];
        heap[0] = heap[--hlen];
        if (hlen > 0)
            evt_sift_down(heap, hlen, 0);
        int nidx = top.nidx;
        int g = per_tree ? tree[nidx] : 0;
        if (top.what == 0) {
            Evt e;
            if (act[nidx]) {
                double r = rc_end[g];
                if (top.when > r)
                    r = top.when;
                r += tc[ns[nidx]];
                rc_end[g] = r;
                double t = r;
                const double *gd = gdurs + (size_t)ns[nidx] * (size_t)lmax;
                for (int v = head[nidx]; v >= 0; v = nxt[v])
                    for (int k = v_start[v]; k < v_end[v]; k++)
                        t += gd[k];
                if (t > makespan)
                    makespan = t;
                e.when = t;
            } else {
                e.when = top.when;
            }
            e.seq = seq++;
            e.what = 1;
            e.nidx = nidx;
            evt_push(heap, &hlen, e);
        } else {
            int go = 0;
            for (int c = ch_off[nidx]; c < ch_off[nidx + 1]; c++)
                if (sub_act[ch_idx[c]]) {
                    go = 1;
                    break;
                }
            if (!go)
                continue;
            if (act[nidx]) {
                double r = rc_end[g];
                if (top.when > r)
                    r = top.when;
                rc_end[g] = r + td[ns[nidx]];
            }
            for (int c = ch_off[nidx]; c < ch_off[nidx + 1]; c++)
                if (sub_act[ch_idx[c]]) {
                    Evt e = {top.when, seq++, 0, ch_idx[c]};
                    evt_push(heap, &hlen, e);
                }
        }
    }
    return makespan;
}
