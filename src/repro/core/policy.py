"""Unified scheduling service API: policies, config and plan results.

The paper frames MIG scheduling as one problem with many strategies — FAR
(§3), MISO-OPT and fixed partitions (§6.5), online greedy placement (§7).
This module is the surface that makes them interchangeable:

* :class:`SchedulerConfig` — one frozen knob object replacing the boolean
  kwarg sprawl that had accumulated on ``schedule_batch`` (refinement
  depth, pruning, engine selection, EPS, seam mode, latency budget, seed);
* :class:`PlanResult` — the unified return type every strategy adapts
  into (schedule, makespan, assignment, per-phase wall time, reconfig
  events, policy-specific extras);
* :class:`SchedulerPolicy` / :func:`register_policy` / :func:`get_policy`
  — a string-keyed registry so consumers (benchmarks, the multi-batch
  driver, the serving facade) run *any* strategy as one loop over names.

Policies self-register where they are implemented (``far.py``,
``baselines.py``, ``online.py``, ``multibatch.py``); :func:`get_policy`
imports those modules lazily so ``import repro.core.policy`` alone never
drags in the whole scheduler stack.
"""

from __future__ import annotations

import dataclasses
import importlib
import time
from typing import Callable, Protocol, Sequence, runtime_checkable

from repro.core.device_spec import DeviceSpec
from repro.core.problem import EPS, Schedule, Task, bind_tasks, validate_schedule
from repro.core.repartition import Assignment


#: valid SchedulerConfig.evaluator values (the family-evaluator registry
#: in repro.core.family_eval may grow beyond these for custom plugins;
#: config validation names only the built-ins plus "auto")
_EVALUATOR_CHOICES = frozenset(
    {"sequential", "incremental", "parallel", "vectorized", "auto"}
)


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """All scheduling knobs in one immutable value.

    The first block mirrors the legacy ``schedule_batch`` booleans; the
    second configures seam concatenation (multi-batch / tail-aware plans);
    the third is the online-serving latency budget consumed by
    :class:`~repro.core.service.SchedulingService`.
    """

    # -- FAR phases (legacy schedule_batch kwargs) --------------------------
    refine: bool = True               # phase-3 move/swap refinement
    max_refine_iterations: int = 64
    prune: bool = True                # admissible phase-2 family pruning
    deep_refine: bool = False         # beyond-paper exact greedy pass
    use_engine: bool = True           # incremental TimingEngine vs replays
    eps: float = EPS                  # float tolerance for comparisons
    # phase-2 family evaluator: "sequential" (one Algorithm-1 simulation
    # per candidate), "incremental" (compiled delta-replay of the shared
    # trajectory prefix), "parallel" (process-pool family sharding),
    # "vectorized" (chunked array-program scoring), or "auto" (the best
    # available tier for the batch size).  All evaluators return
    # bit-identical winners — see repro.core.family_eval.
    evaluator: str = "auto"
    # "auto" task-count floor override: when set, replaces the module
    # constants (AUTO_MIN_TASKS*) gating the accelerated evaluators, so
    # deployments on bigger boxes can tune dispatch without
    # monkeypatching.  None keeps the calibrated defaults.
    evaluator_floor: int | None = None
    # pool width for evaluator="parallel": 0 = one worker per CPU core;
    # 1 short-circuits to sequential scoring in-process.
    parallel_workers: int = 0

    # -- seam concatenation (tail-aware planning) ---------------------------
    concat_mode: str = "move_swap"    # "trivial" | "reverse" | "move_swap" | "auto"
    reverse: bool = False             # play this segment leaves-first (§4.2)

    # -- strategy-specific --------------------------------------------------
    partition: tuple | None = None    # fix-part: instances to pin (None -> 1s)
    seed: int | None = None           # reserved for randomized strategies
    # "auto-serve" meta-policy: batches at least this dense flush through
    # FAR, sparser ones through fix-part.  The threshold comes from the
    # BENCH_online policy sweep: FAR's molding wins on dense batches
    # (gap 0.5s, ~16-task flushes) while its reconfiguration overhead
    # loses to a pinned all-1s partition at sparse rates (gaps 2–8s,
    # <=5-task flushes, fix-part ratios 0.75–0.84 vs FAR).
    auto_dense_batch: int = 12

    # -- online serving (SchedulingService latency budget) ------------------
    max_wait_s: float = 0.25          # accumulate arrivals this long
    max_batch: int = 32               # flush earlier once this many queue up
    min_batch: int = 2                # smaller deadline flushes go online

    # -- deadline-aware serving (SchedulingService SLOs) --------------------
    # admission control for tasks submitted with a deadline whose
    # completion is provably unmeetable against the service's lower bound:
    # "none" accepts everything (deadlines only tracked for miss-rate),
    # "reject" refuses the task, "demote" accepts it best-effort (the
    # deadline is dropped, so it never counts as a miss).
    admission: str = "none"
    # tail re-planning: when a flush lands, placements that have not yet
    # started are pulled back and re-scheduled together with the arrivals
    # (running tasks are never moved; the no-replan plan is kept whenever
    # re-planning does not strictly improve the combined makespan).  With
    # replan on, online-fallback (trickle) flushes also try a withdrawn-
    # tail re-plan under the same strict-win rule.
    replan: bool = False
    # EDF within-batch ordering: before a flush commits, each planned
    # node chain is stably reordered earliest-deadline-first (deadline
    # carriers ahead of best-effort work; see multibatch.edf_order).
    # Chain ends — and therefore makespan, the seam tail and every
    # never-worse guarantee — are order-invariant, only per-task
    # completion times inside a chain move.  False = bit-identical to
    # the makespan-only commit order.
    edf: bool = False

    # -- fault tolerance (closed-loop runtime feedback) ---------------------
    # implicit straggler detection: a committed placement whose observed
    # runtime (via SchedulingService.report / poll observations) exceeds
    # straggler_factor * its profiled duration without a completion
    # report has its projected end stretched and the tail force-re-planned.
    # None disables detection — the pre-feedback open-loop behaviour.
    straggler_factor: float | None = None
    # retry policy (repro.core.faults.RetryPolicy) for tasks reported
    # failed: capped exponential backoff on the re-release time, optional
    # demotion.  None = no retries; a failed task is permanently failed.
    retry: object | None = None
    # straggler speculation (repro.core.faults.SpeculationPolicy): when a
    # straggler is flagged, race a backup attempt on the best alternative
    # placement; first finisher wins, the loser is cancelled.  None =
    # stretch-only straggler handling (the PR 6 behaviour, bit-identical).
    speculation: object | None = None
    # online profile calibration (repro.core.faults.ProfileCalibration):
    # EWMA duration-correction state fed by report(end=) and applied at
    # the policy boundary only — the stored tasks keep their raw profiles.
    # None = plan straight from the submitted profiles, bit-identically.
    calibration: object | None = None
    # profile transfer fallback: derive missing (device_kind, size)
    # profile entries from the nearest measured kind at submit time
    # (repro.core.problem.transfer_profile).  False = off (a task must
    # cover its devices, PR 5 behaviour); True enables derivation with
    # unit speed factors; a {device_kind: relative_speed} mapping scales
    # cross-kind transfers by speed[donor] / speed[target].
    profile_transfer: object = False

    def __post_init__(self):
        if self.straggler_factor is not None and self.straggler_factor <= 1.0:
            raise ValueError(
                f"SchedulerConfig.straggler_factor must exceed 1.0 (a "
                f"deviation factor), got {self.straggler_factor!r}"
            )
        if self.admission not in ("none", "reject", "demote"):
            raise ValueError(
                f"SchedulerConfig.admission must be 'none', 'reject' or "
                f"'demote', got {self.admission!r}"
            )
        if self.evaluator in _EVALUATOR_CHOICES:
            return
        # custom evaluators registered via family_eval.register_evaluator
        # are also accepted (imported lazily to keep `import policy` light)
        from repro.core.family_eval import EVALUATORS

        if self.evaluator not in EVALUATORS:
            raise ValueError(
                f"SchedulerConfig.evaluator must be one of "
                f"{sorted(_EVALUATOR_CHOICES | set(EVALUATORS))}, "
                f"got {self.evaluator!r}"
            )

    def replace(self, **changes) -> "SchedulerConfig":
        return dataclasses.replace(self, **changes)


#: the legacy ``schedule_batch`` boolean kwargs and the config field each
#: maps to — the deprecation shim names these in its warning.
LEGACY_KWARGS: dict[str, str] = {
    "refine": "refine",
    "max_refine_iterations": "max_refine_iterations",
    "prune": "prune",
    "deep_refine": "deep_refine",
    "use_engine": "use_engine",
}


@dataclasses.dataclass
class PlanResult:
    """What every registered policy returns from ``plan``.

    ``makespan`` is stored (not derived) so bound-only policies such as
    ``"lower-bound"`` can report one without a schedule; for every
    schedule-producing policy it equals ``schedule.makespan``.
    ``extras`` carries the policy-specific result the legacy entry point
    used to return (``FARResult`` under ``"far"``, the chosen partition
    under ``"partition"``, online placements under ``"placements"``, the
    seam ``ConcatResult`` under ``"concat"``).  The serving facade adds
    deadline extras onto each flush's plan: ``"deadlines"`` (task id ->
    deadline for the deadline-carrying tasks of the batch) and
    ``"deadline_slack"`` (task id -> deadline minus planned completion at
    flush time; negative = the plan already misses it).
    """

    policy: str
    schedule: Schedule
    makespan: float
    assignment: Assignment | None = None
    tail: object | None = None        # multibatch.Tail after a tail-aware plan
    elapsed_s: float = 0.0
    phase_s: dict[str, float] | None = None
    extras: dict = dataclasses.field(default_factory=dict)

    @property
    def reconfig_events(self) -> int:
        return len(self.schedule.reconfigs)

    def validate(
        self, tasks: Sequence[Task] | None = None, check_reconfig: bool = True
    ) -> None:
        validate_schedule(self.schedule, tasks, check_reconfig=check_reconfig)


@runtime_checkable
class SchedulerPolicy(Protocol):
    """The policy protocol: ``plan(tasks, spec, config, tail) -> PlanResult``."""

    name: str

    def plan(
        self,
        tasks: Sequence[Task],
        spec: DeviceSpec,
        config: SchedulerConfig | None = None,
        tail: object | None = None,
    ) -> PlanResult: ...


class BasePolicy:
    """Shared plumbing: timing, config defaulting and tail-aware splicing.

    Subclasses implement ``_plan_fresh(tasks, spec, config) -> PlanResult``
    for a cold device.  When ``tail`` (a :class:`~repro.core.multibatch.Tail`)
    is given, the fresh plan's assignment is spliced after it with
    :func:`~repro.core.multibatch.concatenate` under ``config.concat_mode``
    (direction from ``config.reverse``) and the result carries the new tail.
    """

    name = "?"

    def plan(
        self,
        tasks: Sequence[Task],
        spec: DeviceSpec,
        config: SchedulerConfig | None = None,
        tail: object | None = None,
    ) -> PlanResult:
        cfg = config or SchedulerConfig()
        t0 = time.perf_counter()
        # instance-type-keyed profiles are lowered onto this device's kind
        # at the policy boundary (identity for size-keyed tasks)
        tasks = bind_tasks(tasks, spec)
        res = self._plan_fresh(tasks, spec, cfg)
        res.policy = self.name
        if tail is not None:
            if res.assignment is None:
                raise ValueError(
                    f"policy {self.name!r} produced no assignment; "
                    "tail-aware planning is unsupported"
                )
            from repro.core.multibatch import concatenate

            out = concatenate(
                res.assignment, tail, mode=cfg.concat_mode,
                reverse=cfg.reverse, use_engine=cfg.use_engine,
            )
            res.schedule = out.schedule
            res.makespan = out.schedule.makespan
            res.tail = out.tail
            res.extras["concat"] = out
        res.elapsed_s = time.perf_counter() - t0
        return res

    def _plan_fresh(
        self, tasks: Sequence[Task], spec: DeviceSpec, config: SchedulerConfig
    ) -> PlanResult:
        raise NotImplementedError


def assignment_from_schedule(schedule: Schedule) -> Assignment:
    """Adapt a bare :class:`Schedule` (MISO / FixPart output) into the
    tree-chain :class:`Assignment` the seam machinery consumes: per-node
    task lists in begin-time order."""
    tasks = {it.task.id: it.task for it in schedule.items}
    node_tasks = {
        key: [it.task.id for it in lst]
        for key, lst in schedule.by_node().items()
    }
    return Assignment(schedule.spec, tasks, node_tasks)


# -- registry ---------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], SchedulerPolicy]] = {}
_INSTANCES: dict[str, SchedulerPolicy] = {}

#: modules whose import self-registers the built-in policies
_BUILTIN_MODULES = (
    "repro.core.far",
    "repro.core.baselines",
    "repro.core.online",
    "repro.core.multibatch",
    "repro.core.cluster",
)


def register_policy(name: str):
    """Class decorator: ``@register_policy("far")`` adds the policy class
    to the registry under ``name`` (instantiated lazily, one singleton)."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        _INSTANCES.pop(name, None)
        return cls

    return deco


def _ensure_builtins() -> None:
    for mod in _BUILTIN_MODULES:
        importlib.import_module(mod)


def get_policy(name: str) -> SchedulerPolicy:
    """Look up a registered policy instance by name."""
    if name not in _REGISTRY:
        _ensure_builtins()
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown scheduling policy {name!r}; "
            f"available: {', '.join(sorted(_REGISTRY))}"
        )
    if name not in _INSTANCES:
        _INSTANCES[name] = _REGISTRY[name]()
    return _INSTANCES[name]


def available_policies() -> list[str]:
    """Sorted names of every registered policy."""
    _ensure_builtins()
    return sorted(_REGISTRY)


@register_policy("auto-serve")
class AutoServePolicy:
    """Per-flush policy selector driven by batch density.

    The BENCH_online policy sweep shows a regime split: FAR's moldable
    packing wins when flushes are dense (many tasks per batch amortise
    its reconfiguration overhead), while a pinned all-1s fix-part
    partition wins at sparse arrival rates where FAR's reconfigurations
    dominate the short chains.  This meta-policy picks per batch —
    ``len(tasks) >= config.auto_dense_batch`` flushes through ``"far"``,
    anything sparser through ``"fix-part"`` — so a serving stream whose
    rate drifts across regimes gets the right planner at every flush
    without a config change.  The chosen name is recorded in
    ``extras["auto_choice"]``.
    """

    name = "auto-serve"

    def plan(
        self,
        tasks: Sequence[Task],
        spec: DeviceSpec,
        config: SchedulerConfig | None = None,
        tail: object | None = None,
    ) -> PlanResult:
        cfg = config or SchedulerConfig()
        choice = "far" if len(tasks) >= cfg.auto_dense_batch else "fix-part"
        res = get_policy(choice).plan(tasks, spec, cfg, tail)
        res.policy = self.name
        res.extras["auto_choice"] = choice
        return res


__all__ = [
    "SchedulerConfig",
    "PlanResult",
    "SchedulerPolicy",
    "BasePolicy",
    "LEGACY_KWARGS",
    "assignment_from_schedule",
    "register_policy",
    "get_policy",
    "available_policies",
]
