"""FAR Phase 3: schedule refinement by task moves and swaps
(paper §3.3, Algorithm 2).

Iteratively finds *critical* instances (their slices reach the makespan),
and either **moves** one of their tasks to the same-size alternative
instance with the earliest completion, or **swaps** a pair of tasks with it.
The candidate task (or pair) is chosen so the transferred duration is as
close as possible to half the available margin ``(ω − end(Iᵃ)) / 2`` — the
margin is split between the two instances, so a balanced split is best.
The search walks the critical subtree in reverse BFS (leaves → root) and an
iteration ends when every opened node is closed; refinement ends when the
root opens (or an iteration cap is hit).

Bookkeeping between iterations uses reconfiguration-free times, exactly like
the paper (Algorithm 2 line 26 defers the full recomputation); the final
schedule is re-derived with :func:`~repro.core.repartition.replay`, and the
whole refinement is guarded to never return something worse than its input.
"""

from __future__ import annotations

import bisect
import dataclasses

from repro.core.device_spec import DeviceSpec, InstanceNode
from repro.core.problem import EPS, Schedule
from repro.core.repartition import Assignment, NodeKey, replay


@dataclasses.dataclass
class RefineStats:
    moves: int = 0
    swaps: int = 0
    iterations: int = 0
    improvement: float = 0.0  # makespan(before) / makespan(after) - 1


def _parent_map(spec: DeviceSpec) -> dict[NodeKey, InstanceNode | None]:
    parents: dict[NodeKey, InstanceNode | None] = {}
    for root in spec.roots:
        parents[root.key] = None
        stack = [root]
        while stack:
            node = stack.pop()
            for child in node.children:
                parents[child.key] = node
                stack.append(child)
    return parents


def _slice_ends_no_reconfig(
    assignment: Assignment, replay_kwargs: dict
) -> dict[tuple[int, int], float]:
    kw = dict(replay_kwargs)
    kw["include_reconfig"] = False
    return replay(assignment, **kw).slice_end_times()


def _node_end(node: InstanceNode, ends: dict[tuple[int, int], float]) -> float:
    return max((ends[(node.tree, s)] for s in node.slices), default=0.0)


def _sorted_insert(lst: list[int], tid: int, assignment: Assignment, size: int) -> None:
    """Insert task id keeping the node list LPT-ordered (desc by duration)."""
    times = [-assignment.tasks[t].times[size] for t in lst]
    pos = bisect.bisect_left(times, -assignment.tasks[tid].times[size])
    lst.insert(pos, tid)


def _best_move(
    assignment: Assignment, key: NodeKey, margin: float
) -> int | None:
    """Task of node ``key`` with duration < margin, closest to margin/2."""
    size = key[2]
    lst = assignment.node_tasks.get(key, [])
    if not lst or margin <= EPS:
        return None
    # list is LPT (desc); build ascending durations for binary search
    asc = sorted(lst, key=lambda t: assignment.tasks[t].times[size])
    durs = [assignment.tasks[t].times[size] for t in asc]
    hi = bisect.bisect_left(durs, margin - EPS)  # durations strictly < margin
    if hi == 0:
        return None
    target = margin / 2.0
    pos = bisect.bisect_left(durs, target, 0, hi)
    cands = [i for i in (pos - 1, pos) if 0 <= i < hi]
    best = min(cands, key=lambda i: abs(durs[i] - target))
    return asc[best]


def _best_swap(
    assignment: Assignment, key_i: NodeKey, key_a: NodeKey, margin: float
) -> tuple[int, int] | None:
    """Pair (T_k of I, T_j of Iᵃ) with 0 < dur_k - dur_j < margin, the
    difference closest to margin/2 (two-pointer over the sorted lists)."""
    size = key_i[2]
    li = assignment.node_tasks.get(key_i, [])
    la = assignment.node_tasks.get(key_a, [])
    if not li or not la or margin <= EPS:
        return None
    di = sorted(
        ((assignment.tasks[t].times[size], t) for t in li)
    )
    da = sorted(
        ((assignment.tasks[t].times[size], t) for t in la)
    )
    target = margin / 2.0
    best: tuple[float, int, int] | None = None  # (|diff-target|, tk, tj)
    j = 0
    for dk, tk in di:
        # advance j while the diff is still >= margin (too big)
        while j < len(da) and dk - da[j][0] >= margin - EPS:
            j += 1
        for dj, tj in da[j:]:
            diff = dk - dj
            if diff <= EPS:
                break  # da ascending -> diffs only shrink further
            score = abs(diff - target)
            if best is None or score < best[0]:
                best = (score, tk, tj)
    if best is None:
        return None
    return best[1], best[2]


def refine_assignment(
    assignment: Assignment,
    max_iterations: int = 64,
    min_rel_improvement: float = 0.0,
    replay_kwargs: dict | None = None,
) -> tuple[Assignment, Schedule, RefineStats]:
    """Algorithm 2.  Returns (assignment, schedule, stats); never worse than
    the input (guarded by a final replay comparison).

    ``replay_kwargs`` (release / alive / direction) retarget the engine at
    the multi-batch seam (paper §4.3): the slice-release times of the
    previous batch then shape the critical slices and margins."""
    spec = assignment.spec
    rkw = dict(replay_kwargs or {})
    parents = _parent_map(spec)
    leaves = [n for n in spec.nodes if not n.children]
    nodes_by_size: dict[int, list[InstanceNode]] = {}
    for n in spec.nodes:
        nodes_by_size.setdefault(n.size, []).append(n)

    base_sched = replay(assignment, **rkw)
    best_assign = assignment.copy()
    best_makespan = base_sched.makespan
    stats = RefineStats()

    work = assignment.copy()
    stop = False
    while not stop and stats.iterations < max_iterations:
        stats.iterations += 1
        ends = _slice_ends_no_reconfig(work, rkw)
        omega = max(ends.values(), default=0.0)
        if omega <= EPS:
            break
        # line 5: open the leaves whose slices reach the makespan
        queue: list[InstanceNode] = [
            leaf for leaf in leaves
            if ends[(leaf.tree, leaf.start)] >= omega - EPS
        ]
        opened = {leaf.key for leaf in queue}
        edited = False
        while queue:  # lines 6-24
            inst = queue.pop(0)
            if parents[inst.key] is None and not _can_act(
                work, inst, nodes_by_size, ends, omega
            ):
                stop = True  # lines 8-10: root opened with nothing to do
                break
            # line 11: alternative same-size instance with min end
            alts = [
                a for a in nodes_by_size.get(inst.size, [])
                if a.key != inst.key
            ]
            acted = False
            if alts and work.node_tasks.get(inst.key):
                alt = min(alts, key=lambda a: (_node_end(a, ends), a.key))
                margin = omega - _node_end(alt, ends)
                # lines 12-16: move
                tid = _best_move(work, inst.key, margin)
                if tid is not None:
                    work.node_tasks[inst.key].remove(tid)
                    lst = work.node_tasks.setdefault(alt.key, [])
                    _sorted_insert(lst, tid, work, alt.size)
                    stats.moves += 1
                    acted = edited = True
                else:
                    # lines 18-22: swap
                    pair = _best_swap(work, inst.key, alt.key, margin)
                    if pair is not None:
                        tk, tj = pair
                        work.node_tasks[inst.key].remove(tk)
                        work.node_tasks[alt.key].remove(tj)
                        _sorted_insert(
                            work.node_tasks[alt.key], tk, work, alt.size
                        )
                        _sorted_insert(
                            work.node_tasks[inst.key], tj, work, inst.size
                        )
                        stats.swaps += 1
                        acted = edited = True
                if acted:
                    ends = _slice_ends_no_reconfig(work, rkw)  # line 16/22
            if not acted:  # lines 23-24: open the parent
                parent = parents[inst.key]
                if parent is None:
                    stop = True
                    break
                if parent.key not in opened:
                    opened.add(parent.key)
                    queue.append(parent)
        # line 26 equivalent: full timing recomputation + acceptance guard
        if edited:
            sched = replay(work, **rkw)
            if sched.makespan < best_makespan - EPS:
                rel = best_makespan / sched.makespan - 1.0
                best_makespan = sched.makespan
                best_assign = work.copy()
                if rel < min_rel_improvement:
                    break
        else:
            break

    final = replay(best_assign, **rkw)
    stats.improvement = (
        base_sched.makespan / final.makespan - 1.0 if final.makespan > 0 else 0.0
    )
    return best_assign, final, stats


def _can_act(assignment, inst, nodes_by_size, ends, omega) -> bool:
    """Cheap check whether the root node could still move/swap anything."""
    alts = [a for a in nodes_by_size.get(inst.size, []) if a.key != inst.key]
    return bool(alts and assignment.node_tasks.get(inst.key))
