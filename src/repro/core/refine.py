"""FAR Phase 3: schedule refinement by task moves and swaps
(paper §3.3, Algorithm 2).

Iteratively finds *critical* instances (their slices reach the makespan),
and either **moves** one of their tasks to the same-size alternative
instance with the earliest completion, or **swaps** a pair of tasks with it.
The candidate task (or pair) is chosen so the transferred duration is as
close as possible to half the available margin ``(ω − end(Iᵃ)) / 2`` — the
margin is split between the two instances, so a balanced split is best.
The search walks the critical subtree in reverse BFS (leaves → root) and an
iteration ends when every opened node is closed; refinement ends when the
root opens (or an iteration cap is hit).

Bookkeeping between iterations uses reconfiguration-free times, exactly like
the paper (Algorithm 2 line 26 defers the full recomputation); the final
schedule is re-derived with :func:`~repro.core.repartition.replay`, and the
whole refinement is guarded to never return something worse than its input.

All intermediate timings come from the incremental
:class:`~repro.core.timing.TimingEngine` (``use_engine=False`` flips to the
replay-per-query reference evaluator with identical results — the engine's
replay-equivalence contract makes the two paths bit-identical).
"""

from __future__ import annotations

import bisect
import collections
import dataclasses
from typing import Sequence

from repro.core.device_spec import DeviceSpec, InstanceNode
from repro.core.problem import EPS, Schedule
from repro.core.repartition import Assignment, NodeKey
from repro.core.timing import make_engine


@dataclasses.dataclass
class RefineStats:
    moves: int = 0
    swaps: int = 0
    iterations: int = 0
    improvement: float = 0.0  # makespan(before) / makespan(after) - 1


def _parent_map(spec: DeviceSpec) -> dict[NodeKey, InstanceNode | None]:
    parents: dict[NodeKey, InstanceNode | None] = {}
    for root in spec.roots:
        parents[root.key] = None
        stack = [root]
        while stack:
            node = stack.pop()
            for child in node.children:
                parents[child.key] = node
                stack.append(child)
    return parents


def _node_end(node: InstanceNode, ends: dict[tuple[int, int], float]) -> float:
    return max((ends[(node.tree, s)] for s in node.slices), default=0.0)


class ChainViews:
    """Sorted candidate views per node, cached on the engine's per-chain
    edit version — phase 3 / §4.3 re-sort the same unchanged chains many
    times per iteration otherwise."""

    def __init__(self, engine):
        self.engine = engine
        self._move: dict[NodeKey, tuple] = {}
        self._swap: dict[NodeKey, tuple] = {}

    def move_view(self, key: NodeKey) -> tuple[list[int], list[float]]:
        """(task ids asc by duration — stable in chain order, durations)."""
        ver = self.engine.chain_version(key)
        hit = self._move.get(key)
        if hit is not None and hit[0] == ver:
            return hit[1], hit[2]
        tasks = self.engine.tasks
        size = key[2]
        lst = self.engine.chains.get(key) or ()
        asc = sorted(lst, key=lambda t: tasks[t].times[size])
        durs = [tasks[t].times[size] for t in asc]
        self._move[key] = (ver, asc, durs)
        return asc, durs

    def swap_view(self, key: NodeKey) -> list[tuple[float, int]]:
        """(duration, task id) pairs sorted ascending (ties by id)."""
        ver = self.engine.chain_version(key)
        hit = self._swap.get(key)
        if hit is not None and hit[0] == ver:
            return hit[1]
        tasks = self.engine.tasks
        size = key[2]
        lst = self.engine.chains.get(key) or ()
        pairs = sorted((tasks[t].times[size], t) for t in lst)
        self._swap[key] = (ver, pairs)
        return pairs


def best_move_from(
    asc: Sequence[int], durs: Sequence[float], margin: float
) -> int | None:
    """Candidate-selection core of the move heuristic: the task (of the
    ascending-by-duration view ``asc``/``durs``) with duration < margin,
    closest to margin/2.  Exposed separately so the inter-device local
    search (:mod:`repro.core.cluster`) can feed views whose durations are
    evaluated under the *destination* device's profile kind."""
    if margin <= EPS or not asc:
        return None
    hi = bisect.bisect_left(durs, margin - EPS)  # durations strictly < margin
    if hi == 0:
        return None
    target = margin / 2.0
    pos = bisect.bisect_left(durs, target, 0, hi)
    cands = [i for i in (pos - 1, pos) if 0 <= i < hi]
    best = min(cands, key=lambda i: abs(durs[i] - target))
    return asc[best]


def _best_move(
    views: ChainViews, key: NodeKey, margin: float
) -> int | None:
    """Task of node ``key`` with duration < margin, closest to margin/2."""
    if margin <= EPS:
        return None
    # chain is LPT (desc); the view is ascending for binary search
    asc, durs = views.move_view(key)
    return best_move_from(asc, durs, margin)


def best_swap_from(
    di: Sequence[tuple[float, int]],
    da: Sequence[tuple[float, int]],
    margin: float,
) -> tuple[int, int] | None:
    """Candidate-selection core of the swap heuristic over two ascending
    ``(duration, task id)`` views: the pair with 0 < dur_k - dur_j <
    margin, difference closest to margin/2 (two-pointer).  Like
    :func:`best_move_from`, this is the piece the inter-device search
    reuses with destination-kind durations."""
    if margin <= EPS or not di or not da:
        return None
    target = margin / 2.0
    best: tuple[float, int, int] | None = None  # (|diff-target|, tk, tj)
    j = 0
    for dk, tk in di:
        # advance j while the diff is still >= margin (too big)
        while j < len(da) and dk - da[j][0] >= margin - EPS:
            j += 1
        for dj, tj in da[j:]:
            diff = dk - dj
            if diff <= EPS:
                break  # da ascending -> diffs only shrink further
            score = abs(diff - target)
            if best is None or score < best[0]:
                best = (score, tk, tj)
    if best is None:
        return None
    return best[1], best[2]


def _best_swap(
    views: ChainViews, key_i: NodeKey, key_a: NodeKey, margin: float
) -> tuple[int, int] | None:
    """Pair (T_k of I, T_j of Iᵃ) with 0 < dur_k - dur_j < margin, the
    difference closest to margin/2 (two-pointer over the sorted lists).
    ``key_i`` and ``key_a`` always have the same instance size."""
    if margin <= EPS:
        return None
    return best_swap_from(
        views.swap_view(key_i), views.swap_view(key_a), margin
    )


def refine_assignment(
    assignment: Assignment,
    max_iterations: int = 64,
    min_rel_improvement: float = 0.0,
    replay_kwargs: dict | None = None,
    use_engine: bool = True,
) -> tuple[Assignment, Schedule, RefineStats]:
    """Algorithm 2.  Returns (assignment, schedule, stats); never worse than
    the input (guarded by a final replay comparison).

    ``replay_kwargs`` (release / alive / direction) retarget the engine at
    the multi-batch seam (paper §4.3): the slice-release times of the
    previous batch then shape the critical slices and margins.

    ``use_engine`` selects the incremental timing engine (default) or the
    replay-per-query reference evaluator — same results either way."""
    spec = assignment.spec
    rkw = dict(replay_kwargs or {})
    parents = _parent_map(spec)
    leaves = [n for n in spec.nodes if not n.children]
    nodes_by_size: dict[int, list[InstanceNode]] = {}
    for n in spec.nodes:
        nodes_by_size.setdefault(n.size, []).append(n)

    stats = RefineStats()

    eng = make_engine(
        assignment,
        use_engine=use_engine,
        release=rkw.get("release"),
        alive=rkw.get("alive"),
        direction=rkw.get("direction", "forward"),
        include_reconfig=rkw.get("include_reconfig", True),
    )
    base_makespan = best_makespan = eng.makespan()
    best_log_length = 0  # rollback token for the best-so-far state
    work = eng.assignment  # live view: engine edits are visible here
    views = ChainViews(eng)
    stop = False
    while not stop and stats.iterations < max_iterations:
        stats.iterations += 1
        ends = eng.slice_end_times(include_reconfig=False)
        omega = max(ends.values(), default=0.0)
        if omega <= EPS:
            break
        # line 5: open the leaves whose slices reach the makespan
        queue = collections.deque(
            leaf for leaf in leaves
            if ends[(leaf.tree, leaf.start)] >= omega - EPS
        )
        opened = {leaf.key for leaf in queue}
        edited = False
        while queue:  # lines 6-24
            inst = queue.popleft()
            if parents[inst.key] is None and not _can_act(
                work, inst, nodes_by_size, ends, omega
            ):
                stop = True  # lines 8-10: root opened with nothing to do
                break
            # line 11: alternative same-size instance with min end
            alts = [
                a for a in nodes_by_size.get(inst.size, [])
                if a.key != inst.key
            ]
            acted = False
            if alts and work.node_tasks.get(inst.key):
                alt = min(alts, key=lambda a: (_node_end(a, ends), a.key))
                margin = omega - _node_end(alt, ends)
                # lines 12-16: move
                tid = _best_move(views, inst.key, margin)
                if tid is not None:
                    eng.apply_move(tid, dst=alt.key, src=inst.key)
                    stats.moves += 1
                    acted = edited = True
                else:
                    # lines 18-22: swap
                    pair = _best_swap(views, inst.key, alt.key, margin)
                    if pair is not None:
                        tk, tj = pair
                        eng.apply_swap(tk, tj)
                        stats.swaps += 1
                        acted = edited = True
                if acted:
                    ends = eng.slice_end_times(include_reconfig=False)
            if not acted:  # lines 23-24: open the parent
                parent = parents[inst.key]
                if parent is None:
                    stop = True
                    break
                if parent.key not in opened:
                    opened.add(parent.key)
                    queue.append(parent)
        # line 26 equivalent: full timing recomputation + acceptance guard
        if edited:
            makespan = eng.makespan()
            if makespan < best_makespan - EPS:
                rel = best_makespan / makespan - 1.0
                best_makespan = makespan
                best_log_length = eng.log_length
                if rel < min_rel_improvement:
                    break
        else:
            break

    # exact undo back to the accepted best state, then materialise once
    eng.rollback(best_log_length)
    best_assign = eng.export_assignment()
    final = eng.schedule()
    final_makespan = final.makespan
    stats.improvement = (
        base_makespan / final_makespan - 1.0 if final_makespan > 0 else 0.0
    )
    return best_assign, final, stats


def _can_act(assignment, inst, nodes_by_size, ends, omega) -> bool:
    """Cheap check whether the root node could still move/swap anything."""
    alts = [a for a in nodes_by_size.get(inst.size, []) if a.key != inst.key]
    return bool(alts and assignment.node_tasks.get(inst.key))
