"""On-demand compiled backend for the incremental phase-2 evaluator.

``_fastsim.c`` (next to this module) is a bit-exact replica of Algorithm
1's heap phase (``repartition._list_schedule_arrays``) with resumable
state and mid-run snapshotting — the delta-replay engine of
``family_eval.IncrementalEvaluator``.  This module owns its build and
loading:

* compiled lazily with the system C compiler (``cc``/``gcc``/``clang``)
  into a user-cache ``.so`` keyed by the source hash, so a source edit
  invalidates the cache and concurrent builds race benignly through an
  atomic ``os.replace``;
* ``-O2 -ffp-contract=off``: optimisation must not fuse the chain
  additions into FMAs or the roundings would diverge from CPython's
  plain double adds (the bit-identical-winner contract);
* no compiler, no write access, or a failed smoke call all degrade to
  ``load() -> None`` — the evaluator then runs its pure-Python fallback
  with identical results.

Nothing here imports numpy at module load; the heap record dtype is
built on first use.
"""

from __future__ import annotations

import ctypes
import hashlib
import os
import shutil
import subprocess
import tempfile

_SOURCE = os.path.join(os.path.dirname(__file__), "_fastsim.c")
_CFLAGS = ["-O2", "-fPIC", "-shared", "-ffp-contract=off"]

#: tri-state: unset / (lib, fn) / None after a failed build
_LOADED: object = False

_HEAP_DTYPE = None
_EVT_DTYPE = None


def heap_dtype():
    """numpy dtype matching the C ``Ent`` heap record (24 bytes)."""
    global _HEAP_DTYPE
    if _HEAP_DTYPE is None:
        import numpy as np

        _HEAP_DTYPE = np.dtype(
            [("end", "<f8"), ("seq", "<i8"), ("nidx", "<i4"), ("pad", "<i4")]
        )
        assert _HEAP_DTYPE.itemsize == 24
    return _HEAP_DTYPE


def evt_dtype():
    """numpy dtype matching the C ``Evt`` event record (24 bytes)."""
    global _EVT_DTYPE
    if _EVT_DTYPE is None:
        import numpy as np

        _EVT_DTYPE = np.dtype(
            [("when", "<f8"), ("seq", "<i8"), ("what", "<i4"), ("nidx", "<i4")]
        )
        assert _EVT_DTYPE.itemsize == 24
    return _EVT_DTYPE


def _cache_dir() -> str:
    base = os.environ.get("XDG_CACHE_HOME") or os.path.join(
        os.path.expanduser("~"), ".cache"
    )
    return os.path.join(base, "repro-fastsim")


def _find_compiler() -> str | None:
    for cand in ("cc", "gcc", "clang"):
        path = shutil.which(cand)
        if path:
            return path
    return None


def _build() -> str | None:
    """Compile (or reuse) the shared object; returns its path or None."""
    try:
        with open(_SOURCE, "rb") as fh:
            src = fh.read()
    except OSError:
        return None
    digest = hashlib.sha256(src).hexdigest()[:16]
    cachedir = _cache_dir()
    so_path = os.path.join(cachedir, f"fastsim-{digest}.so")
    if os.path.exists(so_path):
        return so_path
    compiler = _find_compiler()
    if compiler is None:
        return None
    try:
        os.makedirs(cachedir, exist_ok=True)
        fd, tmp = tempfile.mkstemp(suffix=".so", dir=cachedir)
        os.close(fd)
        proc = subprocess.run(
            [compiler, *_CFLAGS, "-o", tmp, _SOURCE],
            capture_output=True,
            timeout=120,
        )
        if proc.returncode != 0:
            os.unlink(tmp)
            return None
        os.replace(tmp, so_path)  # atomic: concurrent builds race benignly
        return so_path
    except (OSError, subprocess.SubprocessError):
        try:
            os.unlink(tmp)
        except OSError:
            pass
        return None


class _Lib:
    """The two compiled entry points: ``run`` (Algorithm 1's heap phase)
    and ``score`` (``chains_makespan`` over a visit trace)."""

    __slots__ = ("run", "score", "_cdll")

    def __init__(self, cdll, run, score):
        self._cdll = cdll  # keep the dlopen handle alive
        self.run = run
        self.score = score


def load():
    """A :class:`_Lib` with the compiled entry points, or ``None``.

    The first call builds/loads and smoke-checks; the outcome (including
    failure) is cached for the process.
    """
    global _LOADED
    if _LOADED is not False:
        return _LOADED
    _LOADED = None
    so_path = _build()
    if so_path is None:
        return None
    try:
        lib = ctypes.CDLL(so_path)
        run = lib.fastsim_run
        score = lib.fastsim_score
    except (OSError, AttributeError):
        return None
    c = ctypes
    p = c.c_void_p
    run.restype = c.c_int
    run.argtypes = [
        p, p, p, p, p, p, p,                   # state
        c.c_int, c.c_int, p, p, p, p, p,       # spec context
        p, p, c.c_int,                         # candidate data
        c.c_int, c.c_int, c.c_int, c.c_int, c.c_int,  # trigger
        p, p, p, p, p, p, p, p,                # snapshot out
        p, p, p, c.c_longlong,                 # visits out
    ]
    score.restype = c.c_double
    score.argtypes = [
        c.c_int, c.c_int, p, p, c.c_int, c.c_int,  # nodes/sizes/trees
        p, p, p, p,                            # charges + children CSR
        p, c.c_int,                            # roots
        p, c.c_int,                            # candidate rows
        p, p, p, c.c_longlong,                 # visit trace
        p, p, p, p, p, p, p,                   # scratch
    ]
    _LOADED = _Lib(lib, run, score)
    return _LOADED


def available() -> bool:
    """Whether the compiled backend can be (or already is) loaded."""
    return load() is not None


def reset_for_tests() -> None:
    """Drop the cached load outcome (test hook)."""
    global _LOADED
    _LOADED = False


__all__ = ["available", "evt_dtype", "heap_dtype", "load", "reset_for_tests"]
