"""Roofline cost model: job time vs pod-slice count for the TPU adaptation.

This is the framework's ``t_i(s)`` (the paper profiles its tasks on each
MIG size; we derive ours from the same roofline terms the dry-run reports —
§Roofline in EXPERIMENTS.md cross-checks the two).

A pod slice = 32 chips ((2,16) block); a size-``s`` instance is a
(2s, 16) sub-mesh: the model axis stays 16 (TP/EP collectives over ICI),
the data axis grows with s.  Per step:

  compute    = FLOPs / (chips · peak · eff)
  memory     = bytes touched per chip / HBM bw, times a *spill* penalty
               when the working set exceeds HBM — remat/offload traffic
               grows sharply, which is what makes narrow instances
               super-linearly slow (the TPU analogue of the paper's §2.4
               memory-bound MIG superscaling)
  collective = TP/EP activation reductions + DP gradient reduction over ICI

  t(s) = (max of the three) · steps + dispatch overhead

Times are monotone non-increasing in ``s`` (paper monotony point 1) while
*work* ``s·t(s)`` is not monotone when spill is in play — exactly the
regime FAR's allocation family is designed for.
"""

from __future__ import annotations

import dataclasses

from repro.core.device_spec import DeviceSpec, TPU_POD_256
from repro.core.problem import Task
from repro.models.config import ArchConfig, ShapeConfig

# hardware constants (DESIGN.md §6)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
HBM_CAP = 16 * 2**30
ICI_BW = 100e9           # per chip budget (2 link-pairs x 50 GB/s)
COMPUTE_EFF = 0.5        # achievable fraction of peak on dense matmuls
MODEL_AXIS = 16


@dataclasses.dataclass(frozen=True)
class Job:
    """A schedulable unit: run `steps` steps of (arch × shape)."""

    id: int
    cfg: ArchConfig
    shape: ShapeConfig
    steps: int
    name: str = ""
    checkpoint_every: int = 50

    @property
    def label(self) -> str:
        return self.name or f"{self.cfg.name}/{self.shape.name}×{self.steps}"


def step_time(cfg: ArchConfig, shape: ShapeConfig, slices: int,
              chips_per_slice: int = 32) -> float:
    """Seconds per step on a size-``slices`` instance."""
    chips = slices * chips_per_slice
    dp = chips // MODEL_AXIS
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1
    )
    train = shape.kind == "train"
    factor = 6 if train else 2
    flops = factor * n_active * tokens
    # attention flops (quadratic part) — matters for prefill_32k
    if shape.kind != "decode" and cfg.family not in ("ssm",):
        att_layers = (
            cfg.n_layers // (cfg.shared_attn_every or cfg.n_layers)
            if cfg.family == "hybrid" else cfg.n_layers
        )
        window = cfg.sliding_window or 0
        if cfg.local_global:
            n_glob = cfg.n_layers // (cfg.local_global + 1)
            n_loc = cfg.n_layers - n_glob
            eff_ctx = (
                n_glob * shape.seq_len + n_loc * min(window, shape.seq_len)
            ) / cfg.n_layers
            att_layers = cfg.n_layers
        else:
            eff_ctx = shape.seq_len
        qk = cfg.n_heads * cfg.resolved_head_dim
        # QK^T + PV: 2 matmuls × 2 MAC × causal/2, per attention layer
        flops += (3 if train else 1) * 4 * tokens * (eff_ctx / 2) * qk \
            * att_layers

    t_compute = flops / (chips * PEAK_FLOPS * COMPUTE_EFF)

    # --- memory ------------------------------------------------------------
    param_bytes = n_params * 2
    opt_bytes = n_params * 8 if train else 0
    act_bytes_per_chip = (
        tokens / dp * cfg.d_model * 2 * cfg.n_layers * 4 / MODEL_AXIS
    )
    if shape.kind == "decode":
        # KV-cache / state read dominates
        if cfg.family in ("ssm", "hybrid"):
            state = cfg.n_layers * shape.global_batch * cfg.d_inner * 64 * 4
            act_bytes_per_chip = state / chips
        else:
            kv = (
                2 * cfg.n_layers * shape.global_batch * shape.seq_len
                * cfg.n_kv_heads * cfg.resolved_head_dim * 2
            )
            if cfg.local_global:
                n_glob = cfg.n_layers // (cfg.local_global + 1)
                kv = kv * n_glob / cfg.n_layers  # local caches are tiny
            act_bytes_per_chip = kv / chips
    weight_reads_per_chip = (param_bytes * (3 if train else 1)) / chips
    bytes_per_chip = weight_reads_per_chip + act_bytes_per_chip

    # working set per chip and the spill penalty (applied to the whole
    # step below: offload/remat traffic stalls compute too)
    need = (param_bytes + opt_bytes) / chips + act_bytes_per_chip
    spill = max(1.0, (need / HBM_CAP) ** 2)  # quadratic once over capacity
    t_memory = bytes_per_chip / HBM_BW

    # --- collectives --------------------------------------------------------
    act_ar = 2 * (tokens / dp) * cfg.d_model * 2 * cfg.n_layers * 2
    if shape.kind == "decode":
        act_ar = 2 * (tokens / dp) * cfg.d_model * 2 * cfg.n_layers * 2
    grad_ar = 2 * param_bytes / max(dp, 1) if train else 0.0
    t_coll = (act_ar + grad_ar) / ICI_BW

    return max(t_compute, t_memory, t_coll) * spill


def job_time(job: Job, slices: int, chips_per_slice: int = 32,
             dispatch_overhead: float = 2.0) -> float:
    return (
        step_time(job.cfg, job.shape, slices, chips_per_slice) * job.steps
        + dispatch_overhead
    )


def job_to_task(job: Job, spec: DeviceSpec = TPU_POD_256) -> Task:
    """Profile a job on every instance size of ``spec`` (the paper's t_i)."""
    times = {
        s: job_time(job, s, spec.chips_per_slice) for s in spec.sizes
    }
    # enforce monotone non-increasing times (paper monotony point 1) in the
    # face of modelling noise
    sizes = sorted(times)
    for a, b in zip(sizes, sizes[1:]):
        times[b] = min(times[b], times[a])
    return Task(id=job.id, times=times, name=job.label)
