"""Online moldable scheduling (the paper's §7 future work).

When tasks are too short/sparse to accumulate into batches, they must be
placed on arrival.  This scheduler keeps the committed assignment (the
repartitioning tree with per-node task lists) and, for each arriving task,
trial-assigns it to every instance node at every moldable size and keeps
the placement minimising ``completion + s·t(s)/#slices`` — its own finish
time plus the machine-time it consumes spread over the slices (exact
evaluation through the replay-equivalent
:class:`~repro.core.timing.TimingEngine` — speculative append/undo per
candidate — so reconfiguration sequencing and tree feasibility are
inherited rather than re-derived).  The area term is the online analogue of phase 1's min-work
molding: pure min-completion grabs the widest instance for every early
task and starves the queue (measured 2.9-3.6x of offline FAR on
PoorScaling; with the area term ~1.5-2x).

One :class:`TimingEngine` persists across submits: each arrival costs only
its speculative append/undo probes plus one committed append, and
``schedule()`` / ``makespan`` are served straight from the engine (the
replay-equivalence contract in ``tests/test_timing_engine.py`` guarantees
they match a cold ``replay()`` bit-for-bit).  A ``release``/``alive``
seam context makes the same greedy usable after a committed multi-batch
tail — that is the :class:`~repro.core.service.SchedulingService` fallback
path for urgent or trickling tasks.

The paper's Theorem-from-[38] framing gives batched FAR a competitive
ratio of 2ρ against the offline optimum; this greedy has no such guarantee
and measures 1.3-3.2× of offline FAR on the paper's synthetic workloads
(worst on PoorScaling, where early commitments serialise the narrow
instances — ``benchmarks/t_online.py``).  That gap *is* the paper's §2.3
argument for the offline batched formulation, now quantified.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Sequence

from repro.core.device_spec import DeviceSpec
from repro.core.policy import (
    BasePolicy,
    PlanResult,
    SchedulerConfig,
    register_policy,
)
from repro.core.problem import Schedule, Task
from repro.core.repartition import Assignment, NodeKey
from repro.core.timing import TimingEngine


@dataclasses.dataclass
class OnlinePlacement:
    task_id: int
    node_key: tuple
    size: int
    begin: float
    end: float


def completion_floor(candidates, busy, at: float) -> float:
    """Greedy completion bound: the earliest any candidate instance can
    finish the task given per-cell busy-until times.

    ``candidates`` yields ``(node, size_keyed_times)`` pairs (every
    instance node the task could be molded to); ``busy`` maps blocked
    ``(tree, slice)`` cells to the time they clear.  Each candidate can
    start no earlier than ``max(at, cell clear times)`` and runs its
    profiled duration; the floor is the minimum completion over all
    candidates.  Whether this is an admissible lower bound or a
    conservative envelope is decided entirely by what ``busy`` contains:
    the synchronous service feeds work *running* at ``at`` (provable
    floor, admission-safe), the sharded fast path feeds every committed
    placement (dominating envelope, so a fast-path admit never lets in
    a task the exact check would provably reject).
    """
    best = float("inf")
    for node, times in candidates:
        floor = at
        for cell in node.blocked_cells:
            b = busy.get(cell, 0.0)
            if b > floor:
                floor = b
        done = floor + times[node.size]
        if done < best:
            best = done
    return best


class OnlineScheduler:
    """Arrival-driven moldable placement on the repartitioning tree.

    ``release``/``alive`` (the fields of a committed
    :class:`~repro.core.multibatch.Tail`) seed the engine's seam context so
    arrivals are placed *after* an already-committed schedule; both default
    to a cold device.
    """

    def __init__(
        self,
        spec: DeviceSpec,
        release: dict | None = None,
        alive: dict[NodeKey, float] | None = None,
    ):
        self.spec = spec
        self.assignment = Assignment(spec, {}, {})
        self.placements: list[OnlinePlacement] = []
        # one persistent engine for the scheduler's lifetime; it shares the
        # assignment's chains (copy_chains=False), so committed appends are
        # visible in self.assignment without double bookkeeping
        self._eng = TimingEngine(
            self.assignment, release=release, alive=alive, copy_chains=False,
        )

    def _probe(self, task: Task, arrival: float):
        """One speculative append + timing read + undo per candidate node
        on the persistent engine; returns the greedy's arrival-satisfying
        choice and the unconstrained best-completion fallback, each as
        ``(score, size, node_key)`` or ``None``.  ``task`` must already be
        registered in ``self.assignment.tasks``."""
        best: tuple[float, int, tuple] | None = None
        fallback: tuple[float, int, tuple] | None = None
        eng = self._eng
        for node in self.spec.nodes:
            if node.size not in task.times:
                continue
            eng.apply_append(task.id, node.key)
            begin, end = eng.task_begin_end(task.id)
            eng.undo()
            area = node.size * task.times[node.size] / self.spec.n_slices
            key = (end + area, node.size, node.key)
            if (best is None or key < best) and begin >= arrival - 1e-9:
                best = key
            if fallback is None or end < fallback[0]:
                fallback = (end, node.size, node.key)
        return best, fallback

    def best_placement(
        self, task: Task, arrival: float = 0.0
    ) -> tuple | None:
        """Preview the greedy's choice for ``task`` WITHOUT committing.

        Returns ``(rank, score, size, node_key)`` — rank 0 when the
        placement satisfies the arrival preference, 1 for the
        work-conserving fallback — or ``None`` when no node fits.  The
        cluster serving driver compares these keys across devices to pick
        where an urgent/trickle task goes, then commits with
        :meth:`submit` (which re-derives the identical choice)."""
        task = task.bind(self.spec)
        had = task.id in self.assignment.tasks
        prev = self.assignment.tasks.get(task.id)
        self.assignment.tasks[task.id] = task
        try:
            best, fallback = self._probe(task, arrival)
        finally:
            if had:
                self.assignment.tasks[task.id] = prev
            else:
                del self.assignment.tasks[task.id]
        if best is not None:
            return (0,) + best
        if fallback is not None:
            return (1,) + fallback
        return None

    def submit(
        self, task: Task, arrival: float = 0.0,
        node_key: tuple | None = None,
    ) -> OnlinePlacement:
        """Place ``task`` immediately; returns the chosen placement.

        ``arrival`` is a soft preference: placements starting before it
        are filtered out while any candidate satisfies it, but the chain
        model cannot hold a slice idle (tasks are appended back-to-back,
        never delayed — no preemption, per the MIG model), so when every
        chain would start early the task is placed for best completion
        anyway (the fallback).  For a *hard* floor, seed ``release`` with
        the decision time — that is what
        :class:`~repro.core.service.SchedulingService` does, making its
        combined timeline causal.

        ``node_key`` commits a choice previewed by
        :meth:`best_placement` directly, skipping the probe pass (the
        cluster serving driver previews every device and must not pay
        the winning device's node scan twice).
        """
        task = task.bind(self.spec)  # lower a heterogeneous profile
        self.assignment.tasks[task.id] = task
        if node_key is None:
            best, fallback = self._probe(task, arrival)
            if best is None:
                best = fallback
            assert best is not None, "no feasible size for task"
            node_key = best[2]
        eng = self._eng
        eng.apply_append(task.id, node_key)  # commit (chains are shared)
        begin, end = eng.task_begin_end(task.id)
        placement = OnlinePlacement(task.id, node_key, node_key[2], begin, end)
        self.placements.append(placement)
        return placement

    def cancel(self, task_id: int, at: float) -> OnlinePlacement:
        """Cancel a committed placement at absolute time ``at`` — the
        losing attempt of a speculation race.  The slot becomes a failed
        occupancy record truncated to the span it physically held the
        slice (engine op :meth:`~repro.core.timing.ChainState.apply_cancel`,
        logged and undo-exact), successors re-time, and ``schedule()``
        materialises it with ``failed=True``."""
        eng = self._eng
        begin, _ = eng.task_begin_end(task_id)
        eng.apply_cancel(task_id, max(at - begin, 1e-9))
        placement = None
        for p in self.placements:  # cancelled + successors all re-time
            p.begin, p.end = eng.task_begin_end(p.task_id)
            if p.task_id == task_id:
                placement = p
        assert placement is not None, f"task {task_id} has no placement"
        return placement

    def withdraw_not_started(self, t: float, eps: float = 1e-9) -> list[Task]:
        """Pull back every placement that has not started by time ``t``.

        "Started" is judged once, against the timings at the decision
        instant (the pre-withdrawal state) — anything that re-times
        *after* work is freed has, by definition, not begun at ``t``, so
        deciding from post-retraction begins would keep acausal
        placements.  Within a chain begins increase along the chain (its
        tasks run back-to-back), so the not-started set is a per-chain
        suffix and retracts newest-first through the engine's
        suffix-retraction API.  Survivors may recompact earlier (freed
        reconfiguration slots), never later.  Returns the withdrawn tasks
        in their original submission order.
        """
        eng = self._eng
        withdrawn_ids = {
            tid
            for lst in eng.chains.values()
            for tid in lst
            if eng.task_begin_end(tid)[0] > t + eps
        }
        for key, lst in eng.chains.items():
            while lst and lst[-1] in withdrawn_ids:
                eng.apply_retract(lst[-1], key)
        # begins are monotone along a chain, so nothing withdrawn remains
        assert not any(
            tid in withdrawn_ids for lst in eng.chains.values() for tid in lst
        )
        out = [
            self.assignment.tasks.pop(p.task_id)
            for p in self.placements
            if p.task_id in withdrawn_ids
        ]
        self.placements = [
            p for p in self.placements if p.task_id not in withdrawn_ids
        ]
        for p in self.placements:  # re-read: survivors may have compacted
            p.begin, p.end = eng.task_begin_end(p.task_id)
        return out

    def schedule(self) -> Schedule:
        """Full Schedule, bit-identical to a cold ``replay()`` of the
        committed assignment under this scheduler's seam context."""
        return self._eng.schedule()

    @property
    def makespan(self) -> float:
        return self._eng.makespan()


@register_policy("online-greedy")
class OnlineGreedyPolicy(BasePolicy):
    """The arrival-order greedy as a registry policy.

    Unlike the batch policies, tail-awareness is native: the tail's
    ``release``/``alive`` context seeds the placement engine instead of a
    post-hoc seam concatenation, because the greedy's whole point is that
    its decisions see the committed state.
    """

    def plan(
        self,
        tasks: Sequence[Task],
        spec: DeviceSpec,
        config: SchedulerConfig | None = None,
        tail: object | None = None,
    ) -> PlanResult:
        t0 = time.perf_counter()
        if tail is None:
            sched = OnlineScheduler(spec)
        else:
            sched = OnlineScheduler(
                spec, release=tail.release, alive=tail.alive
            )
        for task in tasks:
            sched.submit(task)
        schedule = sched.schedule()
        new_tail = None
        if tail is not None:
            from repro.core.multibatch import tail_after

            new_tail = tail_after(schedule, tail)
        return PlanResult(
            policy=self.name,
            schedule=schedule,
            makespan=schedule.makespan,
            assignment=sched.assignment,
            tail=new_tail,
            elapsed_s=time.perf_counter() - t0,
            extras={"placements": sched.placements},
        )
