"""Online moldable scheduling (the paper's §7 future work).

When tasks are too short/sparse to accumulate into batches, they must be
placed on arrival.  This scheduler keeps the committed assignment (the
repartitioning tree with per-node task lists) and, for each arriving task,
trial-assigns it to every instance node at every moldable size and keeps
the placement minimising ``completion + s·t(s)/#slices`` — its own finish
time plus the machine-time it consumes spread over the slices (exact
evaluation through the replay-equivalent
:class:`~repro.core.timing.TimingEngine` — speculative append/undo per
candidate — so reconfiguration sequencing and tree feasibility are
inherited rather than re-derived).  The area term is the online analogue of phase 1's min-work
molding: pure min-completion grabs the widest instance for every early
task and starves the queue (measured 2.9-3.6x of offline FAR on
PoorScaling; with the area term ~1.5-2x).

The paper's Theorem-from-[38] framing gives batched FAR a competitive
ratio of 2ρ against the offline optimum; this greedy has no such guarantee
and measures 1.3-3.2× of offline FAR on the paper's synthetic workloads
(worst on PoorScaling, where early commitments serialise the narrow
instances — ``benchmarks/t_online.py``).  That gap *is* the paper's §2.3
argument for the offline batched formulation, now quantified.
"""

from __future__ import annotations

import dataclasses

from repro.core.device_spec import DeviceSpec
from repro.core.problem import Schedule, Task
from repro.core.repartition import Assignment, replay
from repro.core.timing import TimingEngine


@dataclasses.dataclass
class OnlinePlacement:
    task_id: int
    node_key: tuple
    size: int
    begin: float
    end: float


class OnlineScheduler:
    """Arrival-driven moldable placement on the repartitioning tree."""

    def __init__(self, spec: DeviceSpec):
        self.spec = spec
        self.assignment = Assignment(spec, {}, {})
        self.placements: list[OnlinePlacement] = []

    def submit(self, task: Task, arrival: float = 0.0) -> OnlinePlacement:
        """Place ``task`` immediately; returns the chosen placement.

        ``arrival`` is honoured as a lower bound on the start by treating
        earlier-committed work as fixed (tasks are appended, never moved —
        no preemption, per the MIG model).
        """
        best: tuple[float, int, tuple] | None = None
        self.assignment.tasks[task.id] = task
        # one incremental engine per arrival: each candidate placement is a
        # speculative append + timing read + undo instead of a full replay
        eng = TimingEngine(self.assignment)
        for node in self.spec.nodes:
            if node.size not in task.times:
                continue
            eng.apply_append(task.id, node.key)
            begin, end = eng.task_begin_end(task.id)
            eng.undo()
            area = node.size * task.times[node.size] / self.spec.n_slices
            key = (end + area, node.size, node.key)
            if (best is None or key < (best[0], best[1], best[2])) \
               and begin >= arrival - 1e-9:
                best = (end + area, node.size, node.key)
        if best is None:
            # arrival constraint unsatisfiable anywhere -> place for best
            # completion anyway (work-conserving)
            for node in self.spec.nodes:
                if node.size not in task.times:
                    continue
                eng.apply_append(task.id, node.key)
                _, end = eng.task_begin_end(task.id)
                eng.undo()
                if best is None or end < best[0]:
                    best = (end, node.size, node.key)
        assert best is not None, "no feasible size for task"
        _, size, node_key = best
        self.assignment.node_tasks.setdefault(node_key, []).append(task.id)
        eng.apply_append(task.id, node_key)
        begin, end = eng.task_begin_end(task.id)
        placement = OnlinePlacement(task.id, node_key, size, begin, end)
        self.placements.append(placement)
        return placement

    def schedule(self) -> Schedule:
        return replay(self.assignment)

    @property
    def makespan(self) -> float:
        return self.schedule().makespan
