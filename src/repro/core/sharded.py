"""Sharded, asynchronous serving on top of :class:`SchedulingService`.

The synchronous service is one object whose ``submit`` can block on an
inline planner flush — decision latency on the submit path is bounded by
planner cost, exactly what MISO-style online serving systems cannot
afford.  :class:`ShardedSchedulingService` splits that into

* a **fast admission path**: ``submit`` picks the least-loaded shard
  whose pool supports the task (O(#shards), constant in queue length),
  runs an engine-derived greedy completion-bound admission check against
  a cached busy envelope (:func:`~repro.core.online.completion_floor`)
  and appends to the shard's inbox — no planning, no tail mutation;
* **background planning**: ``pump()`` (the virtual-time stand-in for a
  background worker loop) drains inboxes into the per-shard inner
  :class:`SchedulingService` objects, where the existing batching /
  deadline / admission / replan / fault machinery runs unchanged.
  Flush planning inside each inner service is pipelined with commit via
  the ``plan_batch`` / ``commit_plan`` split (see
  :mod:`repro.core.multibatch`);
* **work stealing**: before forwarding, queued work migrates from the
  heaviest shard's inbox to the lightest shard that supports it, so one
  hot shard cannot starve the pool.

Two operating modes, chosen at construction:

``defer=False`` (immediate mode) makes the facade a *transparent proxy*:
every ``submit``/``poll``/``report``/... forwards synchronously to the
inner service(s).  With one shard this is **bit-identical** to driving a
:class:`SchedulingService` directly — the differential suite in
``tests/test_scale.py`` pins ``_plan_signature`` equality with
deadlines, admission, replan and fault reporting enabled.

``defer=True`` (async mode) enables the fast path.  Placement decisions
then happen at pump time: a task's causal floor is still its submit
stamp (``admission_stamps``), and the inner decision time can only be
later, so nothing ever begins before its submit decision.  The fast
admission check uses an *envelope* over every committed placement of the
shard (not just running work): the envelope dominates the exact
running-work lower bound at any later instant, so the fast path never
admits a task the exact check would provably reject at the same moment —
the price is that it may conservatively shed a task the exact check
would still have squeezed in.

**Shard layout**: a ClusterSpec pool's devices are dealt round-robin —
global device ``g`` lives on shard ``g % shards`` at local index
``g // shards`` — and each shard serves its devices as an independent
ClusterSpec (one shard reuses the pool object itself, which is what
makes the one-shard differential exact).  ``quarantine``/``recover``
accept pool-global device indices (or DeviceSpecs, or failure-domain
sequences) and route each member to its shard.

**Drain semantics**: ``drain()`` forwards every inbox (after a final
steal pass), then drains each inner service — retries play out, parked
tasks are rejected, nothing is stranded.  With one shard it returns the
combined :class:`~repro.core.problem.Schedule`; with many it returns one
schedule per shard (their timelines share virtual time but separate
device pools, so a merged Schedule would lie about tree identity).
"""

from __future__ import annotations

import dataclasses
import time

from repro.core.cluster import ClusterSpec, cluster
from repro.core.device_spec import DeviceSpec
from repro.core.online import completion_floor
from repro.core.policy import SchedulerConfig
from repro.core.problem import EPS, Schedule, Task
from repro.core.service import SchedulingService, ServiceStats

__all__ = [
    "FastDecision",
    "ScaleStats",
    "ShardedSchedulingService",
]


@dataclasses.dataclass(frozen=True)
class FastDecision:
    """One fast-path intake decision (defer mode)."""

    task_id: int
    arrival: float
    shard: int                  # -1: rejected before shard assignment
    verdict: str                # "queued" | "placed" | "demoted" | "rejected"
    admit_wall_s: float         # wall-clock cost of the submit call


@dataclasses.dataclass
class ScaleStats:
    """Sharded-layer counters (the per-shard ServiceStats live on the
    inner services; see :meth:`ShardedSchedulingService.stats`)."""

    submitted: int = 0
    forwarded: int = 0
    pumps: int = 0
    steals: int = 0              # tasks migrated between shard inboxes
    fast_rejected: list[int] = dataclasses.field(default_factory=list)
    fast_demoted: list[int] = dataclasses.field(default_factory=list)
    expired: list[int] = dataclasses.field(default_factory=list)
    intake: list[FastDecision] = dataclasses.field(default_factory=list)
    queue_depths: list[tuple[float, int]] = dataclasses.field(
        default_factory=list)  # (virtual time, total inbox depth) per pump

    def admit_wall_s(self) -> list[float]:
        return [d.admit_wall_s for d in self.intake]


def _work_estimate(task: Task) -> float:
    """Best-case seconds of the task — the load currency of shard
    selection and stealing (cheap, profile-only, device-agnostic)."""
    return min(task.times.values())


class ShardedSchedulingService:
    """Shard a device pool across independent serving cores.

    Args:
      pool: the full :class:`DeviceSpec` or :class:`ClusterSpec`.
      shards: number of serving cores; a ClusterSpec pool supports up to
        one shard per device, a bare DeviceSpec exactly one.
      policy / config: forwarded to every inner service unchanged.
      defer: ``True`` = async fast path + ``pump()`` (the serving mode),
        ``False`` = transparent synchronous proxy (the differential
        mode; with one shard, bit-identical to SchedulingService).
    """

    def __init__(
        self,
        pool: DeviceSpec | ClusterSpec,
        shards: int = 1,
        policy: str = "far",
        config: SchedulerConfig | None = None,
        defer: bool = True,
    ):
        self.config = config or SchedulerConfig()
        self.policy = policy
        self.pool = pool
        self.defer = defer
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if isinstance(pool, ClusterSpec):
            if shards > len(pool.devices):
                raise ValueError(
                    f"cannot split {len(pool.devices)} devices into "
                    f"{shards} shards"
                )
            if shards == 1:
                pools: list[DeviceSpec | ClusterSpec] = [pool]
            else:
                pools = [
                    cluster(*pool.devices[i::shards],
                            name=f"{pool.name}/shard{i}")
                    for i in range(shards)
                ]
        else:
            if shards != 1:
                raise ValueError(
                    "a single DeviceSpec pool cannot be sharded; pass a "
                    "ClusterSpec to serve more than one shard"
                )
            pools = [pool]
        self._k = shards
        self._shards = [
            SchedulingService(pool=p, policy=policy, config=self.config)
            for p in pools
        ]
        self.now = 0.0
        self.scale = ScaleStats()
        self._inbox: list[list[tuple[Task, float, float | None]]] = [
            [] for _ in range(shards)
        ]
        self._inbox_work = [0.0] * shards
        self._tail_load = [0.0] * shards
        self._owner: dict[int, int] = {}
        self._stamps: dict[int, float] = {}      # task id -> submit stamp
        self._envelopes: list[dict | None] = [None] * shards
        self._unforwarded: set[int] = set()

    # -- introspection ------------------------------------------------------
    @property
    def shards(self) -> int:
        return self._k

    @property
    def shard_services(self) -> list[SchedulingService]:
        """The inner per-shard services (read-only access for tests and
        reporting; driving them directly voids the causal bookkeeping)."""
        return list(self._shards)

    def admission_stamps(self) -> dict[int, float]:
        """Submit-decision virtual times — the causal floor of every task
        that entered through this facade (``tests/invariants.shard_floors``
        folds these under the inner flush-decision floors)."""
        return dict(self._stamps)

    # -- intake -------------------------------------------------------------
    def submit(
        self,
        task: Task,
        arrival: float | None = None,
        urgent: bool = False,
        deadline: float | None = None,
    ) -> str:
        """Fast-path intake: shard selection + admission gate + inbox
        append in defer mode, a transparent forward otherwise.  Returns
        the intake verdict (same vocabulary as the inner service)."""
        t0 = time.perf_counter()
        arrival = self.now if arrival is None else float(arrival)
        if arrival < self.now - 1e-9:
            raise ValueError(
                f"arrivals must be non-decreasing: {arrival} < {self.now}"
            )
        self.now = max(self.now, arrival)
        self.scale.submitted += 1

        if not self.defer:
            shard = self._select_shard(task)
            if shard is None:
                self.scale.intake.append(FastDecision(
                    task.id, arrival, -1, "rejected",
                    time.perf_counter() - t0))
                # mirror the sync intake verdict exactly: the inner
                # service records the rejection itself when it owns the
                # full pool, so only multi-shard selection rejects here
                if self._k == 1:
                    return self._shards[0].submit(
                        task, arrival=arrival, urgent=urgent,
                        deadline=deadline)
                self.scale.fast_rejected.append(task.id)
                return "rejected"
            self._owner[task.id] = shard
            self._stamps[task.id] = arrival
            verdict = self._shards[shard].submit(
                task, arrival=arrival, urgent=urgent, deadline=deadline
            )
            self._touch(shard)
            self.scale.intake.append(FastDecision(
                task.id, arrival, shard, verdict,
                time.perf_counter() - t0))
            return verdict

        # same API-boundary validation as the sync service (a malformed
        # profile must fail the submit, not a later pump)
        self._shards[0]._validate_task(task)
        shard = self._select_shard(task)
        if shard is None:
            self.scale.fast_rejected.append(task.id)
            self.scale.intake.append(FastDecision(
                task.id, arrival, -1, "rejected", time.perf_counter() - t0))
            return "rejected"
        verdict = "queued"
        if deadline is not None:
            deadline = float(deadline)
            if deadline < arrival - 1e-9:
                raise ValueError(
                    f"task {task.id}: deadline {deadline} precedes its "
                    f"arrival {arrival}"
                )
            verdict = self._fast_admit(shard, task, arrival, deadline)
            if verdict == "rejected":
                self.scale.intake.append(FastDecision(
                    task.id, arrival, shard, verdict,
                    time.perf_counter() - t0))
                return verdict
            if verdict == "demoted":
                deadline = None
        self._owner[task.id] = shard
        self._stamps[task.id] = arrival
        if urgent:
            # urgency bypasses the inbox by definition: forward now
            self._touch(shard)
            inner = self._shards[shard]
            inner.submit(task, arrival=max(arrival, inner.now),
                         urgent=True, deadline=deadline)
            self.scale.forwarded += 1
            verdict = "placed" if verdict == "queued" else verdict
        else:
            self._inbox[shard].append((task, arrival, deadline))
            self._inbox_work[shard] += _work_estimate(task)
            self._unforwarded.add(task.id)
        self.scale.intake.append(FastDecision(
            task.id, arrival, shard, verdict, time.perf_counter() - t0))
        return verdict

    def _select_shard(self, task: Task) -> int | None:
        """Least-loaded supporting shard (load = cached committed-tail
        pressure + queued inbox work; ties to the lower index)."""
        best = None
        best_load = 0.0
        for i in range(self._k):
            if not self._shard_supports(i, task):
                continue
            load = self._tail_load[i] + self._inbox_work[i]
            if best is None or load < best_load - 1e-12:
                best, best_load = i, load
        return best

    def _shard_supports(self, i: int, task: Task) -> bool:
        inner = self._shards[i]
        if inner.cluster is not None:
            return inner.cluster.supports(task)
        return True  # single device: the sync service defers validation too

    def _fast_admit(self, shard: int, task: Task, arrival: float,
                    deadline: float) -> str:
        """The O(#nodes) admission gate: greedy completion floor against
        the shard's committed-work envelope.  Envelope >= exact running-
        work bound, so an admit here can never contradict a provable
        exact-check reject; a reject here is load shedding, not proof."""
        if self.config.admission == "none":
            return "queued"
        inner = self._shards[shard]
        bound = completion_floor(
            inner._node_candidates(task), self._envelope(shard), arrival
        )
        if bound <= deadline + EPS:
            return "queued"
        if self.config.admission == "reject":
            self.scale.fast_rejected.append(task.id)
            return "rejected"
        self.scale.fast_demoted.append(task.id)
        return "demoted"

    def _envelope(self, i: int) -> dict:
        """Per-cell busy-until envelope over EVERY committed placement of
        shard ``i`` (running or queued), rebuilt lazily after any inner-
        state change.  Folding queued placements in is what makes the
        cache sound between pumps: the inner timeline is frozen except
        for already-committed begins, all of which the envelope covers."""
        env = self._envelopes[i]
        if env is None:
            inner = self._shards[i]
            env = {}
            for seg in inner.mb.segments:
                if seg.makespan <= inner.now:
                    continue  # fully drained: cannot constrain the future
                for it in seg.items:
                    for cell in it.node.blocked_cells:
                        if it.end > env.get(cell, 0.0):
                            env[cell] = it.end
            self._envelopes[i] = env
        return env

    def _touch(self, i: int) -> None:
        self._envelopes[i] = None

    # -- background planning ------------------------------------------------
    def pump(self, now: float | None = None) -> None:
        """The background worker's turn: steal across inboxes, forward
        every inbox into its inner service (planning happens there, off
        the submit path) and advance the shards to ``now``."""
        if now is not None:
            if now < self.now - 1e-9:
                raise ValueError(
                    f"time must be non-decreasing: {now} < {self.now}"
                )
            self.now = max(self.now, now)
        self.scale.pumps += 1
        self.scale.queue_depths.append(
            (self.now, sum(len(b) for b in self._inbox))
        )
        self.scale.steals += self._steal()
        for i in range(self._k):
            self._forward(i)
            inner = self._shards[i]
            if self.now > inner.now:
                inner.poll(self.now)
            self._touch(i)
            self._tail_load[i] = max(0.0, inner.makespan - self.now)

    def poll(self, now: float) -> None:
        """Advance virtual time (defer mode: one pump; immediate mode: a
        transparent forward)."""
        if now < self.now - 1e-9:
            raise ValueError(f"time must be non-decreasing: {now} < {self.now}")
        self.now = max(self.now, now)
        if self.defer:
            self.pump(now)
            return
        for i in range(self._k):
            inner = self._shards[i]
            if now > inner.now:
                inner.poll(now)
            self._touch(i)

    def flush(self) -> None:
        """Force-flush: forward every inbox and flush every shard."""
        if self.defer:
            self.pump(self.now)
        for i in range(self._k):
            self._shards[i].flush()
            self._touch(i)

    def drain(self) -> Schedule | list[Schedule]:
        """Forward everything still queued, then drain every shard (see
        the module docstring for the one-vs-many return shape)."""
        if self.defer:
            self.pump(self.now)
        out = [s.drain() for s in self._shards]
        for i in range(self._k):
            self._touch(i)
        return out[0] if self._k == 1 else out

    def _forward(self, i: int) -> None:
        inbox = self._inbox[i]
        if not inbox:
            return
        self._inbox[i] = []
        self._inbox_work[i] = 0.0
        inner = self._shards[i]
        self._touch(i)
        for task, arrival, deadline in inbox:
            self._unforwarded.discard(task.id)
            # a stolen task may carry an arrival this shard's clock has
            # already passed: it reaches THIS planner at forward time
            a = arrival if arrival >= inner.now else inner.now
            if deadline is not None and deadline < a - 1e-9:
                # the SLO expired while queued: a placement can only
                # begin at or after the forward decision, so the miss is
                # already certain — track it, plan best-effort
                self.scale.expired.append(task.id)
                deadline = None
            inner.submit(task, arrival=a, deadline=deadline)
            self.scale.forwarded += 1

    def _steal(self) -> int:
        """Deterministic load balancing: migrate queued (never planned)
        tasks from the heaviest shard's inbox to the lightest supporting
        shard until their load gap halves.  Newest work moves first —
        the oldest tasks keep their position near the front of the
        donor's queue, preserving its budget-flush cadence."""
        if self._k == 1:
            return 0
        moved = 0
        for _ in range(self._k):
            loads = [
                self._tail_load[i] + self._inbox_work[i]
                for i in range(self._k)
            ]
            donor = max(range(self._k), key=lambda i: (loads[i], -i))
            recv = min(range(self._k), key=lambda i: (loads[i], i))
            gap = loads[donor] - loads[recv]
            if donor == recv or len(self._inbox[donor]) < 2 or gap <= 1e-9:
                break
            budget = gap / 2.0
            taken: list[int] = []
            for idx in range(len(self._inbox[donor]) - 1, -1, -1):
                task, _, _ = self._inbox[donor][idx]
                w = _work_estimate(task)
                if w > budget:
                    continue
                if not self._shard_supports(recv, task):
                    continue
                taken.append(idx)
                budget -= w
            if not taken:
                break
            for idx in taken:  # descending: pops stay positional
                entry = self._inbox[donor].pop(idx)
                task = entry[0]
                w = _work_estimate(task)
                self._inbox_work[donor] -= w
                self._inbox_work[recv] += w
                self._inbox[recv].append(entry)
                self._owner[task.id] = recv
                moved += 1
        return moved

    # -- runtime feedback ---------------------------------------------------
    def report(self, task_id: int, event: str, t: float,
               end: float | None = None):
        """Route a runtime report to the owning shard (forwarding its
        inbox first if the task somehow has not been planned yet).

        The routing refreshes the shard's cached admission state: the
        busy envelope is dropped (so the next fast admit rebuilds it
        from the corrected placements — an early completion immediately
        widens the admission window instead of waiting for the next
        pump) and the tail-load figure shard selection reads is
        re-derived from the corrected inner makespan."""
        shard = self._owner_of(task_id)
        if task_id in self._unforwarded:
            self.now = max(self.now, t)
            self._forward(shard)
        self._touch(shard)
        out = self._shards[shard].report(task_id, event, t, end=end)
        self.now = max(self.now, t)
        self._tail_load[shard] = max(
            0.0, self._shards[shard].makespan - self.now
        )
        return out

    def _owner_of(self, task_id: int) -> int:
        shard = self._owner.get(task_id)
        if shard is None:
            # backup-attempt ids and other service-minted ids belong to
            # whichever shard committed them
            for i in range(self._k):
                if self._shards[i].committed_item(task_id) is not None:
                    return i
            raise KeyError(f"task {task_id} was never submitted here")
        return shard

    def quarantine(self, device, t: float) -> list[int]:
        """Pool-global device loss: accepts an index, a DeviceSpec or a
        failure-domain sequence, splits it per shard and quarantines each
        member on its owner.  Returns the merged running-attempt ids."""
        running: list[int] = []
        for shard, local in self._locate(device):
            self._touch(shard)
            running.extend(self._shards[shard].quarantine(local, t))
        self.now = max(self.now, t)
        return running

    def recover(self, device, t: float) -> None:
        for shard, local in self._locate(device):
            self._touch(shard)
            self._shards[shard].recover(local, t)
        self.now = max(self.now, t)

    def _locate(self, device) -> list[tuple[int, int]]:
        """(shard, local device index) for a pool-global device argument;
        domain sequences map member-wise, grouped per shard so correlated
        members of one shard go down in a single call."""
        if isinstance(device, (list, tuple)):
            members = [self._global_index(d) for d in device]
        else:
            members = [self._global_index(device)]
        if self._k == 1:
            return [(0, g) for g in members]
        grouped: dict[int, list[int]] = {}
        for g in members:
            grouped.setdefault(g % self._k, []).append(g // self._k)
        out: list[tuple[int, object]] = []
        for shard in sorted(grouped):
            locals_ = grouped[shard]
            out.append((shard, locals_ if len(locals_) > 1 else locals_[0]))
        return out  # type: ignore[return-value]

    def _global_index(self, device) -> int:
        if isinstance(device, int):
            return device
        if not isinstance(self.pool, ClusterSpec):
            raise ValueError("device arguments need a ClusterSpec pool")
        for i, dev in enumerate(self.pool.devices):
            if dev is device:
                return i
        raise ValueError(f"device {device!r} is not in pool {self.pool.name!r}")

    # -- reporting ----------------------------------------------------------
    @property
    def cluster(self) -> ClusterSpec | None:
        return self.pool if isinstance(self.pool, ClusterSpec) else None

    @property
    def mb(self):
        """One-shard compatibility hook (``assert_fault_invariants`` and
        the closed-loop harness read ``svc.mb``)."""
        if self._k != 1:
            raise AttributeError(
                "mb is per-shard on a multi-shard service; use "
                "shard_services"
            )
        return self._shards[0].mb

    @property
    def pending(self) -> list:
        out: list = []
        for i in range(self._k):
            out.extend(self._inbox[i])
            out.extend(self._shards[i].pending)
        return out

    @property
    def completions(self) -> dict[int, float]:
        out: dict[int, float] = {}
        for s in self._shards:
            out.update(s.completions)
        return out

    @property
    def stats(self) -> ServiceStats:
        """One shard: the inner stats object itself (differential tests
        compare it field-for-field).  Many shards: a merged snapshot —
        counters summed, event lists concatenated in (decided_at, task)
        order."""
        if self._k == 1:
            return self._shards[0].stats
        merged = ServiceStats()
        for s in self._shards:
            st = s.stats
            merged.submitted += st.submitted
            merged.batches += st.batches
            merged.online_placements += st.online_placements
            merged.replan_attempts += st.replan_attempts
            merged.replan_wins += st.replan_wins
            merged.withdrawn += st.withdrawn
            merged.completed += st.completed
            merged.stragglers += st.stragglers
            merged.decisions.extend(st.decisions)
            merged.rejected.extend(st.rejected)
            merged.demoted.extend(st.demoted)
            merged.replan_events.extend(st.replan_events)
            merged.failed.extend(st.failed)
            merged.corrections.extend(st.corrections)
            merged.retries.extend(st.retries)
            merged.outages.extend(st.outages)
            merged.speculations.extend(st.speculations)
            merged.checkpoints.extend(st.checkpoints)
        merged.rejected.extend(self.scale.fast_rejected)
        merged.demoted.extend(self.scale.fast_demoted)
        merged.decisions.sort(key=lambda d: (d.decided_at, d.task_id))
        return merged

    def committed_items(self) -> list:
        out: list = []
        for s in self._shards:
            out.extend(s.committed_items())
        return out

    def committed_item(self, task_id: int):
        for s in self._shards:
            it = s.committed_item(task_id)
            if it is not None:
                return it
        return None

    def true_duration(self, item) -> float:
        shard = self._owner_of(item.task.id)
        return self._shards[shard].true_duration(item)

    def next_wakeup(self) -> float | None:
        cands = [
            w for w in (s.next_wakeup() for s in self._shards)
            if w is not None
        ]
        for box in self._inbox:
            if box:
                cands.append(box[0][1] + self.config.max_wait_s)
        return min(cands) if cands else None

    @property
    def makespan(self) -> float:
        return max((s.makespan for s in self._shards), default=0.0)

    def combined_schedule(self) -> Schedule:
        if self._k != 1:
            raise ValueError(
                "a multi-shard service has one timeline per shard; use "
                "shard_schedules()"
            )
        return self._shards[0].combined_schedule()

    def shard_schedules(self) -> list[Schedule]:
        return [s.combined_schedule() for s in self._shards]

    def deadline_report(self) -> dict:
        """The inner services' reports merged with the fast-gate verdicts
        (gate-rejected tasks never reach a shard; inbox-expired deadlines
        are certain misses by construction — see ``_forward``)."""
        if self._k == 1 and not self.defer:
            return self._shards[0].deadline_report()
        reports = [s.deadline_report() for s in self._shards]
        tracked = sum(r["tracked"] for r in reports) + len(self.scale.expired)
        missed = sorted(
            {tid for r in reports for tid in r["missed"]}
            | set(self.scale.expired)
        )
        return {
            "tracked": tracked,
            "missed": missed,
            "miss_rate": len(missed) / tracked if tracked else 0.0,
            "rejected": sorted(
                {tid for r in reports for tid in r["rejected"]}
                | set(self.scale.fast_rejected)
            ),
            "demoted": sorted(
                {tid for r in reports for tid in r["demoted"]}
                | set(self.scale.fast_demoted)
            ),
            "failed": sorted({tid for r in reports for tid in r["failed"]}),
        }
