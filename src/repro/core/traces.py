"""Deterministic large-scale arrival traces for the serving benchmarks.

The paper's value proposition — MIG pays off under sustained multi-task
load — needs streams far longer than the hand-rolled benchmark loops in
``benchmarks/t_online.py``.  This module turns the :mod:`repro.core.synth`
generators into *bit-reproducible* arrival traces of 10^5–10^6 tasks:

* a trace is a **pure function of its** :class:`TraceSpec` — same
  ``(seed, mix, n)`` (and knobs) means byte-identical events on every
  run, in keeping with the repo's ``determinism`` contract (every draw
  comes from ``np.random.default_rng`` seeded from the spec; there is no
  wall clock, no global RNG, no iteration-order dependence);
* three arrival **mixes**: ``"poisson"`` (homogeneous rate),
  ``"bursty"`` (Poisson bursts of geometric size with tight intra-burst
  gaps) and ``"diurnal"`` (sinusoidal-rate inhomogeneous Poisson via
  thinning);
* **heavy-tailed durations**: each task's whole profile is scaled by a
  capped Pareto factor, preserving the paper recurrence's monotone
  molding shape while giving the stream the elephant-and-mice character
  real serving traces have;
* **streaming generation**: tasks are produced in fixed-size blocks
  (:data:`BLOCK`, an internal constant — *not* a knob, so it can never
  silently change the bytes) with per-block derived seeds, so a million-
  task trace never has to be materialised to know event ``i``.

``trace_digest`` folds a canonical byte encoding of every event into
SHA-256; two traces are the same trace iff their digests match, which is
what the determinism tests pin.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
import struct
from typing import Iterator, Sequence

import numpy as np

from repro.core.problem import Profile, Task
from repro.core.synth import generate_cluster_tasks, generate_tasks, workload

__all__ = [
    "BLOCK",
    "TraceEvent",
    "TraceSpec",
    "trace_digest",
    "trace_events",
]

#: generation block size.  Internal constant by design: per-block seeds
#: derive from (spec.seed, block index), so making this configurable
#: would make the trace a function of the block size too.
BLOCK = 2048

#: arrival-mix name -> seed-stream tag (keeps the arrival, duration and
#: deadline streams of one spec independent of each other)
_MIXES = {"poisson": 1, "bursty": 2, "diurnal": 3}
_STREAM_SCALE = 101
_STREAM_DEADLINE = 102
_STREAM_TASKS = 103


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Everything that determines a trace, and nothing else.

    ``seed``/``mix``/``n`` are the identity triple the ISSUE names; the
    remaining knobs have fixed defaults so the triple alone pins the
    bytes unless a benchmark explicitly asks for a different shape.
    """

    seed: int
    mix: str                         # "poisson" | "bursty" | "diurnal"
    n: int
    rate: float = 4.0                # mean arrivals per second
    scaling: str = "mixed"           # synth workload preset
    times: str = "wide"
    tail_alpha: float = 1.8          # Pareto shape of the duration scale
    tail_cap: float = 20.0           # cap on the Pareto factor
    deadline_slack: tuple[float, float] | None = None  # (lo, hi) x best time
    burst_mean: float = 12.0         # bursty: mean tasks per burst
    burst_spread_s: float = 0.05     # bursty: mean intra-burst gap
    diurnal_period_s: float = 600.0  # diurnal: one rate cycle
    diurnal_depth: float = 0.8       # diurnal: rate swings +-80%

    def __post_init__(self):
        if self.mix not in _MIXES:
            raise ValueError(
                f"TraceSpec.mix must be one of {sorted(_MIXES)}, "
                f"got {self.mix!r}"
            )
        if self.n <= 0:
            raise ValueError(f"TraceSpec.n must be positive, got {self.n}")
        if not self.rate > 0.0:
            raise ValueError(f"TraceSpec.rate must be positive, got {self.rate}")
        if not 0.0 <= self.diurnal_depth < 1.0:
            raise ValueError(
                f"TraceSpec.diurnal_depth must be in [0, 1), "
                f"got {self.diurnal_depth}"
            )


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One arrival of the stream: submit ``task`` at ``arrival`` with an
    optional absolute-time ``deadline``."""

    arrival: float
    task: Task
    deadline: float | None = None


def _rng(spec: TraceSpec, stream: int, block: int = 0) -> np.random.Generator:
    """Per-(spec, stream, block) generator: independent, reproducible."""
    return np.random.default_rng((spec.seed, stream, block))


# -- arrival processes -------------------------------------------------------

def _poisson_gaps(spec: TraceSpec, rng, count: int) -> np.ndarray:
    return rng.exponential(1.0 / spec.rate, size=count)


def _bursty_gaps(spec: TraceSpec, rng, count: int) -> np.ndarray:
    """Poisson bursts of geometric size: the long-run rate stays
    ``spec.rate`` (bursts arrive at rate/burst_mean), but arrivals
    cluster into tight groups separated by long quiet gaps."""
    gaps = np.empty(count)
    filled = 0
    while filled < count:
        size = int(rng.geometric(1.0 / spec.burst_mean))
        size = min(size, count - filled)
        # burst leader waits a full inter-burst gap; followers trickle in
        gaps[filled] = rng.exponential(spec.burst_mean / spec.rate)
        if size > 1:
            gaps[filled + 1:filled + size] = rng.exponential(
                spec.burst_spread_s, size=size - 1
            )
        filled += size
    return gaps


def _diurnal_arrivals(spec: TraceSpec, rng, start: float, count: int
                      ) -> np.ndarray:
    """Inhomogeneous Poisson by thinning: candidates at the peak rate,
    each kept with probability rate(t)/peak.  The candidate process and
    the acceptance draws both come from ``rng``, so the accepted subset
    is a pure function of the spec."""
    peak = spec.rate * (1.0 + spec.diurnal_depth)
    omega = 2.0 * math.pi / spec.diurnal_period_s
    out = np.empty(count)
    filled = 0
    t = start
    while filled < count:
        chunk = max(64, 2 * (count - filled))
        cand = t + np.cumsum(rng.exponential(1.0 / peak, size=chunk))
        accept = rng.random(chunk) * peak <= spec.rate * (
            1.0 + spec.diurnal_depth * np.sin(omega * cand)
        )
        kept = cand[accept]
        take = min(len(kept), count - filled)
        out[filled:filled + take] = kept[:take]
        filled += take
        t = float(cand[-1])
    return out


def _block_arrivals(spec: TraceSpec, block: int, start: float,
                    count: int) -> np.ndarray:
    rng = _rng(spec, _MIXES[spec.mix], block)
    if spec.mix == "poisson":
        return start + np.cumsum(_poisson_gaps(spec, rng, count))
    if spec.mix == "bursty":
        return start + np.cumsum(_bursty_gaps(spec, rng, count))
    return _diurnal_arrivals(spec, rng, start, count)


# -- task bodies -------------------------------------------------------------

def _scale_profile(task: Task, factor: float) -> Task:
    """Scale a task's whole profile by ``factor`` — monotone molding
    shape and cross-size ratios are preserved exactly."""
    if isinstance(task.times, Profile):
        times: object = Profile(
            {key: t * factor for key, t in task.times.items()}
        )
    else:
        times = {s: t * factor for s, t in task.times.items()}
    return dataclasses.replace(task, times=times)


def _block_tasks(spec: TraceSpec, pool, block: int, count: int,
                 id_offset: int) -> list[Task]:
    seed = int(_rng(spec, _STREAM_TASKS, block).integers(0, 2 ** 31))
    if hasattr(pool, "devices"):  # ClusterSpec: instance-type profiles
        tasks = generate_cluster_tasks(
            count, pool, spec.scaling, spec.times,
            seed=seed, id_offset=id_offset,
        )
    else:
        tasks = generate_tasks(
            count, pool, workload(spec.scaling, spec.times, pool),
            seed=seed, id_offset=id_offset,
        )
    rng = _rng(spec, _STREAM_SCALE, block)
    # capped Pareto(alpha) factors >= 1: mice stay mice, a few elephants
    factors = np.minimum(
        (1.0 - rng.random(count)) ** (-1.0 / spec.tail_alpha), spec.tail_cap
    )
    return [_scale_profile(t, float(f)) for t, f in zip(tasks, factors)]


def _best_time(task: Task) -> float:
    return min(task.times.values())


def trace_events(pool, spec: TraceSpec) -> Iterator[TraceEvent]:
    """Stream the trace lazily, one :class:`TraceEvent` at a time.

    ``pool`` is the DeviceSpec or ClusterSpec the tasks are generated
    for (profiles must name its sizes/kinds).  Generation is block-wise:
    event ``i`` only ever requires blocks ``0..i // BLOCK``, so a
    million-task trace streams in constant memory.
    """
    start = 0.0
    produced = 0
    block = 0
    while produced < spec.n:
        count = min(BLOCK, spec.n - produced)
        arrivals = _block_arrivals(spec, block, start, count)
        tasks = _block_tasks(spec, pool, block, count, id_offset=produced)
        if spec.deadline_slack is not None:
            lo, hi = spec.deadline_slack
            slack = _rng(spec, _STREAM_DEADLINE, block).uniform(
                lo, hi, size=count
            )
        else:
            slack = None
        for i in range(count):
            deadline = None
            if slack is not None:
                deadline = float(arrivals[i]) + float(slack[i]) * _best_time(
                    tasks[i]
                )
            yield TraceEvent(float(arrivals[i]), tasks[i], deadline)
        start = float(arrivals[-1])
        produced += count
        block += 1


# -- canonical digest --------------------------------------------------------

def _event_bytes(ev: TraceEvent) -> bytes:
    """Canonical encoding: arrival, id, deadline and the full profile in
    sorted key order — two events encode equal iff they are equal."""
    parts = [struct.pack(
        "<dqd", ev.arrival, ev.task.id,
        ev.deadline if ev.deadline is not None else math.nan,
    )]
    if isinstance(ev.task.times, Profile):
        entries = sorted(ev.task.times.items())
        for (kind, size), t in entries:
            parts.append(kind.encode())
            parts.append(struct.pack("<qd", size, t))
    else:
        for size, t in sorted(ev.task.times.items()):
            parts.append(struct.pack("<qd", size, t))
    return b"".join(parts)


def trace_digest(pool, spec: TraceSpec, limit: int | None = None) -> str:
    """SHA-256 over the canonical encoding of the first ``limit`` (default
    all ``spec.n``) events — the bit-reproducibility witness."""
    h = hashlib.sha256()
    for i, ev in enumerate(trace_events(pool, spec)):
        if limit is not None and i >= limit:
            break
        h.update(_event_bytes(ev))
    return h.hexdigest()
