"""Approximation-factor certificates (paper §5).

The List-Scheduling argument bounds phase 2's makespan by a combination of
the total task area and the longest task (the form required by Turek's
Theorem 1), which then yields a factor for the whole moldable problem:

* A30 (4 slices, full binary tree):  ω ≤ ¼·area + ¾·h_max  ⇒  factor 7/4.
* A100/H100: three-case analysis over the idle-slice patterns of the
  irregular tree  ⇒  factor 2.
* general full binary tree over s slices (our TPU pods): the A30 argument
  goes through verbatim (every node's ancestors cover all larger sizes, so
  no gaps before the critical task's start)  ⇒  ω ≤ (1/s)·area +
  ((s-1)/s)·h_max  ⇒  factor (2s-1)/s < 2; with g devices, (2gs-1)/(gs).

These are *upper bounds excluding reconfiguration cost* (paper §5).  The
functions below compute the certified factor for a spec and check a
schedule against its Theorem-1-style bound — both are exercised by the
property tests.
"""

from __future__ import annotations

from typing import Sequence

from repro.core.device_spec import DeviceSpec, InstanceNode
from repro.core.problem import Schedule, Task, area_lower_bound


def _is_full_binary(node: InstanceNode) -> bool:
    if not node.children:
        return node.size == 1
    if len(node.children) == 1:
        return False
    sizes_ok = sum(c.size for c in node.children) == node.size
    halves = all(c.size == node.size // 2 for c in node.children)
    return sizes_ok and halves and all(_is_full_binary(c) for c in node.children)


def approximation_factor(spec: DeviceSpec) -> float:
    """Certified moldable approximation factor for phase 2 on ``spec``."""
    s = spec.n_slices
    if all(_is_full_binary(r) for r in spec.roots):
        # paper §5.1 generalised: (2s-1)/s  (A30: s=4 -> 7/4; g A30s:
        # (8g-1)/(4g); TPU pod s=8 -> 15/8)
        return (2 * s - 1) / s
    if spec.name.startswith(("A100", "H100")) or (
        len(spec.roots) >= 1
        and all(r.size == 7 for r in spec.roots)
    ):
        # paper §5.2: max(7/6 + 5/6, 7/4, 7/5 + 3/5) = 2 per device; the
        # multi-device extension keeps the per-case area argument with
        # g*7 slices but the same gap patterns, still bounded by 2.
        return 2.0
    # conservative fallback: list scheduling with possible single-slice gaps
    return 2.0


def theorem1_rigid_bound(
    schedule: Schedule, tasks: Sequence[Task] | None = None
) -> float:
    """The Theorem-1-form bound on phase 2's *rigid* makespan for the sizes
    actually allotted (reconfigurations excluded), i.e.

        A30-like:  (1/s)·area + ((s-1)/s)·h_max
        A100/H100: max(area/6 + 5/6·h_max, area/4, area/5 + 3/5·h_max)

    Checking ``makespan_without_reconfig <= theorem1_rigid_bound`` certifies
    the §5 analysis on concrete instances.
    """
    spec = schedule.spec
    area = schedule.work_area()
    h_max = max((it.duration for it in schedule.items), default=0.0)
    if all(_is_full_binary(r) for r in spec.roots):
        s = spec.n_slices
        return area / s + (s - 1) / s * h_max
    if all(r.size == 7 for r in spec.roots):
        g = len(spec.roots)
        return max(
            area / (6 * g) + 5 / 6 * h_max,
            area / (4 * g),
            area / (5 * g) + 3 / 5 * h_max,
        )
    # generic list-scheduling fallback (always valid): area/1 ... trivial
    return area + h_max


def cluster_approximation_factor(cspec) -> float:
    """The §5 certificate that survives at heterogeneous-cluster level:
    the worst per-device factor of the pool.  Whatever the phase-0
    partitioner decides, each device's FAR schedule stays within its own
    certified factor of that device's optimum *for its sub-batch*; the
    partitioning step itself carries no Theorem-1-style certificate —
    the cluster-level anchor is instead the constructive guarantee that
    ``far-cluster`` never loses to the best single device
    (:mod:`repro.core.cluster`).  ``cspec`` is duck-typed: anything with
    ``.devices``."""
    return max(approximation_factor(d) for d in cspec.devices)


def certified_gap(result_makespan: float, tasks: Sequence[Task],
                  spec: DeviceSpec) -> float:
    """makespan / (factor · area-lower-bound): ≤ 1 certifies optimal-factor
    behaviour on this instance (only a sanity ceiling — the bound compares
    against ω*, which the area baseline under-estimates)."""
    return result_makespan / (
        approximation_factor(spec) * area_lower_bound(tasks, spec)
    )
