"""FAR — the paper's contribution: moldable task scheduling with dynamic
repartitioning for MIG-style reconfigurable accelerators."""

from repro.core.allocations import allocation_family, first_allocation
from repro.core.device_spec import (
    A30,
    A100,
    H100,
    SPECS,
    TPU_POD_256,
    TPU_SUPERPOD_512,
    DeviceSpec,
    InstanceNode,
    multi_gpu,
)
from repro.core.family_eval import (
    FamilyEvaluator,
    get_evaluator,
    register_evaluator,
)
from repro.core.far import FARResult, far_schedule, rho, schedule_batch
from repro.core.cluster import (
    ClusterMultiBatchScheduler,
    ClusterPlan,
    ClusterSchedule,
    ClusterSpec,
    cluster,
    partition_batch,
    validate_cluster_schedule,
)
from repro.core.multibatch import (
    ConcatResult,
    MultiBatchScheduler,
    Tail,
    concatenate,
    multibatch_baseline,
    tail_after,
)
from repro.core.online import OnlinePlacement, OnlineScheduler
from repro.core.policy import (
    PlanResult,
    SchedulerConfig,
    SchedulerPolicy,
    available_policies,
    get_policy,
    register_policy,
)
from repro.core.faults import (
    ExecutionDraw,
    FaultInjector,
    FaultRunReport,
    FaultSpec,
    ProfileCalibration,
    RetryPolicy,
    SpeculationPolicy,
    demote_shrink,
    execute_open_loop,
    run_with_faults,
)
from repro.core.service import (
    CheckpointEvent,
    CorrectionEvent,
    Decision,
    OutageEvent,
    ReplanEvent,
    RetryEvent,
    SchedulingService,
    ServiceStats,
    SpeculationEvent,
)
from repro.core.problem import (
    InfeasibleScheduleError,
    Profile,
    ProfileCoverageError,
    ReconfigEvent,
    Schedule,
    ScheduledTask,
    Task,
    area_lower_bound,
    bind_tasks,
    lower_bound,
    remainder_task,
    transfer_profile,
    validate_schedule,
)
from repro.core.refine import RefineStats, refine_assignment
from repro.core.sharded import (
    FastDecision,
    ScaleStats,
    ShardedSchedulingService,
)
from repro.core.traces import (
    TraceEvent,
    TraceSpec,
    trace_digest,
    trace_events,
)
from repro.core.repartition import (
    Assignment,
    LPTGroups,
    alive_at_end,
    list_schedule_allocation,
    list_schedule_groups,
    replay,
)
from repro.core.timing import ReplayEngine, TimingEngine, make_engine

__all__ = [
    "A30", "A100", "H100", "SPECS", "TPU_POD_256", "TPU_SUPERPOD_512",
    "DeviceSpec", "InstanceNode", "multi_gpu",
    "Task", "Profile", "bind_tasks", "remainder_task", "transfer_profile",
    "Schedule", "ScheduledTask",
    "ReconfigEvent", "InfeasibleScheduleError", "ProfileCoverageError",
    "validate_schedule",
    "area_lower_bound", "lower_bound",
    "ClusterSpec", "ClusterSchedule", "ClusterPlan", "cluster",
    "ClusterMultiBatchScheduler", "partition_batch",
    "validate_cluster_schedule",
    "allocation_family", "first_allocation",
    "Assignment", "list_schedule_allocation", "list_schedule_groups",
    "LPTGroups", "replay", "alive_at_end",
    "TimingEngine", "ReplayEngine", "make_engine",
    "RefineStats", "refine_assignment",
    "FARResult", "far_schedule", "schedule_batch", "rho",
    "FamilyEvaluator", "get_evaluator", "register_evaluator",
    "MultiBatchScheduler", "Tail", "ConcatResult", "concatenate",
    "multibatch_baseline", "tail_after",
    "OnlineScheduler", "OnlinePlacement",
    "SchedulerConfig", "SchedulerPolicy", "PlanResult",
    "register_policy", "get_policy", "available_policies",
    "SchedulingService", "ServiceStats", "Decision", "ReplanEvent",
    "CorrectionEvent", "RetryEvent", "OutageEvent",
    "SpeculationEvent", "CheckpointEvent",
    "RetryPolicy", "FaultSpec", "FaultInjector", "FaultRunReport",
    "ExecutionDraw", "demote_shrink", "run_with_faults",
    "execute_open_loop",
    "SpeculationPolicy", "ProfileCalibration",
    "ShardedSchedulingService", "ScaleStats", "FastDecision",
    "TraceSpec", "TraceEvent", "trace_events", "trace_digest",
]
