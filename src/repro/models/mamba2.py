"""Mamba2 (SSD) mixer + the zamba2 hybrid — the [hybrid] architecture
(arXiv:2411.15242).

The SSD (state-space dual) scan uses the chunked algorithm: within a chunk
the recurrence is materialised as a decay-masked quadratic form (MXU
friendly); across chunks a [B, H, N, P] state is carried by ``lax.scan``.
Decode is the O(1) recurrent update.  This chunked scan is also the
reference for the ``ssd_scan`` Pallas kernel.

zamba2 block layout: ``n_layers`` Mamba2 layers with one *shared*
transformer block (full attention + MLP, single parameter set) applied
every ``shared_attn_every`` layers — scanned as groups of
(``shared_attn_every`` mamba layers + shared block), the shared parameters
captured by closure so they are reused, not stacked.  Simplifications vs
the reference (DESIGN.md §9): the shared block consumes the hidden state
directly (no embedding concat), and per-invocation LoRA deltas are omitted.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ArchConfig
from repro.models.model import Model
from repro.parallel.sharding import logical

Params = Any
CHUNK = 256
HEAD_P = 64  # SSD head dim


# ---------------------------------------------------------------------------
# SSD chunked scan
# ---------------------------------------------------------------------------

def ssd_chunked(
    x: jax.Array,    # [B, S, H, P]
    dt: jax.Array,   # [B, S, H]   (post-softplus)
    a: jax.Array,    # [H]         (negative; A = -exp(a_log))
    bmat: jax.Array,  # [B, S, N]
    cmat: jax.Array,  # [B, S, N]
    chunk: int = CHUNK,
    state: jax.Array | None = None,  # [B, H, N, P]
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [B,S,H,P], final_state [B,H,N,P])."""
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    c = min(chunk, s)
    pad = (c - s % c) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    sp = s + pad
    nc = sp // c

    xr = x.reshape(b, nc, c, h, p)
    dtr = dt.reshape(b, nc, c, h).astype(jnp.float32)
    br = bmat.reshape(b, nc, c, n)
    cr = cmat.reshape(b, nc, c, n)
    ar = dtr * a.astype(jnp.float32)            # [B,NC,C,H], negative
    cum = jnp.cumsum(ar, axis=2)                # within-chunk cumulative
    atot = cum[:, :, -1]                        # [B,NC,H]

    tri = jnp.tril(jnp.ones((c, c), bool))

    def scan_chunk(carry, xs):
        st = carry                                    # [B,H,N,P] f32
        xc, dtc, bc, cc, cumc, atotc = xs
        # decay L[i,j] = exp(cum_i - cum_j) for j <= i
        dmat = cumc[:, :, None, :] - cumc[:, None, :, :]    # [B,Ci,Cj,H]
        dmat = jnp.where(tri[None, :, :, None], dmat, -jnp.inf)
        ldec = jnp.exp(dmat)
        scores = jnp.einsum("bin,bjn->bij", cc, bc,
                            preferred_element_type=jnp.float32)
        w = scores[..., None] * ldec * dtc[:, None, :, :]   # [B,Ci,Cj,H]
        y_intra = jnp.einsum("bijh,bjhp->bihp", w.astype(xc.dtype), xc)
        # inter-chunk: y += C_i · state * exp(cum_i)
        y_inter = jnp.einsum(
            "bin,bhnp->bihp", cc.astype(jnp.float32), st
        ) * jnp.exp(cumc)[..., None]
        y = y_intra.astype(jnp.float32) + y_inter
        # state update: st = st*exp(atot) + sum_j exp(atot-cum_j) dt_j B_j x_j
        g = jnp.exp(atotc[:, None, :] - cumc) * dtc          # [B,C,H]
        st = st * jnp.exp(atotc)[:, :, None, None] + jnp.einsum(
            "bjn,bjh,bjhp->bhnp", bc.astype(jnp.float32), g,
            xc.astype(jnp.float32),
        )
        return st, y

    if state is None:
        state = jnp.zeros((b, h, n, p), jnp.float32)
    xs = (
        xr.transpose(1, 0, 2, 3, 4), dtr.transpose(1, 0, 2, 3),
        br.transpose(1, 0, 2, 3), cr.transpose(1, 0, 2, 3),
        cum.transpose(1, 0, 2, 3), atot.transpose(1, 0, 2),
    )
    state, ys = jax.lax.scan(scan_chunk, state, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, sp, h, p)[:, :s]
    return y, state


def ssd_step(
    state: jax.Array,  # [B, H, N, P]
    x: jax.Array,      # [B, H, P]
    dt: jax.Array,     # [B, H]
    a: jax.Array,      # [H]
    bvec: jax.Array,   # [B, N]
    cvec: jax.Array,   # [B, N]
) -> tuple[jax.Array, jax.Array]:
    dt = dt.astype(jnp.float32)
    decay = jnp.exp(dt * a.astype(jnp.float32))          # [B,H]
    upd = jnp.einsum(
        "bn,bh,bhp->bhnp", bvec.astype(jnp.float32), dt, x.astype(jnp.float32)
    )
    state = state * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", cvec.astype(jnp.float32), state)
    return state, y


# ---------------------------------------------------------------------------
# Mamba2 layer
# ---------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array, cache: jax.Array | None = None):
    """Depthwise causal conv. x [B,S,D], w [K,D]. Returns (y, new_cache)
    where cache holds the last K-1 inputs."""
    k = w.shape[0]
    if cache is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    y = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(k)
    )
    new_cache = xp[:, -(k - 1):] if k > 1 else xp[:, :0]
    return jax.nn.silu(y), new_cache


def mamba_init(rng, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    din = cfg.d_inner
    h = din // HEAD_P
    n = cfg.ssm_state
    k = cfg.ssm_conv
    ks = jax.random.split(rng, 7)
    return {
        "ln": layers.rmsnorm_init(cfg),
        "in_x": layers._dense_init(ks[0], (d, din), d),
        "in_z": layers._dense_init(ks[1], (d, din), d),
        "in_b": layers._dense_init(ks[2], (d, n), d),
        "in_c": layers._dense_init(ks[3], (d, n), d),
        "in_dt": layers._dense_init(ks[4], (d, h), d),
        "conv_x": (jax.random.normal(ks[5], (k, din)) * 0.1).astype(layers.DTYPE),
        "dt_bias": jnp.zeros((h,), layers.DTYPE),
        "a_log": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), layers.DTYPE),
        "gn": layers.rmsnorm_init(cfg, din),
        "out": layers._dense_init(ks[6], (din, d), din),
    }


def mamba_specs(cfg: ArchConfig) -> Params:
    return {
        "ln": layers.rmsnorm_specs(cfg),
        "in_x": ("embed", "d_inner"),
        "in_z": ("embed", "d_inner"),
        "in_b": ("embed", None),
        "in_c": ("embed", None),
        "in_dt": ("embed", "ssm_heads"),
        "conv_x": (None, "d_inner"),
        "dt_bias": ("ssm_heads",),
        "a_log": ("ssm_heads",),
        "d_skip": ("ssm_heads",),
        "gn": {"scale": (None,)},
        "out": ("d_inner", "embed"),
    }


def _mamba_proj(p, cfg, xin):
    b, s, _ = xin.shape
    din = cfg.d_inner
    h = din // HEAD_P
    z = xin @ p["in_z"]
    xl = xin @ p["in_x"]
    bm = xin @ p["in_b"]
    cm = xin @ p["in_c"]
    dt = jax.nn.softplus(
        (xin @ p["in_dt"] + p["dt_bias"]).astype(jnp.float32)
    )
    return z, xl, bm, cm, dt


def mamba_apply(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    b, s, _ = x.shape
    din = cfg.d_inner
    h = din // HEAD_P
    xin = layers.rmsnorm_apply(p["ln"], x)
    z, xl, bm, cm, dt = _mamba_proj(p, cfg, xin)
    xc, _ = _causal_conv(xl, p["conv_x"])
    xh = xc.reshape(b, s, h, HEAD_P)
    xh = logical(xh, "batch", None, "act_ssm_heads", None)
    a = -jnp.exp(p["a_log"])
    y, _ = ssd_chunked(xh, dt, a, bm, cm)
    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    yflat = y.reshape(b, s, din).astype(x.dtype)
    yflat = layers.rmsnorm_apply(p["gn"], yflat * jax.nn.silu(z))
    return x + yflat @ p["out"]


def mamba_decode(p, cfg: ArchConfig, x, state):
    """state = {"ssd": [B,H,N,P], "conv": [B,K-1,din]}."""
    b = x.shape[0]
    din = cfg.d_inner
    h = din // HEAD_P
    xin = layers.rmsnorm_apply(p["ln"], x)
    z, xl, bm, cm, dt = _mamba_proj(p, cfg, xin)
    xc, conv_cache = _causal_conv(xl, p["conv_x"], cache=state["conv"])
    xh = xc.reshape(b, 1, h, HEAD_P)
    a = -jnp.exp(p["a_log"])
    ssd, y = ssd_step(
        state["ssd"], xh[:, 0], dt[:, 0], a, bm[:, 0], cm[:, 0]
    )
    y = y + xh[:, 0].astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, :, None]
    yflat = y.reshape(b, 1, din).astype(x.dtype)
    yflat = layers.rmsnorm_apply(p["gn"], yflat * jax.nn.silu(z))
    return x + yflat @ p["out"], {"ssd": ssd, "conv": conv_cache}


# ---------------------------------------------------------------------------
# zamba2 hybrid builder
# ---------------------------------------------------------------------------

def build(cfg: ArchConfig, impl: str = "xla", remat: bool = True) -> Model:
    every = cfg.shared_attn_every or 6
    assert cfg.n_layers % every == 0
    n_groups = cfg.n_layers // every
    din = cfg.d_inner
    h_ssm = din // HEAD_P
    kconv = cfg.ssm_conv

    def init(rng):
        k_emb, k_blocks, k_shared = jax.random.split(rng, 3)
        def one_group(key):
            return jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[mamba_init(k, cfg) for k in jax.random.split(key, every)],
            )
        blocks = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[one_group(k) for k in jax.random.split(k_blocks, n_groups)],
        )
        ks1, ks2, ks3 = jax.random.split(k_shared, 3)
        shared = {
            "ln1": layers.rmsnorm_init(cfg),
            "attn": layers.attention_init(ks1, cfg),
            "ln2": layers.rmsnorm_init(cfg),
            "mlp": layers.mlp_init(ks2, cfg),
        }
        return {
            "embed": layers.embedding_init(k_emb, cfg),
            "blocks": blocks,
            "shared": shared,
            "final_ln": layers.rmsnorm_init(cfg),
        }

    def _prepend(specs, extra=1):
        return jax.tree.map(
            lambda sp: (None,) * extra + sp,
            specs,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        )

    def param_specs():
        return {
            "embed": layers.embedding_specs(cfg),
            "blocks": _prepend(mamba_specs(cfg), 2),
            "shared": {
                "ln1": layers.rmsnorm_specs(cfg),
                "attn": layers.attention_specs(cfg),
                "ln2": layers.rmsnorm_specs(cfg),
                "mlp": layers.mlp_specs(cfg),
            },
            "final_ln": layers.rmsnorm_specs(cfg),
        }

    SHARED_WINDOW = 4096  # shared attn uses a sliding window so the hybrid
    # stays sub-quadratic for the long_500k cell (DESIGN.md §4)

    def _shared_apply(sp, x):
        h = layers.attention_apply(
            sp["attn"], cfg, layers.rmsnorm_apply(sp["ln1"], x),
            causal=True, window=SHARED_WINDOW, impl=impl,
        )
        x = x + h
        y = layers.mlp_apply(sp["mlp"], cfg,
                             layers.rmsnorm_apply(sp["ln2"], x))
        return x + y

    def make_group_fwd(shared):
        def group_fwd(x, gp):
            for i in range(every):
                mp = jax.tree.map(lambda a: a[i], gp)
                x = mamba_apply(mp, cfg, x)
            x = _shared_apply(shared, x)
            return logical(x, "batch", "seq", None)
        return group_fwd

    def trunk(params, x):
        group_fwd = make_group_fwd(params["shared"])
        body_fn = (
            jax.checkpoint(group_fwd,
                           policy=jax.checkpoint_policies.nothing_saveable)
            if remat else group_fwd
        )
        def body(carry, gp):
            return body_fn(carry, gp), None
        x, _ = jax.lax.scan(body, x, params["blocks"])
        return layers.rmsnorm_apply(params["final_ln"], x)

    def loss(params, batch):
        x = layers.embed_apply(params["embed"], cfg, batch["tokens"])
        x = logical(x, "batch", "seq", None)
        x = trunk(params, x)
        logits = layers.unembed_apply(params["embed"], cfg, x)
        return layers.softmax_xent(logits, batch["labels"])

    def init_cache(batch: int, length: int):
        w = layers.rolling_cache_len(SHARED_WINDOW, length)
        kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        return {
            "pos": jnp.zeros((), jnp.int32),
            "ssd": jnp.zeros(
                (n_groups, every, batch, h_ssm, cfg.ssm_state, HEAD_P),
                jnp.float32,
            ),
            "conv": jnp.zeros(
                (n_groups, every, batch, kconv - 1, din), layers.DTYPE
            ),
            "attn": {
                "k": jnp.zeros((n_groups, batch, w, kv, hd), layers.DTYPE),
                "v": jnp.zeros((n_groups, batch, w, kv, hd), layers.DTYPE),
            },
        }

    def cache_specs(batch: int, length: int):
        return {
            "pos": (),
            "ssd": (None, None, "batch", "ssm_heads", None, None),
            "conv": (None, None, "batch", None, "d_inner"),
            "attn": {
                "k": (None, "batch", None, "kv_heads", None),
                "v": (None, "batch", None, "kv_heads", None),
            },
        }

    def prefill(params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        w = layers.rolling_cache_len(SHARED_WINDOW, s)
        x = layers.embed_apply(params["embed"], cfg, tokens)
        shared = params["shared"]

        def body(carry, gp):
            x = carry
            ssds, convs = [], []
            for i in range(every):
                mp = jax.tree.map(lambda a: a[i], gp)
                xin = layers.rmsnorm_apply(mp["ln"], x)
                z, xl, bm, cm, dt = _mamba_proj(mp, cfg, xin)
                xc, _ = _causal_conv(xl, mp["conv_x"])
                conv_cache = xl[:, -(kconv - 1):]
                xh = xc.reshape(b, s, h_ssm, HEAD_P)
                a = -jnp.exp(mp["a_log"])
                y, st = ssd_chunked(xh, dt, a, bm, cm)
                y = y + xh.astype(jnp.float32) * mp["d_skip"].astype(
                    jnp.float32)[None, None, :, None]
                yflat = y.reshape(b, s, din).astype(x.dtype)
                yflat = layers.rmsnorm_apply(mp["gn"], yflat * jax.nn.silu(z))
                x = x + yflat @ mp["out"]
                ssds.append(st)
                convs.append(conv_cache)
            # shared attention with rolling window cache
            xin = layers.rmsnorm_apply(shared["ln1"], x)
            k, v = _shared_kv(shared, xin)
            k = layers.to_rolling(k, s, w)
            v = layers.to_rolling(v, s, w)
            x = _shared_apply(shared, x)
            return x, (jnp.stack(ssds), jnp.stack(convs), {"k": k, "v": v})

        x, (ssds, convs, attn_kv) = jax.lax.scan(body, x, params["blocks"])
        x = layers.rmsnorm_apply(params["final_ln"], x)
        logits = layers.unembed_apply(params["embed"], cfg, x[:, -1:])
        cache = {
            "pos": jnp.array(s, jnp.int32),
            "ssd": ssds,
            "conv": convs,
            "attn": attn_kv,
        }
        return logits, cache

    def _shared_kv(sp, xin):
        _, k, v = layers._qkv(sp["attn"], cfg, xin)
        positions = jnp.arange(xin.shape[1])[None, :]
        k = layers.rope(k, positions, cfg.rope_theta)
        return k, v

    def decode_step(params, cache, token):
        pos = cache["pos"]
        x = layers.embed_apply(params["embed"], cfg, token)
        shared = params["shared"]
        w = cache["attn"]["k"].shape[2]

        def body(carry, scanned):
            x = carry
            gp, ssd_g, conv_g, kv_g = scanned
            new_ssd, new_conv = [], []
            for i in range(every):
                mp = jax.tree.map(lambda a: a[i], gp)
                st = {"ssd": ssd_g[i], "conv": conv_g[i]}
                x, st2 = mamba_decode(mp, cfg, x, st)
                new_ssd.append(st2["ssd"])
                new_conv.append(st2["conv"])
            hx, kv2 = layers.attention_decode(
                shared["attn"], cfg, layers.rmsnorm_apply(shared["ln1"], x),
                kv_g, pos, window=SHARED_WINDOW, impl=impl,
            )
            x = x + hx
            y = layers.mlp_apply(shared["mlp"], cfg,
                                 layers.rmsnorm_apply(shared["ln2"], x))
            x = x + y
            return x, (jnp.stack(new_ssd), jnp.stack(new_conv), kv2)

        x, (ssds, convs, kvs) = jax.lax.scan(
            body, x,
            (params["blocks"], cache["ssd"], cache["conv"], cache["attn"]),
        )
        x = layers.rmsnorm_apply(params["final_ln"], x)
        logits = layers.unembed_apply(params["embed"], cfg, x)
        return logits, {
            "pos": pos + 1, "ssd": ssds, "conv": convs, "attn": kvs,
        }

    return Model(
        cfg=cfg,
        init=init,
        param_specs=param_specs,
        loss=loss,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        cache_specs=cache_specs,
    )
