"""Whisper-small backbone — the [audio] enc-dec architecture
(arXiv:2212.04356).

Per the assignment, the conv/mel frontend is a **stub**: ``input_specs``
supplies precomputed frame embeddings [B, T_enc, d_model] (T_enc = 1500).
The backbone is the real thing: a bidirectional encoder (self-attn + GELU
MLP, LayerNorm) and a causal decoder with cross-attention to the encoder
output.  Whisper uses absolute sinusoidal (encoder) / learned (decoder)
positions and no RoPE.

Decode shapes use the decoder self-attention KV cache; cross-attention K/V
are computed once at prefill.  ``long_500k`` is skipped for this arch
(DESIGN.md §4).  Deviation notes (§9): K projection carries a bias like
Q/V (whisper omits it); decoder positions are sinusoidal too, sized to the
synthetic 32k cells (real whisper caps at 448).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.config import ArchConfig
from repro.models.model import Model
from repro.parallel.sharding import logical

Params = Any


def sinusoid(positions: jax.Array, d: int) -> jax.Array:
    """Standard sinusoidal embeddings [..., d]."""
    half = d // 2
    freqs = jnp.exp(
        -np.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1)
    )
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_layer_init(rng, cfg):
    ks = jax.random.split(rng, 2)
    return {
        "ln1": layers.layernorm_init(cfg),
        "attn": layers.attention_init(ks[0], cfg),
        "ln2": layers.layernorm_init(cfg),
        "mlp": layers.mlp2_init(ks[1], cfg),
    }


def _enc_layer_specs(cfg):
    return {
        "ln1": layers.layernorm_specs(cfg),
        "attn": layers.attention_specs(cfg),
        "ln2": layers.layernorm_specs(cfg),
        "mlp": layers.mlp2_specs(cfg),
    }


def _dec_layer_init(rng, cfg):
    ks = jax.random.split(rng, 3)
    return {
        "ln1": layers.layernorm_init(cfg),
        "self_attn": layers.attention_init(ks[0], cfg),
        "ln_x": layers.layernorm_init(cfg),
        "cross_attn": layers.attention_init(ks[1], cfg),
        "ln2": layers.layernorm_init(cfg),
        "mlp": layers.mlp2_init(ks[2], cfg),
    }


def _dec_layer_specs(cfg):
    return {
        "ln1": layers.layernorm_specs(cfg),
        "self_attn": layers.attention_specs(cfg),
        "ln_x": layers.layernorm_specs(cfg),
        "cross_attn": layers.attention_specs(cfg),
        "ln2": layers.layernorm_specs(cfg),
        "mlp": layers.mlp2_specs(cfg),
    }


def build(cfg: ArchConfig, impl: str = "xla", remat: bool = True) -> Model:
    n_enc, n_dec = cfg.encoder_layers, cfg.n_layers

    def init(rng):
        k_emb, k_enc, k_dec, _ = jax.random.split(rng, 4)
        enc = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[_enc_layer_init(k, cfg) for k in jax.random.split(k_enc, n_enc)],
        )
        dec = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[_dec_layer_init(k, cfg) for k in jax.random.split(k_dec, n_dec)],
        )
        return {
            "embed": layers.embedding_init(k_emb, cfg),
            "enc": enc,
            "enc_ln": layers.layernorm_init(cfg),
            "dec": dec,
            "dec_ln": layers.layernorm_init(cfg),
        }

    def _prepend(specs):
        return jax.tree.map(
            lambda sp: (None,) + sp,
            specs,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        )

    def param_specs():
        return {
            "embed": layers.embedding_specs(cfg),
            "enc": _prepend(_enc_layer_specs(cfg)),
            "enc_ln": layers.layernorm_specs(cfg),
            "dec": _prepend(_dec_layer_specs(cfg)),
            "dec_ln": layers.layernorm_specs(cfg),
        }

    # ---- encoder -------------------------------------------------------------
    def encode(params, frames):
        b, t, _ = frames.shape
        x = frames.astype(layers.DTYPE) + sinusoid(
            jnp.arange(t)[None, :], cfg.d_model
        ).astype(layers.DTYPE)
        x = logical(x, "batch", "seq", None)

        def one(x, lp):
            h = layers.attention_apply(
                lp["attn"], cfg, layers.layernorm_apply(lp["ln1"], x),
                causal=False, use_rope=False, impl=impl,
            )
            x = x + h
            y = layers.mlp2_apply(lp["mlp"],
                                  layers.layernorm_apply(lp["ln2"], x))
            return x + y

        body = (
            jax.checkpoint(one, policy=jax.checkpoint_policies.nothing_saveable)
            if remat else one
        )
        x, _ = jax.lax.scan(lambda c, lp: (body(c, lp), None), x, params["enc"])
        return layers.layernorm_apply(params["enc_ln"], x)

    # ---- decoder trunk (teacher forcing) --------------------------------------
    def _dec_layer(lp, x, enc_out, *, causal=True):
        h = layers.attention_apply(
            lp["self_attn"], cfg, layers.layernorm_apply(lp["ln1"], x),
            causal=causal, use_rope=False, impl=impl,
        )
        x = x + h
        kv = layers.cross_attention_kv(lp["cross_attn"], cfg, enc_out)
        h = layers.cross_attention_apply(
            lp["cross_attn"], cfg, layers.layernorm_apply(lp["ln_x"], x), kv
        )
        x = x + h
        y = layers.mlp2_apply(lp["mlp"], layers.layernorm_apply(lp["ln2"], x))
        return x + y

    def decode_trunk(params, tokens, enc_out):
        b, s = tokens.shape
        x = layers.embed_apply(params["embed"], cfg, tokens)
        x = x + sinusoid(jnp.arange(s)[None, :], cfg.d_model).astype(x.dtype)
        x = logical(x, "batch", "seq", None)

        def one(x, lp):
            return _dec_layer(lp, x, enc_out)

        body = (
            jax.checkpoint(one, policy=jax.checkpoint_policies.nothing_saveable)
            if remat else one
        )
        x, _ = jax.lax.scan(lambda c, lp: (body(c, lp), None), x, params["dec"])
        return layers.layernorm_apply(params["dec_ln"], x)

    def loss(params, batch):
        enc_out = encode(params, batch["frames"])
        x = decode_trunk(params, batch["tokens"], enc_out)
        logits = layers.unembed_apply(params["embed"], cfg, x)
        return layers.softmax_xent(logits, batch["labels"])

    # ---- caches ---------------------------------------------------------------
    def init_cache(batch: int, length: int):
        kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        glob = length + layers.DECODE_MARGIN
        t_enc = cfg.encoder_frames
        return {
            "pos": jnp.zeros((), jnp.int32),
            "self": {
                "k": jnp.zeros((n_dec, batch, glob, kv, hd), layers.DTYPE),
                "v": jnp.zeros((n_dec, batch, glob, kv, hd), layers.DTYPE),
            },
            "cross": {
                "k": jnp.zeros((n_dec, batch, t_enc, kv, hd), layers.DTYPE),
                "v": jnp.zeros((n_dec, batch, t_enc, kv, hd), layers.DTYPE),
            },
        }

    def cache_specs(batch: int, length: int):
        selfspec = {
            "k": (None, "batch", "kv_len", "kv_heads", None),
            "v": (None, "batch", "kv_len", "kv_heads", None),
        }
        crossspec = {  # encoder length 1500 does not divide the axis
            "k": (None, "batch", None, "kv_heads", None),
            "v": (None, "batch", None, "kv_heads", None),
        }
        return {"pos": (), "self": dict(selfspec), "cross": dict(crossspec)}

    # ---- prefill ----------------------------------------------------------------
    def prefill(params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        enc_out = encode(params, batch["frames"])
        glob = s + layers.DECODE_MARGIN

        x = layers.embed_apply(params["embed"], cfg, tokens)
        x = x + sinusoid(jnp.arange(s)[None, :], cfg.d_model).astype(x.dtype)

        def body(carry, lp):
            x = carry
            xin = layers.layernorm_apply(lp["ln1"], x)
            _, k, v = layers._qkv(lp["self_attn"], cfg, xin)
            pad = lambda a: jnp.pad(
                a, ((0, 0), (0, glob - s), (0, 0), (0, 0))
            )
            ckv = layers.cross_attention_kv(lp["cross_attn"], cfg, enc_out)
            x = _dec_layer(lp, x, enc_out)
            return x, {"self": {"k": pad(k), "v": pad(v)},
                       "cross": {"k": ckv[0], "v": ckv[1]}}

        x, kvs = jax.lax.scan(body, x, params["dec"])
        x = layers.layernorm_apply(params["dec_ln"], x)
        logits = layers.unembed_apply(params["embed"], cfg, x[:, -1:])
        cache = {
            "pos": jnp.array(s, jnp.int32),
            "self": kvs["self"],
            "cross": kvs["cross"],
        }
        return logits, cache

    # ---- decode -------------------------------------------------------------------
    def decode_step(params, cache, token):
        pos = cache["pos"]
        b = token.shape[0]
        x = layers.embed_apply(params["embed"], cfg, token)
        x = x + sinusoid(
            jnp.full((b, 1), pos), cfg.d_model
        ).astype(x.dtype)

        def body(carry, scanned):
            x = carry
            lp, sc, cc = scanned
            xin = layers.layernorm_apply(lp["ln1"], x)
            h, sc2 = layers.attention_decode(
                lp["self_attn"], cfg, xin, sc, pos, use_rope=False, impl=impl
            )
            x = x + h
            h = layers.cross_attention_apply(
                lp["cross_attn"], cfg,
                layers.layernorm_apply(lp["ln_x"], x), (cc["k"], cc["v"]),
            )
            x = x + h
            y = layers.mlp2_apply(lp["mlp"],
                                  layers.layernorm_apply(lp["ln2"], x))
            return x + y, sc2

        x, new_self = jax.lax.scan(
            body, x, (params["dec"], cache["self"], cache["cross"])
        )
        x = layers.layernorm_apply(params["dec_ln"], x)
        logits = layers.unembed_apply(params["embed"], cfg, x)
        return logits, {
            "pos": pos + 1, "self": new_self, "cross": cache["cross"],
        }

    return Model(
        cfg=cfg,
        init=init,
        param_specs=param_specs,
        loss=loss,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        cache_specs=cache_specs,
    )
