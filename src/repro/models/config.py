"""Architecture configuration and input-shape cells.

Every assigned architecture is an :class:`ArchConfig`; the four assigned
input shapes are :class:`ShapeConfig` instances.  ``input_specs`` yields
``jax.ShapeDtypeStruct`` stand-ins for every model input of a given
(arch × shape) cell — weak-type-correct, shardable, no device allocation —
which is what the multi-pod dry-run lowers against.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

import jax
import jax.numpy as jnp


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture (exact public config; see src/repro/configs/)."""

    name: str
    family: str                     # dense | moe | vlm | ssm | hybrid | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    expert_pad: int = 0      # dead expert slots so EP divides the mesh axis
    # --- attention details ---
    qkv_bias: bool = False          # qwen-style QKV bias
    sliding_window: int = 0         # window for local layers (0 = none)
    local_global: int = 0           # gemma3: N local layers per 1 global
    logit_softcap: float = 0.0
    # --- activation / norms ---
    activation: str = "swiglu"      # swiglu | geglu
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    # --- ssm / hybrid ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    shared_attn_every: int = 0      # zamba2: shared attn block period
    slstm_every: int = 0            # xlstm: sLSTM block period (rest mLSTM)
    # --- encoder-decoder (audio) ---
    encoder_layers: int = 0
    encoder_frames: int = 1500      # stub frontend sequence length
    # --- numerics ---
    dtype: str = "bfloat16"
    # notes recorded in DESIGN.md (e.g. verification tier)
    source: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def n_experts_padded(self) -> int:
        return self.n_experts + self.expert_pad

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    def padded_vocab(self, multiple: int = 256) -> int:
        """Vocab padded so TP shards evenly (whisper's 51865 -> 51968)."""
        return _round_up(self.vocab_size, multiple)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    def param_count(self) -> int:
        """Analytic parameter count (used by the cost model & roofline)."""
        d, v = self.d_model, self.padded_vocab()
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        qkv = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
        out = (self.n_heads * hd) * d
        attn = qkv + out
        dense_mlp = 3 * d * self.d_ff if self.d_ff else 0
        per_layer = attn + dense_mlp
        if self.is_moe:
            expert = 3 * d * self.expert_d_ff
            moe = (self.n_experts + self.n_shared_experts) * expert + \
                d * self.n_experts  # router
            per_layer = attn + moe
        if self.family in ("ssm", "hybrid"):
            din = self.d_inner
            mamba = (
                d * 2 * din                 # in_proj (x, z)
                + din * self.ssm_conv       # depthwise conv
                + din * 2 * self.ssm_state  # B, C projections (approx)
                + din                       # dt
                + din * d                   # out proj
            )
            if self.family == "ssm":
                per_layer = mamba if self.d_ff == 0 else mamba + dense_mlp
            else:
                # hybrid: mamba-only backbone layers; the shared
                # attention+MLP transformer block is counted once below
                per_layer = mamba
        total = emb + self.n_layers * per_layer
        if self.shared_attn_every:
            total += attn + dense_mlp  # one shared transformer block
        if self.is_encoder_decoder:
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            total += self.encoder_layers * (attn + dense_mlp)
            total += self.n_layers * attn  # cross-attention
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top-k + shared)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        expert = 3 * d * self.expert_d_ff
        active_moe = (self.top_k + self.n_shared_experts) * expert
        full_moe = (self.n_experts + self.n_shared_experts) * expert
        return self.param_count() - self.n_layers * (full_moe - active_moe)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES: Mapping[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch × shape) cell runs, with the reason when skipped.

    ``long_500k`` needs sub-quadratic attention: it runs for SSM / hybrid /
    sliding-window archs and is skipped for pure full-attention ones
    (DESIGN.md §4 lists the cells).
    """
    if shape.name == "long_500k":
        subquadratic = (
            cfg.family in ("ssm", "hybrid") or cfg.local_global > 0
        )
        if not subquadratic:
            return False, "pure full-attention arch: 500k KV infeasible"
        if cfg.is_encoder_decoder:
            return False, "enc-dec decode beyond source length is undefined"
    return True, ""


def input_specs(
    cfg: ArchConfig, shape: ShapeConfig
) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every input of this cell.

    train:   tokens + labels (the data pipeline emits both)
    prefill: tokens
    decode:  one new token per sequence (the cache itself is threaded by the
             step function and derived separately via ``jax.eval_shape``).

    ``[audio]`` uses the stub frontend: precomputed encoder frames.
    """
    b, s = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if cfg.is_encoder_decoder:
        frames = jax.ShapeDtypeStruct(
            (b, cfg.encoder_frames, cfg.d_model), jnp.bfloat16
        )
        if shape.kind == "train":
            return {
                "frames": frames,
                "tokens": jax.ShapeDtypeStruct((b, s), tok),
                "labels": jax.ShapeDtypeStruct((b, s), tok),
            }
        if shape.kind == "prefill":
            return {
                "frames": frames,
                "tokens": jax.ShapeDtypeStruct((b, s), tok),
            }
        return {"token": jax.ShapeDtypeStruct((b, 1), tok)}
    if shape.kind == "train":
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), tok),
            "labels": jax.ShapeDtypeStruct((b, s), tok),
        }
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((b, s), tok)}
    return {"token": jax.ShapeDtypeStruct((b, 1), tok)}
