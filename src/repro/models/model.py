"""The Model protocol: a uniform functional interface over all families.

A :class:`Model` bundles pure functions (init / loss / prefill /
decode_step) plus the logical sharding specs for parameters and caches.
``build_model`` dispatches on the architecture family.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax

from repro.models.config import ArchConfig

Params = Any
Cache = Any
Batch = dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[[jax.Array], Params]
    param_specs: Callable[[], Params]          # logical-axis tuples
    loss: Callable[[Params, Batch], jax.Array]
    prefill: Callable[[Params, Batch], tuple[jax.Array, Cache]]
    decode_step: Callable[[Params, Cache, jax.Array], tuple[jax.Array, Cache]]
    init_cache: Callable[[int, int], Cache]    # (batch, length) -> cache
    cache_specs: Callable[[int, int], Cache]   # logical-axis tuples

    def param_shapes(self, rng=None) -> Params:
        return jax.eval_shape(self.init, jax.random.key(0))

    def cache_shapes(self, batch: int, length: int) -> Cache:
        return jax.eval_shape(lambda: self.init_cache(batch, length))


def build_model(cfg: ArchConfig, impl: str = "xla", remat: bool = True) -> Model:
    """impl: "xla" (lowers everywhere; used by the dry-run) or "pallas"
    (TPU kernels for attention/scan hot spots)."""
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models import transformer

        return transformer.build(cfg, impl=impl, remat=remat)
    if cfg.family == "ssm":
        from repro.models import xlstm

        return xlstm.build(cfg, impl=impl, remat=remat)
    if cfg.family == "hybrid":
        from repro.models import mamba2

        return mamba2.build(cfg, impl=impl, remat=remat)
    if cfg.family == "audio":
        from repro.models import whisper

        return whisper.build(cfg, impl=impl, remat=remat)
    raise ValueError(f"unknown family {cfg.family!r}")
