"""Core neural layers (pure JAX, functional, logically-sharded).

Every layer follows the same pattern:

  * ``<layer>_init(rng, cfg, ...) -> params``  (pytree of jnp arrays)
  * ``<layer>_specs(cfg, ...) -> pytree of logical-axis tuples`` matching the
    param pytree leaf-for-leaf (resolved to NamedShardings by
    ``repro.parallel.sharding``)
  * ``<layer>_apply(params, x, ...) -> y``

Computation is bf16 with fp32 softmax/norm/loss accumulation.  Activation
sharding uses :func:`repro.parallel.sharding.logical`, a no-op outside a
mesh context (single-device smoke tests).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.parallel.sharding import logical

Params = Any
DTYPE = jnp.bfloat16

NEG_INF = -1e9  # additive mask value (safe in bf16)


def _dense_init(rng, shape, scale_dim) -> jax.Array:
    scale = 1.0 / np.sqrt(scale_dim)
    return (jax.random.normal(rng, shape, jnp.float32) * scale).astype(DTYPE)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(cfg: ArchConfig, dim: int | None = None) -> Params:
    return {"scale": jnp.ones((dim or cfg.d_model,), DTYPE)}


def rmsnorm_specs(cfg: ArchConfig) -> Params:
    return {"scale": (None,)}


def rmsnorm_apply(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"].astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# LayerNorm (whisper-style, with bias)
# ---------------------------------------------------------------------------

def layernorm_init(cfg: ArchConfig, dim: int | None = None) -> Params:
    d = dim or cfg.d_model
    return {"scale": jnp.ones((d,), DTYPE), "bias": jnp.zeros((d,), DTYPE)}


def layernorm_specs(cfg: ArchConfig) -> Params:
    return {"scale": (None,), "bias": (None,)}


def layernorm_apply(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Plain 2-matrix MLP (whisper-style GELU)
# ---------------------------------------------------------------------------

def mlp2_init(rng, cfg: ArchConfig, d_ff: int | None = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(rng, 2)
    return {
        "wi": _dense_init(ks[0], (d, f), d),
        "bi": jnp.zeros((f,), DTYPE),
        "wo": _dense_init(ks[1], (f, d), f),
        "bo": jnp.zeros((d,), DTYPE),
    }


def mlp2_specs(cfg: ArchConfig) -> Params:
    return {"wi": ("embed", "ff"), "bi": ("ff",),
            "wo": ("ff", "embed"), "bo": (None,)}


def mlp2_apply(params: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ params["wi"] + params["bi"])
    h = logical(h, "batch", None, "act_ff")
    return h @ params["wo"] + params["bo"]


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, hd]; positions: broadcastable to [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [.., S, half]
    cos = jnp.cos(angles)[..., None, :]  # [.., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Grouped-query attention (full / causal / sliding window; KV-cache decode)
# ---------------------------------------------------------------------------

def attention_init(rng, cfg: ArchConfig) -> Params:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(rng, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h * hd), d),
        "wk": _dense_init(ks[1], (d, kv * hd), d),
        "wv": _dense_init(ks[2], (d, kv * hd), d),
        "wo": _dense_init(ks[3], (h * hd, d), h * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), DTYPE)
        p["bk"] = jnp.zeros((kv * hd,), DTYPE)
        p["bv"] = jnp.zeros((kv * hd,), DTYPE)
    return p


def attention_specs(cfg: ArchConfig) -> Params:
    p = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = ("heads",)
        p["bk"] = ("kv_heads",)
        p["bv"] = ("kv_heads",)
    return p


def _qkv(params: Params, cfg: ArchConfig, x: jax.Array):
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    b, s = x.shape[0], x.shape[1]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    return q, k, v


def _attn_weights(q, k, cfg: ArchConfig):
    """[B,Sq,H,hd] x [B,Skv,KV,hd] -> [B,KV,G,Sq,Skv] logits in f32."""
    h, kv = cfg.n_heads, cfg.n_kv_heads
    g = h // kv
    b, sq, _, hd = q.shape
    qg = q.reshape(b, sq, kv, g, hd)
    logits = jnp.einsum(
        "bqkgh,bskh->bkgqs", qg, k, preferred_element_type=jnp.float32
    )
    logits = logits / np.sqrt(cfg.resolved_head_dim)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


def _attn_out(weights, v, cfg: ArchConfig):
    b, kv, g, sq, _ = weights.shape
    out = jnp.einsum("bkgqs,bskh->bqkgh", weights.astype(v.dtype), v)
    return out.reshape(b, sq, kv * g * v.shape[-1])


def attention_mask(
    sq: int,
    skv: int,
    causal: bool,
    window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    """Additive mask [Sq, Skv] (0 or NEG_INF)."""
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(skv)
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window:
        ok &= kpos[None, :] > qpos[:, None] - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


# sequences past this length use query-chunked attention on the XLA path
# (bounds the materialised score tile exactly like the Pallas kernel does)
QCHUNK_THRESHOLD = 8192
QCHUNK = 1024


def _chunked_attention(q, k, v, cfg: ArchConfig, causal: bool, window: int):
    """Scan over query chunks; scores tile is [.., QCHUNK, Skv]."""
    b, s, h, hd = q.shape
    c = QCHUNK
    nq = s // c
    qc = q.reshape(b, nq, c, h, hd).transpose(1, 0, 2, 3, 4)

    def body(_, args):
        qi, idx = args
        qi = logical(qi, "batch", "q_seq", "act_heads", None)  # H5
        logits = _attn_weights(qi, k, cfg)              # [B,KV,G,c,Skv]
        mask = attention_mask(c, s, causal, window, q_offset=idx * c)
        logits = logits + mask[None, None, None]
        weights = jax.nn.softmax(logits, axis=-1)
        return None, _attn_out(weights, v, cfg)          # [B,c,H*hd]

    _, outs = jax.lax.scan(body, None, (qc, jnp.arange(nq)))
    return outs.transpose(1, 0, 2, 3).reshape(b, s, -1)


def attention_apply(
    params: Params,
    cfg: ArchConfig,
    x: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    positions: jax.Array | None = None,
    use_rope: bool = True,
    impl: str = "xla",
) -> jax.Array:
    """Self-attention over full sequences (train / prefill)."""
    b, s, _ = x.shape
    q, k, v = _qkv(params, cfg, x)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = logical(q, "batch", "q_seq", "act_heads", None)
    k = logical(k, "batch", None, "kv_heads", None)
    v = logical(v, "batch", None, "kv_heads", None)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(
            q, k, v, causal=causal, window=window,
            softcap=cfg.logit_softcap,
        )
        out = out.reshape(b, s, -1)
    elif s > QCHUNK_THRESHOLD and s % QCHUNK == 0:
        out = _chunked_attention(q, k, v, cfg, causal, window)
    else:
        logits = _attn_weights(q, k, cfg)
        mask = attention_mask(s, s, causal, window)
        logits = logits + mask[None, None, None]
        weights = jax.nn.softmax(logits, axis=-1)
        out = _attn_out(weights, v, cfg)
    out = logical(out, "batch", None, "act_heads")
    return row_parallel(out, params["wo"])


def row_parallel(x: jax.Array, w: jax.Array) -> jax.Array:
    """Row-parallel projection: the contraction dim is model-sharded, so
    the partial sums cross the mesh.  Forcing a bf16 accumulator makes the
    all-reduce/reduce-scatter move 2-byte words instead of the f32
    accumulator XLA would otherwise reduce (§Perf H2a); each shard's local
    dot still accumulates in f32 on the MXU."""
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=x.dtype,
    )


def cross_attention_apply(
    params: Params,
    cfg: ArchConfig,
    x: jax.Array,
    kv_cache: tuple[jax.Array, jax.Array],
) -> jax.Array:
    """Cross-attention against precomputed encoder K/V (whisper decoder)."""
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    b, s, _ = x.shape
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    if cfg.qkv_bias:
        q = q + params["bq"].reshape(h, hd)
    k, v = kv_cache
    logits = _attn_weights(q, k, cfg)
    weights = jax.nn.softmax(logits, axis=-1)
    out = _attn_out(weights, v, cfg)
    return out @ params["wo"]


def cross_attention_kv(params: Params, cfg: ArchConfig, enc: jax.Array):
    kvh, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    b, s, _ = enc.shape
    k = (enc @ params["wk"]).reshape(b, s, kvh, hd)
    v = (enc @ params["wv"]).reshape(b, s, kvh, hd)
    if cfg.qkv_bias:
        k = k + params["bk"].reshape(kvh, hd)
        v = v + params["bv"].reshape(kvh, hd)
    return k, v


# --- KV-cache decode --------------------------------------------------------

def kv_cache_init(
    cfg: ArchConfig, batch: int, length: int, n_layers: int
) -> Params:
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (n_layers, batch, length, kv, hd)
    return {
        "k": jnp.zeros(shape, DTYPE),
        "v": jnp.zeros(shape, DTYPE),
        "pos": jnp.zeros((), jnp.int32),
    }


def kv_cache_specs(cfg: ArchConfig) -> Params:
    return {
        "k": (None, "batch", None, "kv_heads", None),
        "v": (None, "batch", None, "kv_heads", None),
        "pos": (),
    }


def attention_decode(
    params: Params,
    cfg: ArchConfig,
    x: jax.Array,                 # [B, 1, D]
    layer_cache: dict,            # {"k","v": [B, L, KV, hd]}
    pos: jax.Array,               # scalar int32: index of the new token
    *,
    window: int = 0,
    use_rope: bool = True,
    impl: str = "xla",
) -> tuple[jax.Array, dict]:
    """One decode step against a (possibly rolling) cache."""
    b = x.shape[0]
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q, k, v = _qkv(params, cfg, x)
    if use_rope:
        posb = jnp.full((b, 1), pos)
        q = rope(q, posb, cfg.rope_theta)
        k = rope(k, posb, cfg.rope_theta)
    length = layer_cache["k"].shape[1]
    # window > 0 -> rolling cache of size `length` (== min(window, alloc))
    slot = pos % jnp.int32(length) if window > 0 else pos
    ck = jax.lax.dynamic_update_slice(
        layer_cache["k"], k, (0, slot, 0, 0)
    )
    cv = jax.lax.dynamic_update_slice(
        layer_cache["v"], v, (0, slot, 0, 0)
    )
    # validity of cache entries
    idx = jnp.arange(length)
    if window > 0:
        # entry at slot j holds absolute position p - ((p - j) mod L)
        abs_pos = pos - (pos - idx) % length
        valid = (abs_pos >= 0) & (abs_pos > pos - window)
    else:
        valid = idx <= pos
    if impl == "pallas":
        from repro.kernels.decode_attention import ops as da_ops

        out = da_ops.decode_attention(
            q, ck, cv, valid, softcap=cfg.logit_softcap,
            scale=1.0 / np.sqrt(hd),
        )
    else:
        logits = _attn_weights(q, ck, cfg)  # [B,KV,G,1,L]
        mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
        logits = logits + mask[None, None, None, None, :]
        weights = jax.nn.softmax(logits, axis=-1)
        out = _attn_out(weights, cv, cfg)
    out = out @ params["wo"]
    return out, {"k": ck, "v": cv}


DECODE_MARGIN = 32  # headroom so decode steps never write past buffers


def rolling_cache_len(window: int, length: int) -> int:
    """Slot count for a sliding-window cache seeded with ``length`` tokens
    and able to absorb DECODE_MARGIN more without wrongly evicting entries
    still inside the window."""
    return min(window, length + DECODE_MARGIN)


def to_rolling(k: jax.Array, s: int, slots: int) -> jax.Array:
    """Lay out prefill K/V [B, s, ...] into a rolling buffer of ``slots``
    entries such that index == absolute position %% slots."""
    if s >= slots:
        return jnp.roll(k[:, -slots:], s % slots, axis=1)
    pad = [(0, 0), (0, slots - s)] + [(0, 0)] * (k.ndim - 2)
    return jnp.pad(k, pad)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_init(rng, cfg: ArchConfig, d_ff: int | None = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    return {
        "wi": _dense_init(ks[0], (d, f), d),
        "wg": _dense_init(ks[1], (d, f), d),
        "wo": _dense_init(ks[2], (f, d), f),
    }


def mlp_specs(cfg: ArchConfig, expert: bool = False) -> Params:
    ff = "expert_ff" if expert else "ff"
    return {"wi": ("embed", ff), "wg": ("embed", ff), "wo": (ff, "embed")}


def mlp_apply(params: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    act = jax.nn.gelu if cfg.activation == "geglu" else jax.nn.silu
    h = act(x @ params["wg"]) * (x @ params["wi"])
    h = logical(h, "batch", None, "act_ff")
    return row_parallel(h, params["wo"])


# ---------------------------------------------------------------------------
# Embedding / unembedding / loss
# ---------------------------------------------------------------------------

def embedding_init(rng, cfg: ArchConfig) -> Params:
    v, d = cfg.padded_vocab(), cfg.d_model
    ks = jax.random.split(rng, 2)
    p = {"table": _dense_init(ks[0], (v, d), d)}
    if not cfg.tie_embeddings:
        p["unembed"] = _dense_init(ks[1], (d, v), d)
    return p


def embedding_specs(cfg: ArchConfig) -> Params:
    p = {"table": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        p["unembed"] = ("embed", "vocab")
    return p


def embed_apply(params: Params, cfg: ArchConfig, tokens: jax.Array) -> jax.Array:
    x = params["table"][tokens]  # gather over vocab-sharded table
    if cfg.tie_embeddings:
        x = x * np.sqrt(cfg.d_model)  # gemma-style scaling
    return x.astype(DTYPE)


def unembed_apply(params: Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = x @ params["table"].T
    else:
        logits = x @ params["unembed"]
    return logical(logits, "batch", None, "act_vocab")


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy; fp32 accumulation over sharded vocab."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)
