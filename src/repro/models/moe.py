"""Capacity-based top-k Mixture-of-Experts with shared experts.

Mesh-TF / MaxText-style "dropping" dispatch: tokens are grouped, each group
one-hot-dispatches its tokens to per-expert capacity buffers, experts run a
dense batched FFN, and the combine einsum scatters results back weighted by
the router probabilities.  Tokens over capacity are dropped (residual passes
through) — standard for throughput-oriented training.

Sharding (DESIGN.md §5):
  * experts divide the model axis  -> expert parallelism (EP): the expert
    dim of the weights and dispatch buffers shards over ``model``
    (moonshot-v1-16b-a3b: 64 experts / 16).
  * otherwise                      -> intra-expert tensor parallelism: the
    expert FFN hidden dim shards over ``model``
    (qwen2-moe-a2.7b: 60 experts, expert_d_ff 1408 / 16 = 88).

Shared experts (qwen2-moe's 4) are a plain dense gated MLP applied to every
token, fused into one wider MLP.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.config import ArchConfig
from repro.parallel.sharding import logical

GROUP_SIZE = 512          # tokens per dispatch group
CAPACITY_FACTOR = 1.25


def moe_init(rng, cfg: ArchConfig) -> layers.Params:
    d, e, f = cfg.d_model, cfg.n_experts_padded, cfg.expert_d_ff
    ks = jax.random.split(rng, 5)
    p = {
        "router": layers._dense_init(ks[0], (d, e), d),
        "wi": layers._dense_init(ks[1], (e, d, f), d),
        "wg": layers._dense_init(ks[2], (e, d, f), d),
        "wo": layers._dense_init(ks[3], (e, f, d), f),
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.mlp_init(
            ks[4], cfg, d_ff=cfg.n_shared_experts * f
        )
    return p


def moe_specs(cfg: ArchConfig) -> layers.Params:
    p = {
        "router": ("embed", None),
        "wi": ("experts", "embed", "expert_ff"),
        "wg": ("experts", "embed", "expert_ff"),
        "wo": ("experts", "expert_ff", "embed"),
    }
    if cfg.n_shared_experts:
        p["shared"] = layers.mlp_specs(cfg, expert=True)
    return p


def capacity(cfg: ArchConfig, group: int = GROUP_SIZE) -> int:
    cap = int(np.ceil(group * cfg.top_k / cfg.n_experts * CAPACITY_FACTOR))
    return max(cap, cfg.top_k)


def moe_apply(params: layers.Params, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    """x: [B, S, D] -> [B, S, D]."""
    b, s, d = x.shape
    e, k = cfg.n_experts_padded, cfg.top_k
    tokens = b * s
    g = max(tokens // GROUP_SIZE, 1)
    gs = tokens // g
    xt = x.reshape(g, gs, d)

    # --- routing -----------------------------------------------------------
    router_logits = (
        xt.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    )  # [G, S, E_real] — dead pad slots can never win top-k
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_p, top_idx = jax.lax.top_k(probs, k)          # [G, S, K]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalise

    # --- index-based dispatch (§Perf H3) ------------------------------------
    # The classic one-hot dispatch/combine einsums cost tokens·E·C·D MACs —
    # with 60 experts and capacity 43 that is ~150x the useful expert-FFN
    # FLOPs.  Build the expert buffers with a scatter'd index map + gather
    # instead: data movement O(tokens·top_k·D), zero matmul overhead.
    cap = capacity(cfg)
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)  # [G, S, K, E]
    # position of each (token, k) within its expert's buffer
    pos_in_expert = (
        jnp.cumsum(onehot.reshape(g, gs * k, e), axis=1).reshape(
            g, gs, k, e
        )
        - onehot
    )  # [G, S, K, E]
    slot = jnp.sum(pos_in_expert * onehot, axis=-1).astype(jnp.int32)
    keep = slot < cap                                        # [G, S, K]

    # token index feeding each (expert, slot) buffer entry; overflow dropped
    src_tok = jnp.broadcast_to(
        jnp.arange(gs, dtype=jnp.int32)[None, :, None], (g, gs, k)
    )
    gidx = jnp.broadcast_to(
        jnp.arange(g, dtype=jnp.int32)[:, None, None], (g, gs, k)
    )
    safe_slot = jnp.where(keep, slot, cap)  # cap row = drop bucket
    fill = jnp.full((g, e, cap + 1), gs, jnp.int32)  # gs = "no token"
    fill = fill.at[
        gidx.reshape(-1), top_idx.reshape(-1), safe_slot.reshape(-1)
    ].set(src_tok.reshape(-1), mode="drop")
    buf_tok = fill[:, :, :cap]                               # [G, E, C]
    buf_valid = buf_tok < gs

    # gather tokens into expert buffers (a padded zero row backs "no token")
    xt_pad = jnp.concatenate(
        [xt, jnp.zeros((g, 1, d), xt.dtype)], axis=1
    )
    xe = jnp.take_along_axis(
        xt_pad[:, :, None, :],
        buf_tok.reshape(g, -1, 1, 1).astype(jnp.int32),
        axis=1,
    ).reshape(g, e, cap, d)                                  # [G, E, C, D]
    xe = logical(xe, "batch", "experts", None, None)

    act = jax.nn.gelu if cfg.activation == "geglu" else jax.nn.silu
    h = act(jnp.einsum("gecd,edf->gecf", xe, params["wg"])) * jnp.einsum(
        "gecd,edf->gecf", xe, params["wi"]
    )
    h = logical(h, "batch", "experts", None, "act_expert_ff")
    ye = jnp.einsum("gecf,efd->gecd", h, params["wo"])       # [G, E, C, D]
    ye = ye * buf_valid[..., None].astype(ye.dtype)
    ye = logical(ye, "batch", "experts", None, None)

    # --- combine: one-hot einsum (§Perf H3c) --------------------------------
    # A gather from the expert-sharded ye would all-reduce [G,S,K,D]
    # (top_k copies of every token); the one-hot einsum contracts the
    # sharded expert dim locally and all-reduces only [G,S,D].
    pos_oh = jax.nn.one_hot(
        jnp.minimum(slot, cap - 1), cap, dtype=jnp.float32
    ) * keep[..., None]                                       # [G, S, K, C]
    combine = jnp.einsum(
        "gske,gskc,gsk->gsec", onehot, pos_oh,
        top_p.astype(jnp.float32),
    ).astype(x.dtype)                                         # [G, S, E, C]
    y = jnp.einsum("gsec,gecd->gsd", combine, ye)

    if cfg.n_shared_experts:
        y = y + layers.mlp_apply(params["shared"], cfg, xt)
    return y.reshape(b, s, d)


def aux_load_balance_loss(router_probs: jax.Array, top_idx: jax.Array,
                          n_experts: int) -> jax.Array:
    """Switch-style load-balance auxiliary loss (available to training)."""
    me = jnp.mean(router_probs, axis=(0, 1))
    ce = jnp.mean(
        jax.nn.one_hot(top_idx[..., 0], n_experts, dtype=jnp.float32),
        axis=(0, 1),
    )
    return n_experts * jnp.sum(me * ce)
