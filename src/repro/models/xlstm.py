"""xLSTM (sLSTM + mLSTM blocks) — the [ssm] architecture (arXiv:2405.04517).

* **mLSTM**: matrix-memory cell with exponential input/forget gates.  The
  training path uses the *chunkwise-parallel* form (intra-chunk quadratic
  attention-like einsums + inter-chunk state recurrence under a
  ``lax.scan``), numerically stabilised in log-space with a running max
  ``m``.  Decode is the O(1) single-step recurrence.
* **sLSTM**: scalar-memory cell with per-head block-diagonal recurrent
  weights; inherently sequential, computed with ``lax.scan`` over time.

Block layout follows the paper's residual pre-norm backbone: every
``slstm_every``-th block is an sLSTM block, the rest are mLSTM blocks
(xlstm-350m: 24 blocks, d_model 1024, 4 heads).  Simplifications vs the
reference implementation (recorded in DESIGN.md §9): the mLSTM up-projection
uses factor 2 without the causal-conv branch; the sLSTM block's gated
feed-forward uses factor 4/3 SwiGLU.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.config import ArchConfig
from repro.models.model import Model
from repro.parallel.sharding import logical

Params = Any
CHUNK = 256


# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise parallel (train) and recurrent (decode)
# ---------------------------------------------------------------------------

def mlstm_chunkwise(
    q: jax.Array,  # [B, S, H, K] (K = key/query dim per head)
    k: jax.Array,
    v: jax.Array,  # [B, S, H, V]
    ig: jax.Array,  # [B, S, H] input gate pre-activation
    fg: jax.Array,  # [B, S, H] forget gate pre-activation
    chunk: int = CHUNK,
) -> jax.Array:
    """Stabilised chunkwise mLSTM. Returns h: [B, S, H, V]."""
    out, _ = _mlstm_chunk_with_state(q, k, v, ig, fg, chunk)
    return out


def mlstm_step(
    state: dict,  # {"c": [B,H,dk,dv], "n": [B,H,dk], "m": [B,H]}
    q: jax.Array, k: jax.Array, v: jax.Array,  # [B,H,dk/dv]
    ig: jax.Array, fg: jax.Array,              # [B,H]
) -> tuple[dict, jax.Array]:
    dk = q.shape[-1]
    q = q.astype(jnp.float32) / np.sqrt(dk)
    k = k.astype(jnp.float32) / np.sqrt(dk)
    logf = jax.nn.log_sigmoid(fg.astype(jnp.float32))
    ig = ig.astype(jnp.float32)
    m_new = jnp.maximum(logf + state["m"], ig)
    fprime = jnp.exp(logf + state["m"] - m_new)
    iprime = jnp.exp(ig - m_new)
    c = state["c"] * fprime[..., None, None] + iprime[..., None, None] * (
        k[..., :, None] * v.astype(jnp.float32)[..., None, :]
    )
    n = state["n"] * fprime[..., None] + iprime[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", c, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)),
                      jnp.exp(-m_new))
    h = num / den[..., None]
    return {"c": c, "n": n, "m": m_new}, h


# ---------------------------------------------------------------------------
# sLSTM cell — sequential scan
# ---------------------------------------------------------------------------

def slstm_scan(
    zx: jax.Array, ix: jax.Array, fx: jax.Array, ox: jax.Array,  # [B,S,H,D]
    r: dict,                                   # recurrent weights [H,D,D] x4
    state: dict | None,                        # {"c","n","h","m": [B,H,D]}
) -> tuple[jax.Array, dict]:
    b, s, h, d = zx.shape
    if state is None:
        z0 = jnp.zeros((b, h, d), jnp.float32)
        state = {"c": z0, "n": z0, "h": z0, "m": jnp.full((b, h, d), -jnp.inf)}

    def step(carry, xs):
        zt, it, ft, ot = xs  # [B,H,D] each
        hprev = carry["h"]
        rec = lambda w: jnp.einsum("bhd,hde->bhe", hprev, w.astype(jnp.float32))
        zt = jnp.tanh(zt.astype(jnp.float32) + rec(r["rz"]))
        it = it.astype(jnp.float32) + rec(r["ri"])
        ft = ft.astype(jnp.float32) + rec(r["rf"])
        ot = jax.nn.sigmoid(ot.astype(jnp.float32) + rec(r["ro"]))
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + carry["m"], it)
        fp = jnp.exp(logf + carry["m"] - m_new)
        ip = jnp.exp(it - m_new)
        c = fp * carry["c"] + ip * zt
        n = fp * carry["n"] + ip
        hnew = ot * c / jnp.maximum(n, 1e-6)
        return {"c": c, "n": n, "h": hnew, "m": m_new}, hnew

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (zx, ix, fx, ox))
    # remat the step: the backward pass recomputes gates from the carried
    # state instead of saving ~20 f32 [S,B,H,D] residual buffers
    # (EXPERIMENTS.md §Perf H1)
    state, hs = jax.lax.scan(jax.checkpoint(step), state, xs)
    return hs.transpose(1, 0, 2, 3), state  # [B,S,H,D]


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _mlstm_block_init(rng, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    din = cfg.ssm_expand * d
    h = cfg.n_heads
    dk = din // h
    ks = jax.random.split(rng, 8)
    return {
        "ln": layers.rmsnorm_init(cfg),
        "up_x": layers._dense_init(ks[0], (d, din), d),
        "up_z": layers._dense_init(ks[1], (d, din), d),
        "wq": layers._dense_init(ks[2], (din, din), din),
        "wk": layers._dense_init(ks[3], (din, din), din),
        "wv": layers._dense_init(ks[4], (din, din), din),
        "w_ig": layers._dense_init(ks[5], (din, h), din),
        "w_fg": layers._dense_init(ks[6], (din, h), din),
        "b_ig": jnp.zeros((h,), layers.DTYPE),
        "b_fg": jnp.full((h,), 3.0, layers.DTYPE),  # open forget gates
        "gn": layers.rmsnorm_init(cfg, din),
        "down": layers._dense_init(ks[7], (din, d), din),
    }


def _mlstm_block_specs(cfg: ArchConfig) -> Params:
    return {
        "ln": layers.rmsnorm_specs(cfg),
        "up_x": ("embed", "d_inner"),
        "up_z": ("embed", "d_inner"),
        "wq": ("d_inner", None),
        "wk": ("d_inner", None),
        "wv": ("d_inner", None),
        "w_ig": ("d_inner", None),
        "w_fg": ("d_inner", None),
        "b_ig": (None,),
        "b_fg": (None,),
        "gn": {"scale": (None,)},
        "down": ("d_inner", "embed"),
    }


def _mlstm_qkvg(p, cfg, xin):
    b, s, _ = xin.shape
    h = cfg.n_heads
    din = cfg.ssm_expand * cfg.d_model
    dk = din // h
    xu = xin @ p["up_x"]
    z = xin @ p["up_z"]
    q = (xu @ p["wq"]).reshape(b, s, h, dk)
    k = (xu @ p["wk"]).reshape(b, s, h, dk)
    v = (xu @ p["wv"]).reshape(b, s, h, dk)
    ig = xu @ p["w_ig"] + p["b_ig"]
    fg = xu @ p["w_fg"] + p["b_fg"]
    return z, q, k, v, ig, fg


def _mlstm_block_apply(p, cfg: ArchConfig, x: jax.Array) -> jax.Array:
    xin = layers.rmsnorm_apply(p["ln"], x)
    z, q, k, v, ig, fg = _mlstm_qkvg(p, cfg, xin)
    hcell = mlstm_chunkwise(q, k, v, ig, fg)
    b, s = x.shape[:2]
    hflat = hcell.reshape(b, s, -1).astype(x.dtype)
    hflat = layers.rmsnorm_apply(p["gn"], hflat) * jax.nn.silu(z)
    return x + hflat @ p["down"]


def _mlstm_block_decode(p, cfg, x, state):
    xin = layers.rmsnorm_apply(p["ln"], x)  # [B,1,D]
    z, q, k, v, ig, fg = _mlstm_qkvg(p, cfg, xin)
    state, h = mlstm_step(
        state, q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0]
    )
    b = x.shape[0]
    hflat = h.reshape(b, 1, -1).astype(x.dtype)
    hflat = layers.rmsnorm_apply(p["gn"], hflat) * jax.nn.silu(z)
    return x + hflat @ p["down"], state


def _slstm_block_init(rng, cfg: ArchConfig) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    ks = jax.random.split(rng, 7)
    f_in = int(d * 4 / 3)
    return {
        "ln": layers.rmsnorm_init(cfg),
        "w_in": layers._dense_init(ks[0], (d, 4 * d), d),  # z,i,f,o stacked
        "r": {
            "rz": layers._dense_init(ks[1], (h, hd, hd), hd),
            "ri": layers._dense_init(ks[2], (h, hd, hd), hd),
            "rf": layers._dense_init(ks[3], (h, hd, hd), hd),
            "ro": layers._dense_init(ks[4], (h, hd, hd), hd),
        },
        "gn": layers.rmsnorm_init(cfg, d),
        "ln2": layers.rmsnorm_init(cfg),
        "ff": layers.mlp_init(ks[5], cfg, d_ff=f_in),
    }


def _slstm_block_specs(cfg: ArchConfig) -> Params:
    return {
        "ln": layers.rmsnorm_specs(cfg),
        "w_in": ("embed", None),
        "r": {k: (None, None, None) for k in ("rz", "ri", "rf", "ro")},
        "gn": {"scale": (None,)},
        "ln2": layers.rmsnorm_specs(cfg),
        "ff": {"wi": ("embed", None), "wg": ("embed", None),
               "wo": (None, "embed")},
    }


def _slstm_gates(p, cfg, xin):
    b, s, d = xin.shape
    h = cfg.n_heads
    hd = d // h
    g = (xin @ p["w_in"]).reshape(b, s, 4, h, hd)
    return tuple(g[:, :, i] for i in range(4))  # z,i,f,o: [B,S,H,hd]


def _slstm_block_apply(p, cfg: ArchConfig, x, state=None):
    xin = layers.rmsnorm_apply(p["ln"], x)
    zx, ix, fx, ox = _slstm_gates(p, cfg, xin)
    hs, state = slstm_scan(zx, ix, fx, ox, p["r"], state)
    b, s = x.shape[:2]
    hflat = layers.rmsnorm_apply(p["gn"], hs.reshape(b, s, -1).astype(x.dtype))
    x = x + hflat
    y = layers.rmsnorm_apply(p["ln2"], x)
    act = jax.nn.silu
    y = act(y @ p["ff"]["wg"]) * (y @ p["ff"]["wi"])
    return x + y @ p["ff"]["wo"], state


# ---------------------------------------------------------------------------
# model builder
# ---------------------------------------------------------------------------

def build(cfg: ArchConfig, impl: str = "xla", remat: bool = True) -> Model:
    every = cfg.slstm_every or 8
    n_groups = cfg.n_layers // every
    n_m = every - 1  # mLSTM blocks per group (last block is sLSTM)
    assert cfg.n_layers % every == 0

    din = cfg.ssm_expand * cfg.d_model
    h = cfg.n_heads
    dk = din // h
    hd = cfg.d_model // h

    def init(rng):
        k_emb, k_blocks, _ = jax.random.split(rng, 3)
        def one_group(key):
            km, ks_ = jax.random.split(key)
            return {
                "mlstm": jax.tree.map(
                    lambda *xs: jnp.stack(xs),
                    *[_mlstm_block_init(k, cfg)
                      for k in jax.random.split(km, n_m)],
                ),
                "slstm": _slstm_block_init(ks_, cfg),
            }
        blocks = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[one_group(k) for k in jax.random.split(k_blocks, n_groups)],
        )
        return {
            "embed": layers.embedding_init(k_emb, cfg),
            "blocks": blocks,
            "final_ln": layers.rmsnorm_init(cfg),
        }

    def _prepend(specs, extra=1):
        return jax.tree.map(
            lambda sp: (None,) * extra + sp,
            specs,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(e, (str, type(None))) for e in x),
        )

    def param_specs():
        group = {
            "mlstm": _prepend(_mlstm_block_specs(cfg)),
            "slstm": _slstm_block_specs(cfg),
        }
        return {
            "embed": layers.embedding_specs(cfg),
            "blocks": _prepend(group),
            "final_ln": layers.rmsnorm_specs(cfg),
        }

    def group_fwd(x, gp):
        for i in range(n_m):
            mp = jax.tree.map(lambda a: a[i], gp["mlstm"])
            x = _mlstm_block_apply(mp, cfg, x)
        x, _ = _slstm_block_apply(gp["slstm"], cfg, x)
        return logical(x, "batch", "seq", None)

    body_fn = (
        jax.checkpoint(group_fwd,
                       policy=jax.checkpoint_policies.nothing_saveable)
        if remat else group_fwd
    )

    def trunk(params, x):
        def body(carry, gp):
            return body_fn(carry, gp), None
        x, _ = jax.lax.scan(body, x, params["blocks"])
        return layers.rmsnorm_apply(params["final_ln"], x)

    def loss(params, batch):
        x = layers.embed_apply(params["embed"], cfg, batch["tokens"])
        x = trunk(params, x)
        logits = layers.unembed_apply(params["embed"], cfg, x)
        return layers.softmax_xent(logits, batch["labels"])

    # ---- recurrent caches ----------------------------------------------------
    def init_cache(batch: int, length: int):
        del length  # recurrent state is O(1) in sequence length
        f32 = jnp.float32
        return {
            "pos": jnp.zeros((), jnp.int32),
            "mlstm": {
                "c": jnp.zeros((n_groups, n_m, batch, h, dk, dk), f32),
                "n": jnp.zeros((n_groups, n_m, batch, h, dk), f32),
                "m": jnp.full((n_groups, n_m, batch, h), -jnp.inf, f32),
            },
            "slstm": {
                "c": jnp.zeros((n_groups, batch, h, hd), f32),
                "n": jnp.zeros((n_groups, batch, h, hd), f32),
                "h": jnp.zeros((n_groups, batch, h, hd), f32),
                "m": jnp.full((n_groups, batch, h, hd), -jnp.inf, f32),
            },
        }

    def cache_specs(batch: int, length: int):
        return {
            "pos": (),
            "mlstm": {
                "c": (None, None, "batch", None, "d_inner", None),
                "n": (None, None, "batch", None, "d_inner"),
                "m": (None, None, "batch", None),
            },
            "slstm": {
                k: (None, "batch", None, None) for k in ("c", "n", "h", "m")
            },
        }

    # NOTE: prefill for recurrent archs = run the recurrence over the prompt
    # carrying exact states.  Implemented as a scan over time chunks with
    # mlstm_chunkwise's carry exposed; for the serving path we use the exact
    # step recurrence below (slow-but-correct reference); the chunked carry
    # version is the Pallas/XLA production path.
    def prefill(params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache = init_cache(b, s)

        x = layers.embed_apply(params["embed"], cfg, tokens)

        def body(carry, gp):
            x = carry
            new_mc = {"c": [], "n": [], "m": []}
            for i in range(n_m):
                mp = jax.tree.map(lambda a: a[i], gp["mlstm"])
                xin = layers.rmsnorm_apply(mp["ln"], x)
                z, q, k, v, ig, fg = _mlstm_qkvg(mp, cfg, xin)
                hcell, fstate = _mlstm_chunk_with_state(q, k, v, ig, fg)
                hflat = hcell.reshape(b, s, -1).astype(x.dtype)
                hflat = layers.rmsnorm_apply(mp["gn"], hflat) * jax.nn.silu(z)
                x = x + hflat @ mp["down"]
                for key in new_mc:
                    new_mc[key].append(fstate[key])
            xs_, s2 = _slstm_block_apply(gp["slstm"], cfg, x, None)
            x = xs_
            stacked_mc = {
                key: jnp.stack(new_mc[key]) for key in new_mc
            }
            return x, (stacked_mc, s2)

        x, (mstates, sstates) = jax.lax.scan(
            body, x, params["blocks"]
        )
        x = layers.rmsnorm_apply(params["final_ln"], x)
        logits = layers.unembed_apply(params["embed"], cfg, x[:, -1:])
        cache = {
            "pos": jnp.array(s, jnp.int32),
            "mlstm": mstates,
            "slstm": sstates,
        }
        return logits, cache

    def decode_step(params, cache, token):
        x = layers.embed_apply(params["embed"], cfg, token)

        def body(carry, scanned):
            x = carry
            gp, mc, sc = scanned
            new_mc = {"c": [], "n": [], "m": []}
            for i in range(n_m):
                mp = jax.tree.map(lambda a: a[i], gp["mlstm"])
                st = {k: mc[k][i] for k in ("c", "n", "m")}
                x, st2 = _mlstm_block_decode(mp, cfg, x, st)
                for key in new_mc:
                    new_mc[key].append(st2[key])
            x, s2 = _slstm_block_apply(gp["slstm"], cfg, x, sc)
            stacked = {k: jnp.stack(new_mc[k]) for k in new_mc}
            return x, (stacked, s2)

        x, (mstates, sstates) = jax.lax.scan(
            body, x, (params["blocks"], cache["mlstm"], cache["slstm"])
        )
        x = layers.rmsnorm_apply(params["final_ln"], x)
        logits = layers.unembed_apply(params["embed"], cfg, x)
        return logits, {
            "pos": cache["pos"] + 1,
            "mlstm": mstates,
            "slstm": sstates,
        }

    return Model(
        cfg=cfg,
        init=init,
        param_specs=param_specs,
        loss=loss,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        cache_specs=cache_specs,
    )


def _mlstm_chunk_with_state(q, k, v, ig, fg, chunk: int = CHUNK):
    """Chunkwise mLSTM that also returns the final (c, n, m) state."""
    b, s, h, dk = q.shape
    # reuse the scan from mlstm_chunkwise but capture the carry
    c = min(chunk, s)
    if s % c:
        pad = c - s % c
        q, k, v = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for a in (q, k, v))
        # padded steps: ig = -inf (no input), fg = +inf (keep state)
        ig = jnp.pad(ig, ((0, 0), (0, pad), (0, 0)),
                     constant_values=-1e9)
        fg = jnp.pad(fg, ((0, 0), (0, pad), (0, 0)),
                     constant_values=30.0)
    hs, state = _chunkwise_impl(q, k, v, ig, fg, c)
    return hs[:, :s], state


def _chunkwise_impl(q, k, v, ig, fg, c):
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    n_chunks = s // c
    qq = q.reshape(b, n_chunks, c, h, dk) / np.sqrt(dk)
    kk = k.reshape(b, n_chunks, c, h, dk) / np.sqrt(dk)
    vv = v.reshape(b, n_chunks, c, h, dv)
    igc = ig.reshape(b, n_chunks, c, h).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(
        fg.reshape(b, n_chunks, c, h).astype(jnp.float32)
    )
    bcum = jnp.cumsum(logf, axis=2)
    btot = bcum[:, :, -1]
    # NOTE (perf, EXPERIMENTS.md §Perf H1): the decay matrix and its row
    # max are built *inside* the chunk scan — materialising them for every
    # chunk up front ([B, NC, C, C, H]) made the memory roofline term
    # explode (74 s/step on xlstm train_4k)
    tri = jnp.tril(jnp.ones((c, c), bool))

    def scan_chunk(carry, xs):
        csum, nsum, m_prev = carry
        qc, kc, vc, ic, bc, btc = xs
        dm = bc[:, :, None, :] - bc[:, None, :, :] + ic[:, None, :, :]
        dm = jnp.where(tri[None, :, :, None], dm, -jnp.inf)
        mi = jnp.max(dm, axis=2)
        m_inter = m_prev[:, None, :] + bc
        m = jnp.maximum(m_inter, mi)
        sc = jnp.einsum("bihk,bjhk->bijh", qc, kc,
                        preferred_element_type=jnp.float32)
        wg = jnp.exp(dm - m[:, :, None, :])   # gate-only decay weights
        w = sc * wg
        h_intra = jnp.einsum(
            "bijh,bjhv->bihv", w, vc.astype(jnp.float32)
        )
        n_intra = jnp.einsum("bijh,bjhk->bihk", wg, kc.astype(jnp.float32))
        scale = jnp.exp(m_inter - m)
        h_inter = jnp.einsum("bihk,bhkv->bihv", qc.astype(jnp.float32),
                             csum) * scale[..., None]
        n_inter = jnp.einsum("bihk,bhk->bih", qc.astype(jnp.float32),
                             nsum) * scale
        num = h_intra + h_inter
        den = jnp.abs(
            n_inter + jnp.einsum(
                "bihk,bihk->bih", qc.astype(jnp.float32), n_intra
            )
        )
        hout = num / jnp.maximum(den, jnp.exp(-m))[..., None]
        m_next = jnp.maximum(
            m_prev + btc, jnp.max(btc[:, None, :] - bc + ic, axis=1)
        )
        g_carry = jnp.exp(m_prev + btc - m_next)
        g_in = jnp.exp(btc[:, None, :] - bc + ic - m_next[:, None, :])
        csum = csum * g_carry[..., None, None] + jnp.einsum(
            "bjhk,bjhv,bjh->bhkv", kc.astype(jnp.float32),
            vc.astype(jnp.float32), g_in,
        )
        nsum = nsum * g_carry[..., None] + jnp.einsum(
            "bjhk,bjh->bhk", kc.astype(jnp.float32), g_in
        )
        return (csum, nsum, m_next), hout

    init = (
        jnp.zeros((b, h, dk, dv), jnp.float32),
        jnp.zeros((b, h, dk), jnp.float32),
        jnp.full((b, h), -jnp.inf, jnp.float32),
    )
    xs = (
        qq.transpose(1, 0, 2, 3, 4), kk.transpose(1, 0, 2, 3, 4),
        vv.transpose(1, 0, 2, 3, 4), igc.transpose(1, 0, 2, 3),
        bcum.transpose(1, 0, 2, 3), btot.transpose(1, 0, 2),
    )
    # remat each chunk: backward recomputes the intra-chunk quadratic form
    # instead of saving [B,C,C,H] weight tensors per chunk (§Perf H1)
    (csum, nsum, m_fin), hs = jax.lax.scan(
        jax.checkpoint(scan_chunk), init, xs
    )
    out = hs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dv)
    return out, {"c": csum, "n": nsum, "m": m_fin}
