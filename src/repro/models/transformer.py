"""Decoder-only transformer LM (dense / MoE / early-fusion VLM families).

Layers are stacked into *groups* scanned with ``jax.lax.scan``:

  * uniform archs: group = 1 layer, scanned ``n_layers`` times;
  * gemma3-style local:global: group = ``local_global`` sliding-window
    layers + 1 full-attention layer, scanned ``n_layers/(lg+1)`` times —
    the 5 local layers are unrolled inside the scan body so the HLO stays
    one-group-sized while the pattern is exact.

Each group body is rematerialised (``jax.checkpoint``) during training so
only the carried residual stream is saved per group; the residual carry is
sequence-sharded over the ``model`` axis (sequence parallelism) between
groups.

KV caches: full-attention layers allocate ``length`` slots; sliding-window
layers allocate ``min(window, length)`` rolling slots (this is what makes
gemma3's ``long_500k`` cell fit: 8 global caches of 500k + 40 local caches
of 1k, DESIGN.md §4/§5).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers, moe
from repro.models.config import ArchConfig
from repro.models.model import Model
from repro.parallel.sharding import logical

Params = Any


# ---------------------------------------------------------------------------
# structure helpers
# ---------------------------------------------------------------------------

def group_layout(cfg: ArchConfig) -> tuple[int, int]:
    """(n_groups, local_per_group).  local_per_group == 0 -> uniform arch."""
    if cfg.local_global:
        per = cfg.local_global + 1
        assert cfg.n_layers % per == 0, (cfg.name, cfg.n_layers, per)
        return cfg.n_layers // per, cfg.local_global
    return cfg.n_layers, 0


def _layer_init(rng, cfg: ArchConfig) -> Params:
    ks = jax.random.split(rng, 4)
    p = {
        "ln1": layers.rmsnorm_init(cfg),
        "attn": layers.attention_init(ks[0], cfg),
        "ln2": layers.rmsnorm_init(cfg),
    }
    if cfg.is_moe:
        p["moe"] = moe.moe_init(ks[1], cfg)
    else:
        p["mlp"] = layers.mlp_init(ks[2], cfg)
    return p


def _layer_specs(cfg: ArchConfig) -> Params:
    p = {
        "ln1": layers.rmsnorm_specs(cfg),
        "attn": layers.attention_specs(cfg),
        "ln2": layers.rmsnorm_specs(cfg),
    }
    if cfg.is_moe:
        p["moe"] = moe.moe_specs(cfg)
    else:
        p["mlp"] = layers.mlp_specs(cfg)
    return p


def _stack(tree_list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *tree_list)


def _prepend_spec(specs, extra: int = 1):
    """Add leading (unsharded) stacking dims to every leaf spec tuple."""
    return jax.tree.map(
        lambda spec: (None,) * extra + spec,
        specs,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def _layer_apply(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,
    *,
    window: int,
    impl: str,
    positions=None,
) -> jax.Array:
    h = layers.attention_apply(
        p["attn"], cfg, layers.rmsnorm_apply(p["ln1"], x),
        causal=True, window=window, positions=positions, impl=impl,
    )
    # sequence-parallel residual (§Perf H2b): constraining the residual to
    # seq-sharding turns the row-parallel partial-sum all-reduces into
    # reduce-scatter(+later all-gather) pairs — half the wire bytes, and
    # every elementwise/norm op between them runs on 1/16th of the tokens
    x = logical(x + h, "batch", "seq", None)
    y = layers.rmsnorm_apply(p["ln2"], x)
    if cfg.is_moe:
        y = moe.moe_apply(p["moe"], cfg, y)
    else:
        y = layers.mlp_apply(p["mlp"], cfg, y)
    return logical(x + y, "batch", "seq", None)


def _layer_decode(
    p: Params,
    cfg: ArchConfig,
    x: jax.Array,
    cache: dict,
    pos: jax.Array,
    *,
    window: int,
    impl: str,
) -> tuple[jax.Array, dict]:
    h, new_cache = layers.attention_decode(
        p["attn"], cfg, layers.rmsnorm_apply(p["ln1"], x), cache, pos,
        window=window, impl=impl,
    )
    x = x + h
    y = layers.rmsnorm_apply(p["ln2"], x)
    if cfg.is_moe:
        y = moe.moe_apply(p["moe"], cfg, y)
    else:
        y = layers.mlp_apply(p["mlp"], cfg, y)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# model builder
# ---------------------------------------------------------------------------

def build(cfg: ArchConfig, impl: str = "xla", remat: bool = True) -> Model:
    n_groups, n_local = group_layout(cfg)
    window = cfg.sliding_window

    # ---- init / specs ------------------------------------------------------
    def init(rng) -> Params:
        k_emb, k_blocks, k_final = jax.random.split(rng, 3)
        def one_group(key):
            if n_local:
                k_loc, k_glob = jax.random.split(key)
                return {
                    "local": _stack([
                        _layer_init(k, cfg)
                        for k in jax.random.split(k_loc, n_local)
                    ]),
                    "global": _layer_init(k_glob, cfg),
                }
            return _layer_init(key, cfg)
        blocks = _stack([
            one_group(k) for k in jax.random.split(k_blocks, n_groups)
        ])
        return {
            "embed": layers.embedding_init(k_emb, cfg),
            "blocks": blocks,
            "final_ln": layers.rmsnorm_init(cfg),
        }

    def param_specs() -> Params:
        if n_local:
            group = {
                "local": _prepend_spec(_layer_specs(cfg)),
                "global": _layer_specs(cfg),
            }
        else:
            group = _layer_specs(cfg)
        return {
            "embed": layers.embedding_specs(cfg),
            "blocks": _prepend_spec(group),
            "final_ln": layers.rmsnorm_specs(cfg),
        }

    # ---- forward (train / prefill trunk) ------------------------------------
    def group_fwd(x, gp):
        if n_local:
            for i in range(n_local):
                lp = jax.tree.map(lambda a: a[i], gp["local"])
                x = _layer_apply(lp, cfg, x, window=window, impl=impl)
            x = _layer_apply(gp["global"], cfg, x, window=0, impl=impl)
        else:
            # uniform archs: window applies to every layer (0 = full attn)
            x = _layer_apply(gp, cfg, x, window=window, impl=impl)
        return logical(x, "batch", "seq", None)

    if remat:
        group_fwd_ck = jax.checkpoint(
            group_fwd, policy=jax.checkpoint_policies.nothing_saveable
        )
    else:
        group_fwd_ck = group_fwd

    def trunk(params, x):
        x = logical(x, "batch", "seq", None)
        def body(carry, gp):
            return group_fwd_ck(carry, gp), None
        x, _ = jax.lax.scan(body, x, params["blocks"])
        return layers.rmsnorm_apply(params["final_ln"], x)

    # ---- loss ---------------------------------------------------------------
    def loss(params, batch) -> jax.Array:
        x = layers.embed_apply(params["embed"], cfg, batch["tokens"])
        x = trunk(params, x)
        logits = layers.unembed_apply(params["embed"], cfg, x)
        return layers.softmax_xent(logits, batch["labels"])

    # ---- caches --------------------------------------------------------------
    DECODE_MARGIN = layers.DECODE_MARGIN

    def _cache_lengths(length: int) -> tuple[int, int]:
        """(local rolling slots, global slots) for a cache holding `length`
        tokens with room to append."""
        glob = length + DECODE_MARGIN
        loc = layers.rolling_cache_len(window, length) if window else glob
        return loc, glob

    def init_cache(batch: int, length: int):
        loc_len, glob_len = _cache_lengths(length)
        kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        def kvz(n, ln):
            shape = (n_groups,) + ((n,) if n else ()) + (batch, ln, kv, hd)
            return {
                "k": jnp.zeros(shape, layers.DTYPE),
                "v": jnp.zeros(shape, layers.DTYPE),
            }
        cache = {"pos": jnp.zeros((), jnp.int32)}
        if n_local:
            cache["local"] = kvz(n_local, loc_len)
            cache["global"] = kvz(0, glob_len)
        else:
            cache["global"] = kvz(0, loc_len if window else glob_len)
        return cache

    def cache_specs(batch: int, length: int):
        # global caches: heads-sharded when possible, else length-sharded
        # (flash-decoding, §Perf H4); rolling local caches stay unsharded
        glob = lambda extra: {
            "k": (None,) * extra + ("batch", "kv_len", "kv_heads", None),
            "v": (None,) * extra + ("batch", "kv_len", "kv_heads", None),
        }
        loc = lambda extra: {
            "k": (None,) * extra + ("batch", None, "kv_heads", None),
            "v": (None,) * extra + ("batch", None, "kv_heads", None),
        }
        spec = {"pos": ()}
        if n_local:
            spec["local"] = loc(2)
            spec["global"] = glob(1)
        else:
            spec["global"] = glob(1) if not window else loc(1)
        return spec

    # ---- prefill --------------------------------------------------------------
    def prefill(params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = layers.embed_apply(params["embed"], cfg, tokens)
        x = logical(x, "batch", "seq", None)
        loc_len, glob_len = _cache_lengths(s)

        def _rolling(k):
            return layers.to_rolling(k, s, loc_len)

        def _padded(k):
            pad = glob_len - s
            return jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))

        def body(carry, gp):
            x = carry
            outs = {}
            if n_local:
                lks, lvs = [], []
                for i in range(n_local):
                    lp = jax.tree.map(lambda a: a[i], gp["local"])
                    k, v = _prefill_kv(lp, cfg, x)
                    lks.append(_rolling(k))
                    lvs.append(_rolling(v))
                    x = _layer_apply(lp, cfg, x, window=window, impl=impl)
                gk, gv = _prefill_kv(gp["global"], cfg, x)
                x = _layer_apply(gp["global"], cfg, x, window=0, impl=impl)
                outs["local"] = {"k": jnp.stack(lks), "v": jnp.stack(lvs)}
                outs["global"] = {"k": _padded(gk), "v": _padded(gv)}
            else:
                gk, gv = _prefill_kv(gp, cfg, x)
                w = window or 0
                if w:
                    gk, gv = _rolling(gk), _rolling(gv)
                else:
                    gk, gv = _padded(gk), _padded(gv)
                x = _layer_apply(gp, cfg, x, window=w, impl=impl)
                outs["global"] = {"k": gk, "v": gv}
            return logical(x, "batch", "seq", None), outs

        x, kvs = jax.lax.scan(body, x, params["blocks"])
        x = layers.rmsnorm_apply(params["final_ln"], x)
        logits = layers.unembed_apply(params["embed"], cfg, x[:, -1:])
        cache = {"pos": jnp.array(s, jnp.int32)}
        if n_local:
            cache["local"] = kvs["local"]
            cache["global"] = kvs["global"]
        else:
            cache["global"] = kvs["global"]
        return logits, cache

    def _prefill_kv(p, cfg_, x):
        q, k, v = layers._qkv(p["attn"], cfg_,
                              layers.rmsnorm_apply(p["ln1"], x))
        positions = jnp.arange(x.shape[1])[None, :]
        k = layers.rope(k, positions, cfg_.rope_theta)
        return k, v

    # ---- decode ----------------------------------------------------------------
    def decode_step(params, cache, token):
        pos = cache["pos"]
        x = layers.embed_apply(params["embed"], cfg, token)  # [B,1,D]

        def body(carry, scanned):
            x = carry
            gp, gc = scanned
            new_c = {}
            if n_local:
                nk, nv = [], []
                for i in range(n_local):
                    lp = jax.tree.map(lambda a: a[i], gp["local"])
                    lc = {
                        "k": gc["local"]["k"][i],
                        "v": gc["local"]["v"][i],
                    }
                    x, c2 = _layer_decode(
                        lp, cfg, x, lc, pos, window=window, impl=impl
                    )
                    nk.append(c2["k"])
                    nv.append(c2["v"])
                x, cg = _layer_decode(
                    gp["global"], cfg, x, gc["global"], pos, window=0,
                    impl=impl,
                )
                new_c["local"] = {"k": jnp.stack(nk), "v": jnp.stack(nv)}
                new_c["global"] = cg
            else:
                w = window or 0
                x, cg = _layer_decode(
                    gp, cfg, x, gc["global"], pos, window=w, impl=impl
                )
                new_c["global"] = cg
            return x, new_c

        scan_cache = {k: v for k, v in cache.items() if k != "pos"}
        x, new_cache = jax.lax.scan(body, x, (params["blocks"], scan_cache))
        x = layers.rmsnorm_apply(params["final_ln"], x)
        logits = layers.unembed_apply(params["embed"], cfg, x)
        new_cache["pos"] = pos + 1
        return logits, new_cache

    return Model(
        cfg=cfg,
        init=init,
        param_specs=param_specs,
        loss=loss,
        prefill=prefill,
        decode_step=decode_step,
        init_cache=init_cache,
        cache_specs=cache_specs,
    )
