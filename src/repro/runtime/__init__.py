from repro.core.costmodel import Job, job_time, job_to_task, step_time
from repro.runtime.executor import (
    ExecutionEvent,
    ExecutionResult,
    Fault,
    SimExecutor,
    Slowdown,
)
from repro.runtime.elastic import ClusterManager

__all__ = [
    "Job", "job_time", "job_to_task", "step_time",
    "SimExecutor", "ExecutionResult", "ExecutionEvent", "Fault", "Slowdown",
    "ClusterManager",
]
