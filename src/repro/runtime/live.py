"""Live executor — the paper's Algorithm 3 on real devices.

Walks the FAR repartitioning tree exactly as the paper's GPU runner does:
each node with tasks "creates" its instance (here: builds a JAX mesh over
the node's device group), runs its tasks sequentially on it, "destroys"
it, and recurses into its children in separate threads, so tasks on
disjoint instances run concurrently.  Wall-clock task start/end offsets
are reported for the Table-3-style sim-vs-real comparison.

Tasks here are real work: a few steps of a smoke-config model on the
instance's devices (CPU devices in this container — same code path as a
pod).  One slice maps to ``len(devices) // n_slices`` devices.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable

import jax

from repro.core.device_spec import DeviceSpec, InstanceNode
from repro.core.repartition import Assignment
from repro.launch.mesh import make_submesh


@dataclasses.dataclass
class LiveRecord:
    task_id: int
    node: str
    start: float
    end: float
    payload: dict


def run_live(
    assignment: Assignment,
    spec: DeviceSpec,
    task_fn: Callable[[int, object], dict],
    devices=None,
) -> list[LiveRecord]:
    """Execute an assignment on real devices (Algorithm 3).

    Args:
      assignment: FAR output tree (task lists per instance node).
      spec: the device spec the assignment was built for.
      task_fn: ``task_fn(task_id, mesh) -> payload dict`` — the actual work.
      devices: flat device list (default: all jax.devices()).
    """
    devices = list(devices if devices is not None else jax.devices())
    per_slice = max(len(devices) // spec.n_slices, 1)
    records: list[LiveRecord] = []
    lock = threading.Lock()
    init_time = time.perf_counter()

    def devices_of(node: InstanceNode):
        base = (
            sum(r.footprint for r in spec.roots[: node.tree]) + node.start
        )
        lo = base * per_slice
        hi = (base + node.footprint) * per_slice
        return devices[lo:hi]

    def execute_tree(node: InstanceNode) -> None:
        tids = assignment.node_tasks.get(node.key, [])
        if tids:
            devs = devices_of(node)
            n = len(devs)
            mesh = make_submesh(devs, data=n, model=1)
            for tid in tids:
                t0 = time.perf_counter() - init_time
                payload = task_fn(tid, mesh)
                t1 = time.perf_counter() - init_time
                with lock:
                    records.append(LiveRecord(
                        tid, repr(node), t0, t1, payload
                    ))
        threads = [
            threading.Thread(target=execute_tree, args=(child,))
            for child in node.children
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    roots = [
        threading.Thread(target=execute_tree, args=(root,))
        for root in spec.roots
    ]
    for t in roots:
        t.start()
    for t in roots:
        t.join()
    records.sort(key=lambda r: r.end)
    return records
