"""Discrete-event executor for FAR schedules, with fault injection.

Plays a :class:`~repro.core.problem.Schedule` in simulated time (the
paper's Table-3 "real execution" role — §6.2 argues the simulation is
deterministic given isolation + stable reconfig costs, which we verified
for the core and inherit here).  Beyond the paper it injects:

* :class:`Fault` — a pod-slice dies at time ``t``: every task whose
  instance footprint contains the slice is killed; its *remaining* work
  (rounded up to the last checkpoint) is reported for rescheduling.
* :class:`Slowdown` — a straggling slice stretches task durations by a
  factor; the executor flags tasks drifting more than ``straggle_tol``
  from the FAR simulation (paper §6.2 observed ≤2% drift on healthy
  hardware, so drift is a reliable straggler signal).

The executor never edits the schedule itself — recovery policy lives in
:mod:`repro.runtime.elastic`, which reschedules through FAR (moldability
*is* the mitigation: a restarted job may get a different instance size).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.problem import Schedule, ScheduledTask

EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class Fault:
    time: float
    tree: int
    slice_index: int


@dataclasses.dataclass(frozen=True)
class Slowdown:
    tree: int
    slice_index: int
    factor: float          # >1: this slice runs tasks slower
    start: float = 0.0


@dataclasses.dataclass
class ExecutionEvent:
    time: float
    kind: str              # start | finish | killed | straggler | reconfig
    task_id: int | None = None
    detail: str = ""


@dataclasses.dataclass
class ExecutionResult:
    events: list[ExecutionEvent]
    finished: dict[int, float]          # task id -> finish time
    killed: dict[int, float]            # task id -> completed fraction
    stragglers: list[int]
    makespan: float
    sim_makespan: float

    @property
    def drift(self) -> float:
        """Relative makespan deviation vs the FAR simulation (Table 3)."""
        if self.sim_makespan <= 0:
            return 0.0
        return self.makespan / self.sim_makespan - 1.0


class SimExecutor:
    """Deterministic discrete-event playback of a schedule."""

    def __init__(
        self,
        faults: Sequence[Fault] = (),
        slowdowns: Sequence[Slowdown] = (),
        straggle_tol: float = 0.05,
        duration_noise: float = 0.0,
        seed: int = 0,
    ):
        self.faults = sorted(faults, key=lambda f: f.time)
        self.slowdowns = list(slowdowns)
        self.straggle_tol = straggle_tol
        self.duration_noise = duration_noise
        self.seed = seed

    def _actual_duration(self, item: ScheduledTask) -> float:
        dur = item.duration
        factor = 1.0
        for sd in self.slowdowns:
            if sd.tree == item.node.tree and sd.slice_index in item.node.slices:
                factor = max(factor, sd.factor)
        if self.duration_noise:
            import random

            rng = random.Random(self.seed * 100003 + item.task.id)
            factor *= 1.0 + rng.uniform(-1, 1) * self.duration_noise
        return dur * factor

    def run(self, schedule: Schedule) -> ExecutionResult:
        events: list[ExecutionEvent] = []
        finished: dict[int, float] = {}
        killed: dict[int, float] = {}
        stragglers: list[int] = []
        makespan = 0.0

        for rc in schedule.reconfigs:
            events.append(ExecutionEvent(rc.begin, "reconfig", None,
                                         f"{rc.kind} {rc.node}"))

        # per-instance sequential playback with drift propagation: a task
        # starts at max(planned begin, previous task's actual end on any of
        # its slices)
        slice_free: dict[tuple[int, int], float] = {}
        dead: dict[tuple[int, int], float] = {
            (f.tree, f.slice_index): f.time for f in self.faults
        }
        for item in sorted(schedule.items, key=lambda it: it.begin):
            cells = [(item.node.tree, s) for s in item.node.blocked]
            start = max(
                [item.begin] + [slice_free.get(c, 0.0) for c in cells]
            )
            dur = self._actual_duration(item)
            end = start + dur

            # does a fault interrupt this task?
            kill_at = min(
                (dead[c] for c in cells
                 if c in dead and dead[c] < end - EPS
                 and dead[c] >= start - EPS),
                default=None,
            )
            # fault before the task even starts kills it immediately
            pre_dead = any(c in dead and dead[c] <= start + EPS for c in cells)
            if pre_dead:
                killed[item.task.id] = 0.0
                events.append(ExecutionEvent(start, "killed", item.task.id,
                                             "slice dead before start"))
                continue
            events.append(ExecutionEvent(start, "start", item.task.id))
            if kill_at is not None:
                frac = max(0.0, (kill_at - start) / dur)
                killed[item.task.id] = frac
                events.append(ExecutionEvent(
                    kill_at, "killed", item.task.id, f"at {frac:.0%}"
                ))
                for c in cells:
                    slice_free[c] = kill_at
                makespan = max(makespan, kill_at)
                continue
            finished[item.task.id] = end
            drift = (end - start) / max(item.duration, EPS) - 1.0
            if drift > self.straggle_tol:
                stragglers.append(item.task.id)
                events.append(ExecutionEvent(
                    end, "straggler", item.task.id, f"+{drift:.0%}"
                ))
            events.append(ExecutionEvent(end, "finish", item.task.id))
            for c in cells:
                slice_free[c] = end
            makespan = max(makespan, end)

        events.sort(key=lambda e: e.time)
        return ExecutionResult(
            events=events,
            finished=finished,
            killed=killed,
            stragglers=stragglers,
            makespan=makespan,
            sim_makespan=schedule.makespan,
        )
