"""Elastic cluster manager: FAR scheduling + fault recovery + stragglers.

The control loop a 1000-node deployment needs, at pod scale:

  1. jobs accumulate in a queue while the current batch executes (paper §1.3
     scenario);
  2. each batch is scheduled offline by FAR on the *current* device spec
     and spliced after the live tail (paper §4 concatenation);
  3. the executor plays the batch; on a pod-slice failure the spec is
     degraded (subtree removal — healthy instances are untouched thanks to
     isolation), killed jobs are resurrected from their last checkpoint as
     *new* jobs (remaining steps only) and rejoin the queue — consistent
     with the paper's no-preemption model: a restart is a new task;
  4. straggler-flagged jobs are requeued the same way — because FAR is
     moldable, the retry is free to pick a different instance size.

FAR itself never needs global state, so pods joining/leaving between
batches is just a different ``DeviceSpec`` — that is the elasticity story.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core.costmodel import Job, job_to_task
from repro.core.device_spec import DeviceSpec, TPU_POD_256
from repro.core.multibatch import Tail, concatenate
from repro.core.policy import SchedulerConfig, get_policy
from repro.core.problem import Schedule, Task
from repro.runtime.executor import ExecutionResult, Fault, SimExecutor, Slowdown


@dataclasses.dataclass
class BatchRecord:
    jobs: list[Job]
    schedule: Schedule
    result: ExecutionResult
    spec_name: str


class ClusterManager:
    def __init__(
        self,
        spec: DeviceSpec = TPU_POD_256,
        concat_mode: str = "move_swap",
        straggle_tol: float = 0.05,
        policy: str = "far",
        config: SchedulerConfig | None = None,
    ):
        self.spec = spec
        self.straggle_tol = straggle_tol
        self.policy = policy
        # config is authoritative when given; the legacy concat_mode param
        # is only consulted to build the default (same rule as
        # MultiBatchScheduler)
        self.config = config or SchedulerConfig(concat_mode=concat_mode)
        self.concat_mode = self.config.concat_mode
        self.queue: list[Job] = []
        self.tail = Tail.empty(spec)
        self.history: list[BatchRecord] = []
        self._flip = False
        self._next_id = 0
        self.clock = 0.0

    # -- job intake -----------------------------------------------------------
    def submit(self, job: Job) -> None:
        self.queue.append(job)

    def new_job(self, cfg, shape, steps, checkpoint_every=50) -> Job:
        job = Job(self._next_id, cfg, shape, steps,
                  checkpoint_every=checkpoint_every)
        self._next_id += 1
        return job

    # -- one control-loop iteration --------------------------------------------
    def run_batch(
        self,
        faults: Sequence[Fault] = (),
        slowdowns: Sequence[Slowdown] = (),
        max_jobs: int | None = None,
    ) -> BatchRecord | None:
        if not self.queue:
            return None
        take = self.queue if max_jobs is None else self.queue[:max_jobs]
        self.queue = self.queue[len(take):]
        jobs = list(take)
        tasks: list[Task] = []
        by_task_id: dict[int, Job] = {}
        for job in jobs:
            t = job_to_task(job, self.spec)
            tasks.append(t)
            by_task_id[t.id] = job

        plan = get_policy(self.policy).plan(tasks, self.spec, self.config)
        out = concatenate(
            plan.assignment, self.tail, mode=self.concat_mode,
            reverse=self._flip, use_engine=self.config.use_engine,
        )
        self._flip = not self._flip
        self.tail = out.tail
        schedule = out.schedule

        executor = SimExecutor(
            faults=faults, slowdowns=slowdowns,
            straggle_tol=self.straggle_tol,
        )
        result = executor.run(schedule)
        self.clock = max(self.clock, result.makespan)

        # --- recovery: degrade spec, resurrect killed/straggling jobs --------
        if faults:
            self.spec = self.spec.degrade(
                [(f.tree, f.slice_index) for f in faults]
            )
            self.tail = _prune_tail(self.tail, self.spec)
        for tid, frac in result.killed.items():
            job = by_task_id[tid]
            done_steps = int(frac * job.steps)
            ckpt_steps = (
                done_steps // job.checkpoint_every * job.checkpoint_every
            )
            remaining = job.steps - ckpt_steps
            if remaining > 0:
                self.queue.append(dataclasses.replace(
                    job,
                    id=self._alloc_id(),
                    steps=remaining,
                    name=f"{job.label}~restart@{ckpt_steps}",
                ))
        for tid in result.stragglers:
            # straggler jobs finished late; nothing to requeue, but record
            pass

        rec = BatchRecord(jobs, schedule, result, self.spec.name)
        self.history.append(rec)
        return rec

    def _alloc_id(self) -> int:
        self._next_id += 1
        return 10_000 + self._next_id

    # -- reporting ---------------------------------------------------------------
    @property
    def makespan(self) -> float:
        return max((r.result.makespan for r in self.history), default=0.0)

    def utilization(self) -> float:
        """Busy slice-seconds / available slice-seconds."""
        if not self.history:
            return 0.0
        busy = sum(
            it.size * it.duration
            for r in self.history
            for it in r.schedule.items
            if it.task.id in r.result.finished
        )
        return busy / (self.makespan * self.spec.n_slices)


def _prune_tail(tail: Tail, spec: DeviceSpec) -> Tail:
    """Drop tail state referring to instances that no longer exist."""
    from repro.core.repartition import is_reconfig_key

    keys = {n.key for n in spec.nodes}
    cells = {(r.tree, s) for r in spec.roots for s in r.blocked}
    trees = {r.tree for r in spec.roots}
    release = {
        k: v for k, v in tail.release.items()
        if k in cells or (
            is_reconfig_key(k) and (k == "reconfig" or k[1] in trees)
        )
    }
    alive = {k: v for k, v in tail.alive.items() if k in keys}
    return Tail(release=release, alive=alive)
