"""pjit step builders: train / prefill / decode with full shardings.

``make_train_step`` returns the jitted step plus the sharding pytrees the
launcher (and the dry-run) need for ``in_shardings`` / ``out_shardings``.
The step is donate-safe (state is donated) and optionally applies
error-feedback int8 gradient compression for the cross-pod axis.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig, ShapeConfig, input_specs
from repro.models.model import Model
from repro.optim import AdamWState, adamw_init, adamw_update, global_norm
from repro.parallel import compression
from repro.parallel.sharding import ShardingRules, param_shardings, use_rules

Params = Any


@dataclasses.dataclass
class StepBundle:
    """A lowered-able step function with its sharding contract."""

    fn: Callable
    in_shardings: Any
    out_shardings: Any
    donate_argnums: tuple[int, ...] = ()


def _batch_shardings(rules: ShardingRules, mesh: Mesh, specs: dict):
    out = {}
    for name, sds in specs.items():
        if name in ("tokens", "labels", "token"):
            out[name] = rules.sharding(mesh, "batch", None)
        elif name == "frames":
            out[name] = rules.sharding(mesh, "batch", None, None)
        else:
            out[name] = rules.sharding(mesh, "batch", None)
    return out


def make_train_state_shardings(
    model: Model, rules: ShardingRules, mesh: Mesh
):
    pspecs = model.param_specs()
    psh = param_shardings(pspecs, rules, mesh)
    repl = NamedSharding(mesh, P())
    opt = AdamWState(step=repl, mu=psh, nu=psh)
    return {"params": psh, "opt": opt}


def init_train_state(model: Model, rng) -> dict:
    params = model.init(rng)
    return {"params": params, "opt": adamw_init(params)}


def make_train_step(
    model: Model,
    rules: ShardingRules,
    mesh: Mesh,
    shape: ShapeConfig,
    lr_schedule: Callable | float = 3e-4,
    compress_grads: bool = False,
    microbatches: int = 1,
) -> StepBundle:
    """``microbatches > 1`` runs gradient accumulation: the global batch is
    split on its leading axis and scanned, with fp32 gradient accumulators
    sharded like the parameters — how a large global batch trains on a
    narrow FAR instance without blowing activation memory."""
    cfg = model.cfg
    state_sh = make_train_state_shardings(model, rules, mesh)
    batch_sh = _batch_shardings(rules, mesh, input_specs(cfg, shape))
    if compress_grads:
        state_sh = dict(state_sh)
        state_sh["ef"] = state_sh["params"]  # error buffers: like params

    def _loss_and_grads(params, batch):
        if microbatches == 1:
            return jax.value_and_grad(model.loss)(params, batch)
        mb = jax.tree.map(
            lambda x: x.reshape(microbatches, -1, *x.shape[1:]), batch
        )
        acc0 = (
            jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            ),
            jnp.zeros((), jnp.float32),
        )

        def body(acc, one):
            loss, grads = jax.value_and_grad(model.loss)(params, one)
            gsum, lsum = acc
            gsum = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads
            )
            return (gsum, lsum + loss), None

        (gsum, lsum), _ = jax.lax.scan(body, acc0, mb)
        grads = jax.tree.map(
            lambda g, p: (g / microbatches).astype(p.dtype), gsum, params
        )
        return lsum / microbatches, grads

    def train_step(state, batch):
        with use_rules(rules):
            loss, grads = _loss_and_grads(state["params"], batch)
            if compress_grads:
                grads, new_ef = compression.ef_compress(grads, state["ef"])
            lr = (
                lr_schedule(state["opt"].step)
                if callable(lr_schedule) else lr_schedule
            )
            gnorm = global_norm(grads)
            params, opt = adamw_update(
                state["params"], grads, state["opt"], lr
            )
            new_state = {"params": params, "opt": opt}
            if compress_grads:
                new_state["ef"] = new_ef
            metrics = {"loss": loss, "grad_norm": gnorm,
                       "step": opt.step}
        return new_state, metrics

    repl = NamedSharding(mesh, P())
    metrics_sh = {"loss": repl, "grad_norm": repl, "step": repl}
    return StepBundle(
        fn=train_step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,),
    )


def make_prefill_step(
    model: Model, rules: ShardingRules, mesh: Mesh, shape: ShapeConfig
) -> StepBundle:
    cfg = model.cfg
    pspecs = model.param_specs()
    psh = param_shardings(pspecs, rules, mesh)
    batch_sh = _batch_shardings(rules, mesh, input_specs(cfg, shape))
    cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
    cache_sh = param_shardings(cache_specs, rules, mesh)
    logits_sh = rules.sharding(mesh, "batch", None, "act_vocab")

    def prefill_step(params, batch):
        with use_rules(rules):
            return model.prefill(params, batch)

    return StepBundle(
        fn=prefill_step,
        in_shardings=(psh, batch_sh),
        out_shardings=(logits_sh, cache_sh),
    )


def make_decode_step(
    model: Model, rules: ShardingRules, mesh: Mesh, shape: ShapeConfig
) -> StepBundle:
    cfg = model.cfg
    pspecs = model.param_specs()
    psh = param_shardings(pspecs, rules, mesh)
    cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
    cache_sh = param_shardings(cache_specs, rules, mesh)
    token_sh = rules.sharding(mesh, "batch", None)
    logits_sh = rules.sharding(mesh, "batch", None, "act_vocab")

    def decode_step(params, cache, token):
        with use_rules(rules):
            return model.decode_step(params, cache, token)

    return StepBundle(
        fn=decode_step,
        in_shardings=(psh, cache_sh, token_sh),
        out_shardings=(logits_sh, cache_sh),
        donate_argnums=(1,),
    )
