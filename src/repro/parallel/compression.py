"""Gradient compression (beyond-paper distributed-optimization trick).

Two pieces:

* **Error-feedback int8 quantisation** — symmetric per-tensor int8 with a
  persistent error accumulator (Seide et al. / 1-bit Adam style): the
  quantisation residual is added back to the next step's gradient, so the
  *long-run* update is unbiased and convergence is preserved.

* **Ring all-reduce over the quantised payload** — a shard_map +
  ``lax.ppermute`` ring reduce-scatter/all-gather whose wire format is
  int8 + one fp32 scale per hop (7.97× less DCI traffic than fp32, ~3.98×
  less than bf16).  Intended for the cross-pod ``pod`` axis where
  data-centre interconnect, not ICI, is the bottleneck.  Each hop
  dequantises, accumulates in fp32 and requantises (standard practice;
  the requantisation noise is folded into the error-feedback buffer).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


# ---------------------------------------------------------------------------
# int8 quantisation with error feedback
# ---------------------------------------------------------------------------

def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(
    grads: Params, err: Params
) -> tuple[Params, Params]:
    """Quantise (grads + err) to int8, return the dequantised gradient and
    the new error buffer.  Apply before the cross-pod reduction."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), gf - deq

    out = jax.tree.map(one, grads, err)
    newg = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    newe = jax.tree.map(lambda t: t[1], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    return newg, newe


def ef_init(grads_like: Params) -> Params:
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like
    )


# ---------------------------------------------------------------------------
# int8 ring all-reduce (runs inside shard_map over one mesh axis)
# ---------------------------------------------------------------------------

def ring_allreduce_int8(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """All-reduce ``x`` (flat fp32 [n*chunk]) over ``axis_name`` with an
    int8-on-the-wire ring: reduce-scatter then all-gather.

    Call inside shard_map; x must have leading dim divisible by axis_size.
    """
    n = axis_size
    idx = jax.lax.axis_index(axis_name)
    chunks = x.reshape(n, -1)                     # [n, c]
    perm = [(i, (i + 1) % n) for i in range(n)]

    # ---- reduce-scatter: after n-1 hops, device i holds the full sum of
    # chunk (i+1) mod n ----------------------------------------------------
    def rs_body(k, carry):
        acc = carry                                # [c] running partial
        q, s = quantize_int8(acc)
        q = jax.lax.ppermute(q, axis_name, perm)
        s = jax.lax.ppermute(s, axis_name, perm)
        recv = dequantize_int8(q, s)
        # chunk this device contributes at hop k+1
        j = (idx - k - 1) % n
        nxt = recv + chunks[j]
        return nxt

    start = chunks[(idx - 0) % n]
    # hop 0 sends own chunk (idx); we fold it into the loop by starting
    # with chunk idx and doing n-1 hops
    acc = jax.lax.fori_loop(0, n - 1, rs_body, start)
    # acc now = sum over devices of chunk (idx - (n-1)) % n == (idx+1) % n
    own = (idx + 1) % n

    # ---- all-gather the reduced chunks (int8 wire again) -------------------
    def ag_body(k, carry):
        buf, cur, cur_idx = carry
        q, s = quantize_int8(cur)
        q = jax.lax.ppermute(q, axis_name, perm)
        s = jax.lax.ppermute(s, axis_name, perm)
        nxt = dequantize_int8(q, s)
        nxt_idx = (cur_idx - 1) % n
        buf = jax.lax.dynamic_update_slice(
            buf, nxt[None], (nxt_idx, jnp.int32(0))
        )
        return buf, nxt, nxt_idx

    buf = jnp.zeros_like(chunks)
    buf = jax.lax.dynamic_update_slice(buf, acc[None], (own, jnp.int32(0)))
    buf, _, _ = jax.lax.fori_loop(0, n - 1, ag_body, (buf, acc, own))
    return buf.reshape(x.shape)
