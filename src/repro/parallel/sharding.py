"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP).

Model code annotates parameters and activations with *logical* axis names
("embed", "vocab", "heads", "ff", "experts", "batch", "seq", …).  A
:class:`ShardingRules` table resolves logical names to physical mesh axes,
per architecture — e.g. attention heads shard over ``model`` only when the
head count divides the axis; experts use EP when they divide it and fall
back to intra-expert tensor parallelism otherwise (DESIGN.md §5).

The resolution is dependency-light so the scheduler/cost model can use it
without touching jax device state; actual ``NamedSharding`` objects are
built only when a mesh is supplied.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig

# mesh axis names used across the framework
POD, DATA, MODEL = "pod", "data", "model"


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis -> tuple of mesh axes (or () for replicated)."""

    rules: Mapping[str, tuple[str, ...]]
    mesh_axes: tuple[str, ...]

    def spec(self, *logical: str | None) -> P:
        parts = []
        for name in logical:
            if name is None:
                parts.append(None)
                continue
            axes = self.rules.get(name, ())
            if not axes:
                parts.append(None)
            elif len(axes) == 1:
                parts.append(axes[0])
            else:
                parts.append(tuple(axes))
        return P(*parts)

    def sharding(self, mesh: Mesh, *logical: str | None) -> NamedSharding:
        return NamedSharding(mesh, self.spec(*logical))


def _axis_size(mesh_shape: Mapping[str, int], axis: str) -> int:
    return mesh_shape.get(axis, 1)


def make_rules(
    cfg: ArchConfig,
    mesh_shape: Mapping[str, int],
    fsdp: bool | None = None,
    seq_shard: bool = True,
    batch_size: int | None = None,
) -> ShardingRules:
    """Build the rule table for one architecture on one mesh shape.

    Args:
      cfg: architecture.
      mesh_shape: e.g. {"data": 16, "model": 16} or with "pod".
      fsdp: shard parameters' non-TP dimension over ``data`` (ZeRO-3-style).
        Default: on when the replicated parameter bytes exceed ~1.5 GiB/chip.
      seq_shard: sequence-parallel the residual stream over ``model``.
      batch_size: when given, the ``batch`` logical axis only keeps the
        data axes it divides (long_500k decodes a single stream: batch=1
        cannot data-shard, so the data axes idle — visible in §Roofline).
    """
    model = _axis_size(mesh_shape, MODEL)
    data_axes = tuple(a for a in (POD, DATA) if a in mesh_shape)
    if batch_size is not None:
        kept: tuple[str, ...] = ()
        # keep the largest prefix of (pod, data) whose product divides batch
        for i in range(len(data_axes), 0, -1):
            prod = 1
            for a in data_axes[:i]:
                prod *= mesh_shape[a]
            if batch_size % prod == 0:
                kept = data_axes[:i]
                break
        data_axes = kept

    heads_ok = cfg.n_heads % model == 0
    kv_ok = cfg.n_kv_heads % model == 0 and heads_ok
    ff_ok = (cfg.d_ff % model == 0) if cfg.d_ff else False
    vocab_ok = cfg.padded_vocab() % model == 0
    experts_ok = cfg.is_moe and cfg.n_experts_padded % model == 0
    expert_ff_ok = cfg.is_moe and cfg.expert_d_ff % model == 0
    dinner_ok = cfg.family in ("ssm", "hybrid") and cfg.d_inner % model == 0

    if fsdp is None:
        repl_bytes = cfg.param_count() * 2 / max(model, 1)
        fsdp = repl_bytes > 1.5 * 2**30

    fsdp_axes: tuple[str, ...] = (DATA,) if (fsdp and DATA in mesh_shape) else ()

    rules: dict[str, tuple[str, ...]] = {
        # --- parameters ---
        "embed": fsdp_axes,                    # d_model dim of most weights
        "vocab": (MODEL,) if vocab_ok else (),
        "heads": (MODEL,) if heads_ok else (),
        "kv_heads": (MODEL,) if kv_ok else (),
        "head_dim": (),
        "ff": (MODEL,) if ff_ok else (),
        "experts": (MODEL,) if experts_ok else (),
        # EP when experts divide the axis, otherwise intra-expert TP
        "expert_ff": () if experts_ok else
                     ((MODEL,) if expert_ff_ok else ()),
        "act_expert_ff": () if experts_ok else
                         ((MODEL,) if expert_ff_ok else ()),
        "d_inner": (MODEL,) if dinner_ok else (),
        "ssm_state": (),
        "conv": (),
        "ssm_heads": (MODEL,) if (
            cfg.family in ("ssm", "hybrid")
            and (cfg.d_inner // 64) % model == 0
        ) else (),
        "act_ssm_heads": (MODEL,) if (
            cfg.family in ("ssm", "hybrid")
            and (cfg.d_inner // 64) % model == 0
        ) else (),
        # --- activations ---
        "batch": data_axes,
        "seq": (MODEL,) if seq_shard else (),
        "act_heads": (MODEL,) if heads_ok else (),
        # H5: when heads cannot shard, shard attention *queries* over the
        # model axis instead (k/v stay whole — tiny under MQA/GQA): each
        # device scores only its query rows, removing the 16x-replicated
        # [*, S, S] attention work on few-head archs (gemma-2b, whisper)
        "q_seq": () if heads_ok else ((MODEL,) if seq_shard else ()),
        "act_ff": (MODEL,) if ff_ok else (),
        "act_vocab": (MODEL,) if vocab_ok else (),
        "act_d_inner": (MODEL,) if dinner_ok else (),
        # flash-decoding-style KV sharding (§Perf H4): when the kv heads
        # cannot shard over the model axis, shard the cache LENGTH instead —
        # each device scores its KV chunk and the softmax merge becomes a
        # pair of tiny cross-shard reductions.  Without this, archs like
        # qwen1.5-110b (kv=8) replicate a 121 GiB cache per device.
        "kv_len": () if kv_ok else (MODEL,),
    }
    return ShardingRules(rules=rules, mesh_axes=tuple(mesh_shape))


# ---------------------------------------------------------------------------
# activation constraint helper: models call logical() inside jit; it is a
# no-op outside a mesh context so smoke tests on 1 CPU device do not shard.
# ---------------------------------------------------------------------------

_ACTIVE_RULES: list[ShardingRules | None] = [None]


class use_rules:
    """Context manager installing rules for ``logical`` constraints."""

    def __init__(self, rules: ShardingRules | None):
        self.rules = rules

    def __enter__(self):
        _ACTIVE_RULES.append(self.rules)
        return self.rules

    def __exit__(self, *exc):
        _ACTIVE_RULES.pop()
        return False


def logical(x: jax.Array, *names: str | None) -> jax.Array:
    """Apply a with_sharding_constraint for the active rule table (no-op
    when no rules are installed, e.g. single-device smoke tests)."""
    rules = _ACTIVE_RULES[-1]
    if rules is None:
        return x
    spec = rules.spec(*names)
    return jax.lax.with_sharding_constraint(x, spec)


def param_shardings(
    param_specs,  # pytree of tuple[str|None, ...]
    rules: ShardingRules,
    mesh: Mesh,
):
    """Resolve a pytree of logical param specs into NamedShardings."""
    return jax.tree.map(
        lambda spec: rules.sharding(mesh, *spec),
        param_specs,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )
