"""Deterministic synthetic token pipeline.

A seeded, stateless source (batch ``i`` is a pure function of (seed, i), so
restarts after checkpoint recovery replay the exact stream — fault
tolerance needs no data-state checkpointing) with a host-side prefetch
thread.  Each host materialises only its shard of the global batch
(``host_slice``), the standard multi-host JAX pattern.

The synthetic distribution is a Zipfian unigram mix with a Markov-ish
repetition kick so the loss actually decreases during the example runs
(pure-uniform tokens would pin CE at log V).
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


class SyntheticTokens:
    """Deterministic batch source: ``batch(i) -> {"tokens", "labels"}``."""

    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        seed: int = 0,
        zipf_a: float = 1.2,
        repeat_p: float = 0.3,
    ):
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.zipf_a = zipf_a
        self.repeat_p = repeat_p

    def batch(self, index: int, host_id: int = 0, host_count: int = 1):
        assert self.global_batch % host_count == 0
        per_host = self.global_batch // host_count
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, index, host_id])
        )
        z = rng.zipf(self.zipf_a, size=(per_host, self.seq_len + 1))
        toks = (z - 1) % self.vocab_size
        # repetition kick: with prob repeat_p, copy the previous token + 1
        rep = rng.random((per_host, self.seq_len)) < self.repeat_p
        nxt = (toks[:, :-1] + 1) % self.vocab_size
        toks[:, 1:] = np.where(rep, nxt, toks[:, 1:])
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


class TokenBatchIterator:
    """Prefetching iterator over a SyntheticTokens source."""

    def __init__(
        self,
        source: SyntheticTokens,
        start_index: int = 0,
        prefetch: int = 2,
        host_id: int = 0,
        host_count: int = 1,
    ):
        self.source = source
        self.index = start_index
        self.host_id = host_id
        self.host_count = host_count
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        i = self.index
        while not self._stop.is_set():
            b = self.source.batch(i, self.host_id, self.host_count)
            while not self._stop.is_set():
                try:
                    self._q.put((i, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            i += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        i, b = self._q.get()
        self.index = i + 1
        return b

    def close(self):
        self._stop.set()
