from repro.data.pipeline import SyntheticTokens, TokenBatchIterator

__all__ = ["SyntheticTokens", "TokenBatchIterator"]
