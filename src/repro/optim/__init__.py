from repro.optim.adamw import (
    AdamWState,
    adamw_init,
    adamw_update,
    global_norm,
    wsd_schedule,
)

__all__ = [
    "AdamWState", "adamw_init", "adamw_update", "global_norm",
    "wsd_schedule",
]
