"""AdamW with ZeRO-1-style sharded states.

The first/second-moment trees are fp32 and inherit the *parameter*
shardings leaf-for-leaf (so FSDP-sharded params get FSDP-sharded moments —
the optimizer touches only local shards and pjit keeps the update local).
Global-norm clipping runs in fp32 with a single scalar all-reduce.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array       # int32 scalar
    mu: Params            # fp32, sharded like params
    nu: Params            # fp32, sharded like params


def adamw_init(params: Params) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(f32, params),
        nu=jax.tree.map(f32, params),
    )


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )


def adamw_update(
    params: Params,
    grads: Params,
    state: AdamWState,
    lr: jax.Array | float,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
) -> tuple[Params, AdamWState]:
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu)


def wsd_schedule(
    peak_lr: float,
    warmup: int,
    total: int,
    decay_frac: float = 0.1,
) -> Callable[[jax.Array], jax.Array]:
    """Warmup-stable-decay schedule."""
    decay_start = int(total * (1 - decay_frac))

    def lr(step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        stable = peak_lr
        frac = (s - decay_start) / max(total - decay_start, 1)
        decay = peak_lr * jnp.maximum(1.0 - frac, 0.05)
        return jnp.where(
            s < warmup, warm, jnp.where(s < decay_start, stable, decay)
        )

    return lr
