"""Online vs offline (batched FAR) — quantifies what batching buys
(the paper's §2.3 argument and §7 future work)."""

import numpy as np

from repro.core.device_spec import A100
from repro.core.far import schedule_batch
from repro.core.online import OnlineScheduler
from repro.core.problem import validate_schedule
from repro.core.synth import generate_tasks, workload

from benchmarks.common import Rows


def run(reps: int = 40) -> Rows:
    rows = Rows(
        "Online greedy vs offline FAR (A100)",
        ["workload", "n", "omega_online/omega_FAR", "theory_bound"],
    )
    reps = max(10, min(reps, 60))
    for scaling in ("poor", "mixed", "good"):
        cfg = workload(scaling, "wide", A100)
        for n in (10, 20):
            ratios = []
            for seed in range(reps):
                tasks = generate_tasks(n, A100, cfg, seed=seed)
                far = schedule_batch(tasks, A100)
                online = OnlineScheduler(A100)
                for t in tasks:
                    online.submit(t)
                sched = online.schedule()
                validate_schedule(sched, tasks)
                ratios.append(sched.makespan / far.makespan)
            rows.add(cfg.name, n, float(np.mean(ratios)),
                     "2*rho (batched, [38])")
    return rows
