"""Online vs offline (batched FAR) — quantifies what batching buys
(the paper's §2.3 argument and §7 future work).

Two experiments:

1. the per-task greedy (``"online-greedy"`` policy) against offline FAR on
   whole batches — the paper-motivated gap table;
2. the :class:`~repro.core.service.SchedulingService` on a Poisson arrival
   stream with per-task deadlines: tasks accumulate within a latency
   budget and flush through multi-batch FAR, a trickle falls back to
   greedy placement.  Each stream runs twice — ``replan=False`` and
   ``replan=True`` — and the run asserts the re-planning contract
   (replan makespan <= plain makespan on every stream).  A third run per
   stream serves with ``edf=True`` (earliest-deadline-first ordering of
   deadline carriers within each flush chain) to track what the EDF
   toggle buys on miss rate.  The run emits ``BENCH_online.json``
   (service p50/p95 wall-clock decision latency, virtual queueing delay,
   makespan ratio vs offline FAR, deadline miss-rates under all three
   settings and the replan win counters) so the serving trajectory is
   tracked like ``BENCH_sched_cost.json``.  The policy sweep iterates
   every registered schedule-producing policy, so the ``auto-serve``
   selector (fix-part when sparse, FAR when dense) is scored against its
   two delegates on the identical streams.
"""

import json
import os

import numpy as np

from repro.core.device_spec import A100
from repro.core.policy import SchedulerConfig, available_policies, get_policy
from repro.core.problem import validate_schedule
from repro.core.service import SchedulingService
from repro.core.synth import generate_tasks, workload

from benchmarks.common import Rows

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_online.json")

CFG = SchedulerConfig()


def _run_stream(tasks, arrivals, deadlines, max_wait_s, replan, edf=False):
    svc = SchedulingService(
        A100,
        policy="far",
        config=SchedulerConfig(
            max_wait_s=max_wait_s, max_batch=16, replan=replan, edf=edf,
        ),
    )
    for task, arr in zip(tasks, arrivals):
        svc.submit(task, arrival=float(arr), deadline=deadlines[task.id])
    combined = svc.drain()
    validate_schedule(combined, tasks, check_reconfig=False)
    return svc


def _service_entry(scaling: str, n_tasks: int, mean_gap: float,
                   max_wait_s: float, seed: int) -> dict:
    """One service run on a Poisson stream (with and without tail
    re-planning); returns its JSON entry."""
    cfg = workload(scaling, "wide", A100)
    tasks = generate_tasks(n_tasks, A100, cfg, seed=seed)
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(mean_gap, size=n_tasks))
    # deadline = arrival + queueing allowance + a slack multiple of the
    # task's best-case time — tight enough that misses actually occur
    deadlines = {
        t.id: float(a) + max_wait_s + float(s) * min(t.times.values())
        for t, a, s in zip(tasks, arrivals,
                           rng.uniform(2.0, 12.0, size=n_tasks))
    }
    plain = _run_stream(tasks, arrivals, deadlines, max_wait_s, replan=False)
    re = _run_stream(tasks, arrivals, deadlines, max_wait_s, replan=True)
    # EDF within-batch flush ordering (SchedulerConfig.edf): deadline
    # carriers run earliest-deadline-first within each flush chain
    edf = _run_stream(tasks, arrivals, deadlines, max_wait_s, replan=False,
                      edf=True)
    # the re-planning contract, enforced on every benchmark stream: the
    # shadowed no-replan chain guarantees replan can only ever help
    assert re.makespan <= plain.makespan + 1e-9, \
        f"replan worsened the stream: {re.makespan} > {plain.makespan}"
    offline = get_policy("far").plan(tasks, A100, CFG).makespan
    wall_ms = np.asarray(plain.stats.plan_wall_s()) * 1e3
    delays = np.asarray(plain.stats.queue_delays())
    return {
        "workload": cfg.name,
        "n_tasks": n_tasks,
        "mean_interarrival_s": mean_gap,
        # the stream horizon: for sparse streams the makespan ratio is
        # dominated by waiting for arrivals (placements are causal — never
        # before the flush decision), not by scheduling quality
        "last_arrival_s": float(arrivals[-1]),
        "max_wait_s": max_wait_s,
        "batches": plain.stats.batches,
        "online_placements": plain.stats.online_placements,
        "decision_wall_ms_p50": float(np.percentile(wall_ms, 50)),
        "decision_wall_ms_p95": float(np.percentile(wall_ms, 95)),
        "queue_delay_s_p50": float(np.percentile(delays, 50)),
        "queue_delay_s_p95": float(np.percentile(delays, 95)),
        "makespan_ratio_vs_offline_far": float(plain.makespan / offline),
        # -- deadline-aware serving + tail re-planning ----------------------
        "deadline_miss_rate_noreplan": plain.deadline_report()["miss_rate"],
        "deadline_miss_rate_replan": re.deadline_report()["miss_rate"],
        "deadline_miss_rate_edf": edf.deadline_report()["miss_rate"],
        "makespan_ratio_replan_vs_noreplan": float(
            re.makespan / plain.makespan
        ),
        "replan_attempts": re.stats.replan_attempts,
        "replan_wins": re.stats.replan_wins,
        "withdrawn_tasks": re.stats.withdrawn,
    }


def _sweep_stream(policy, tasks, arrivals, deadlines, max_wait_s):
    """One plain (no-replan) service stream flushed under ``policy``."""
    svc = SchedulingService(
        A100,
        policy=policy,
        config=SchedulerConfig(max_wait_s=max_wait_s, max_batch=16),
    )
    for task, arr in zip(tasks, arrivals):
        svc.submit(task, arrival=float(arr), deadline=deadlines[task.id])
    combined = svc.drain()
    validate_schedule(combined, tasks, check_reconfig=False)
    return svc


def _policy_sweep(n_tasks=40, seed=0, max_wait_s=8.0) -> list[dict]:
    """The multi-policy serving experiment (ROADMAP open item): every
    registered schedule-producing policy drives the service's batch
    flushes, across arrival rates.  `lower-bound` is schedule-less and
    `far-cluster` delegates to `far` on a single device, so both are
    skipped; the interesting axis is offline FAR flushing vs the greedy
    and the §6.5 baselines as arrival density changes."""
    policies = [
        p for p in available_policies()
        if p not in ("lower-bound", "far-cluster")
    ]
    cfg = workload("mixed", "wide", A100)
    tasks = generate_tasks(n_tasks, A100, cfg, seed=seed)
    offline = get_policy("far").plan(tasks, A100, CFG).makespan
    out = []
    for mean_gap in (0.5, 2.0, 8.0):
        rng = np.random.default_rng(seed)
        arrivals = np.cumsum(rng.exponential(mean_gap, size=n_tasks))
        deadlines = {
            t.id: float(a) + max_wait_s + float(s) * min(t.times.values())
            for t, a, s in zip(tasks, arrivals,
                               rng.uniform(2.0, 12.0, size=n_tasks))
        }
        per_rate = {}
        for policy in policies:
            svc = _sweep_stream(policy, tasks, arrivals, deadlines,
                                max_wait_s)
            wall_ms = np.asarray(svc.stats.plan_wall_s()) * 1e3
            per_rate[policy] = {
                "policy": policy,
                "workload": cfg.name,
                "n_tasks": n_tasks,
                "mean_interarrival_s": mean_gap,
                "batches": svc.stats.batches,
                "online_placements": svc.stats.online_placements,
                "decision_wall_ms_p95": float(np.percentile(wall_ms, 95))
                if len(wall_ms) else 0.0,
                "makespan_s": svc.makespan,
                "makespan_ratio_vs_offline_far": float(
                    svc.makespan / offline
                ),
                "deadline_miss_rate": svc.deadline_report()["miss_rate"],
            }
        far_mk = per_rate["far"]["makespan_s"]
        for e in per_rate.values():
            # the comparison column: this policy's served makespan
            # against FAR flushing on the identical stream
            e["makespan_ratio_vs_far_flushing"] = float(
                e["makespan_s"] / far_mk
            )
            out.append(e)
    return out


def run(reps: int = 40) -> Rows:
    rows = Rows(
        "Online greedy vs offline FAR (A100)",
        ["workload", "n", "omega_online/omega_FAR", "theory_bound"],
    )
    reps = max(10, min(reps, 60))
    far = get_policy("far")
    greedy = get_policy("online-greedy")
    for scaling in ("poor", "mixed", "good"):
        cfg = workload(scaling, "wide", A100)
        for n in (10, 20):
            ratios = []
            for seed in range(reps):
                tasks = generate_tasks(n, A100, cfg, seed=seed)
                offline = far.plan(tasks, A100, CFG)
                online = greedy.plan(tasks, A100, CFG)
                online.validate(tasks)
                ratios.append(online.makespan / offline.makespan)
            rows.add(cfg.name, n, float(np.mean(ratios)),
                     "2*rho (batched, [38])")

    # -- latency-budget serving (BENCH_online.json) -------------------------
    report = {
        "device": "A100",
        "policy": "far",
        "metric": "SchedulingService decision latency + makespan vs "
                  "offline FAR; deadline miss-rate and replan wins per "
                  "stream (replan makespan <= plain asserted)",
        "entries": [
            # dense stream: budget accumulates real batches
            _service_entry("mixed", 60, mean_gap=1.0, max_wait_s=8.0, seed=0),
            # sparse trickle: most tasks fall back to greedy placement
            _service_entry("mixed", 30, mean_gap=30.0, max_wait_s=8.0, seed=0),
            _service_entry("poor", 60, mean_gap=1.0, max_wait_s=8.0, seed=1),
        ],
        # the multi-policy serving sweep: every schedule-producing policy
        # flushing the same streams, across arrival rates
        "policy_sweep": _policy_sweep(),
    }
    with open(JSON_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    svc_rows = Rows(
        "SchedulingService (Poisson arrivals, latency budget, deadlines)",
        ["workload", "n", "batches", "online", "wall_p95_ms",
         "makespan/offline_FAR", "replan/plain", "miss%_plain",
         "miss%_replan", "miss%_edf", "replan_wins"],
    )
    for e in report["entries"]:
        svc_rows.add(e["workload"], e["n_tasks"], e["batches"],
                     e["online_placements"], e["decision_wall_ms_p95"],
                     e["makespan_ratio_vs_offline_far"],
                     e["makespan_ratio_replan_vs_noreplan"],
                     100 * e["deadline_miss_rate_noreplan"],
                     100 * e["deadline_miss_rate_replan"],
                     100 * e["deadline_miss_rate_edf"],
                     e["replan_wins"])
    print(svc_rows.render())
    sweep_rows = Rows(
        "Multi-policy serving sweep (A100, MixedScaling/Wide, n=40)",
        ["policy", "gap_s", "batches", "online", "mk/offline_far",
         "mk/far_flushing", "miss%", "wall_p95_ms"],
    )
    for e in report["policy_sweep"]:
        sweep_rows.add(e["policy"], e["mean_interarrival_s"], e["batches"],
                       e["online_placements"],
                       e["makespan_ratio_vs_offline_far"],
                       e["makespan_ratio_vs_far_flushing"],
                       100 * e["deadline_miss_rate"],
                       e["decision_wall_ms_p95"])
    print(sweep_rows.render())
    return rows
