"""Heterogeneous cluster scheduling vs the naive single-queue baseline.

Three questions, answered per workload and recorded in
``BENCH_cluster.json`` (uploaded by the CI bench-smoke job):

1. **Does the pool beat the best single device?**  ``far-cluster``
   (phase-0 moldable device partitioning + per-device FAR + cross-device
   move/swap) against the *single-queue* baseline: the whole batch FAR-
   scheduled on whichever one device finishes it fastest.  The margin is
   the heterogeneous-fleet win the cluster layer exists for.
2. **How evenly does the pool run?**  Per-device utilisation (busy
   compute share against the cluster makespan) of the partitioned plan.
3. **What does per-driver reconfiguration sequencing buy?**  The same
   batch on a homogeneous ``multi_gpu`` forest with per-tree
   reconfiguration sequences (the paper-§2.1-faithful model: one driver
   per GPU) vs the old globally-coupled sequence
   (``reconfig_scope="global"``) — the reconfig parallelism win.

CLI: ``PYTHONPATH=src python -m benchmarks.t_cluster [--quick]``
"""

import argparse
import dataclasses
import json
import os

import numpy as np

from repro.core.cluster import cluster, validate_cluster_schedule
from repro.core.device_spec import A30, A100, H100, multi_gpu
from repro.core.far import schedule_batch
from repro.core.policy import SchedulerConfig, get_policy
from repro.core.problem import validate_schedule
from repro.core.synth import generate_cluster_tasks, generate_tasks, workload

from benchmarks.common import Rows

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_cluster.json")

CFG = SchedulerConfig()

POOLS = {
    "A30+A100": (A30, A100),
    "A30+A100+H100": (A30, A100, H100),
}


def _cluster_entry(pool_name, specs, scaling, n, seed) -> dict:
    from repro.core.bounds import cluster_approximation_factor

    cs = cluster(*specs)
    tasks = generate_cluster_tasks(n, cs, scaling, "wide", seed=seed)
    plan = get_policy("far-cluster").plan(tasks, cs, CFG)
    validate_cluster_schedule(plan.schedule, tasks)
    cp = plan.extras["cluster"]
    far = get_policy("far")
    singles = {
        dev.name: far.plan(tasks, dev, CFG).makespan for dev in cs.devices
    }
    best_dev = min(singles, key=singles.get)
    best_single = singles[best_dev]
    assert plan.makespan <= best_single + 1e-9, \
        "far-cluster lost to a single device"
    return {
        "pool": pool_name,
        "workload": f"{scaling.capitalize()}Scaling,WideTimes",
        "n_tasks": n,
        "seed": seed,
        "cluster_makespan_s": plan.makespan,
        "best_single_device": best_dev,
        "best_single_makespan_s": best_single,
        "single_queue_over_cluster": best_single / plan.makespan,
        "mode": cp.mode,
        "cross_device_moves": cp.moves,
        "cross_device_swaps": cp.swaps,
        "partition_sizes": [len(p) for p in cp.partition],
        "device_utilisation": dict(zip(
            [d.name for d in cs.devices], plan.schedule.utilization()
        )),
        "plan_wall_s": plan.elapsed_s,
        "per_device_certified_factor": cluster_approximation_factor(cs),
    }


def _reconfig_entry(count, n, seed) -> dict:
    """Per-tree vs globally-coupled reconfiguration sequences on a
    homogeneous multi-GPU forest (the satellite fidelity fix)."""
    spec_tree = multi_gpu(A100, count)
    spec_global = dataclasses.replace(spec_tree, reconfig_scope="global")
    cfg = workload("mixed", "wide", spec_tree)
    tasks = generate_tasks(n, spec_tree, cfg, seed=seed)
    a = schedule_batch(tasks, spec_tree)
    b = schedule_batch(tasks, spec_global)
    validate_schedule(a.schedule, tasks)
    validate_schedule(b.schedule, tasks)
    return {
        "device": spec_tree.name,
        "n_tasks": n,
        "makespan_per_tree_s": a.makespan,
        "makespan_global_s": b.makespan,
        "reconfig_parallelism_win_s": b.makespan - a.makespan,
        "reconfig_parallelism_win_ratio": b.makespan / a.makespan,
    }


def run(quick: bool = False, reps: int | None = None) -> Rows:
    del reps  # benchmarks.run passes it; the sweep is deterministic
    sizes = (16,) if quick else (16, 32, 64)
    seeds = (0,) if quick else (0, 1)
    entries = []
    for pool_name, specs in POOLS.items():
        for scaling in ("mixed", "poor", "good"):
            for n in sizes:
                cell = [
                    _cluster_entry(pool_name, specs, scaling, n, seed)
                    for seed in seeds
                ]
                mean = float(np.mean(
                    [e["single_queue_over_cluster"] for e in cell]
                ))
                for e in cell:
                    e["single_queue_over_cluster_mean"] = mean
                entries.extend(cell)

    reconfig = [
        _reconfig_entry(2, 24 if quick else 48, seed=0),
        _reconfig_entry(4, 24 if quick else 96, seed=0),
    ]

    report = {
        "metric": "far-cluster vs best single device (single-queue "
                  "baseline); per-device utilisation; per-tree vs global "
                  "reconfiguration sequencing on multi-GPU forests",
        "entries": entries,
        "reconfig_scope": reconfig,
    }
    with open(JSON_PATH, "w") as fh:
        json.dump(report, fh, indent=2)

    rows = Rows(
        "far-cluster vs single-queue baseline",
        ["pool", "workload", "n", "cluster_mk", "best_single",
         "single/cluster", "mode", "mv/sw", "util"],
    )
    for e in entries:
        util = "/".join(
            f"{u:.2f}" for u in e["device_utilisation"].values()
        )
        rows.add(e["pool"], e["workload"], e["n_tasks"],
                 e["cluster_makespan_s"], e["best_single_makespan_s"],
                 e["single_queue_over_cluster"], e["mode"],
                 f"{e['cross_device_moves']}/{e['cross_device_swaps']}",
                 util)
    for e in reconfig:
        rows.add(e["device"], "reconfig win", e["n_tasks"],
                 e["makespan_per_tree_s"], e["makespan_global_s"],
                 e["reconfig_parallelism_win_ratio"], "per-tree vs global",
                 "-", "-")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="small sweep (CI bench-smoke)")
    args = ap.parse_args()
    print(run(quick=args.quick).render())
