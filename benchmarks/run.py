"""Benchmark runner — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--reps N] [--only t4,t5]

Prints each table and a machine-readable CSV block at the end
(``table,<fields...>`` lines).
"""

from __future__ import annotations

import argparse
import time

from benchmarks import (
    fig3_speedups,
    roofline,
    t1_reconfig,
    t3_sim_vs_exec,
    t4_rho,
    t5_vs_baselines,
    t6_refinement,
    t7_concat,
    t9_multibatch,
    t_cluster,
    t_cost,
    t_faults,
    t_online,
)
from benchmarks.common import DEFAULT_REPS

MODULES = {
    "t1": (t1_reconfig, "Table 1 reconfig times"),
    "fig3": (fig3_speedups, "Fig 3 speedup profiles"),
    "t3": (t3_sim_vs_exec, "Table 3 sim vs executed"),
    "t4": (t4_rho, "Table 4 rho vs n"),
    "t5": (t5_vs_baselines, "Table 5 vs baselines"),
    "t6": (t6_refinement, "Table 6 refinement"),
    "t7": (t7_concat, "Tables 7+8 concatenation"),
    "t9": (t9_multibatch, "Table 9 multi-batch"),
    "cost": (t_cost, "Scheduler cost"),
    "online": (t_online, "Online vs batched FAR"),
    "cluster": (t_cluster, "Heterogeneous cluster vs single queue"),
    "faults": (t_faults, "Fault injection: closed vs open loop"),
    "roofline": (roofline, "Roofline from dry-run"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=DEFAULT_REPS,
                    help="repetitions per config (paper used 1000)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module keys, e.g. t4,t5")
    args = ap.parse_args()

    keys = list(MODULES) if not args.only else args.only.split(",")
    all_csv: list[str] = []
    for key in keys:
        mod, desc = MODULES[key]
        t0 = time.time()
        rows = mod.run(reps=args.reps)
        print(rows.render())
        print(f"   [{desc}: {time.time() - t0:.1f}s]\n")
        all_csv.extend(rows.csv())
        if key == "roofline" and hasattr(mod, "run_far_on_pod"):
            rows2 = mod.run_far_on_pod()
            print(rows2.render())
            print()
            all_csv.extend(rows2.csv())

    print("== CSV ==")
    for line in all_csv:
        print(line)


if __name__ == "__main__":
    main()
