"""Paper §6.4.2 / §6.5.2: scheduler compute cost vs batch size.

Paper (C++, Ryzen 5 4600H): 12.3 ms / 532 ms / 1621 ms at n=100/500/1000.
Ours is Python with an admissible allocation-family pruning (far.py), a
warm-started family evaluation and the incremental timing engine
(core/timing.py) on every refinement hot path.

Besides the printed table, the run emits ``BENCH_sched_cost.json`` in the
repo root: batch size -> p50/p95 scheduler latency with per-phase
breakdown (family / evaluate / refine), plus the end-to-end speedup of
the incremental-engine pipeline over the in-tree replay-per-query
reference pipeline (``schedule_batch(use_engine=False)``) at n=200.
Note the reference pipeline itself already contains this PR's replay
micro-optimisations, so the recorded speedup *understates* the gain over
the true pre-change code.
"""

import json
import os
import time

import numpy as np

from repro.core.baselines import fix_part, miso_opt, partition_of_ones
from repro.core.device_spec import A100
from repro.core.far import schedule_batch
from repro.core.policy import SchedulerConfig
from repro.core.synth import generate_tasks, workload

from benchmarks.common import Rows

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_sched_cost.json")


def _timed_runs(tasks, reps: int, use_engine: bool = True):
    """Per-run wall times + per-phase medians for schedule_batch(refine=True)."""
    times, phases = [], []
    cfg = SchedulerConfig(use_engine=use_engine)
    schedule_batch(tasks, A100, cfg)  # warm caches
    for _ in range(reps):
        t0 = time.perf_counter()
        res = schedule_batch(tasks, A100, cfg)
        times.append(time.perf_counter() - t0)
        phases.append(res.phase_s)
    med_phase = {
        k: float(np.median([p[k] for p in phases]) * 1e3)
        for k in phases[0]
    }
    return np.asarray(times) * 1e3, med_phase, res


def run(reps: int = 5) -> Rows:
    reps = max(reps, 5)
    rows = Rows(
        "Scheduler cost (MixedScaling, WideTimes, A100)",
        ["n", "far_p50_ms", "far_p95_ms", "evaluated/family",
         "miso_ms", "fixpart_ms", "paper_far_ms"],
    )
    paper = {100: 12.32, 200: "-", 500: 532.21, 1000: 1620.82}
    cfg = workload("mixed", "wide", A100)
    report = {
        "device": "A100",
        "workload": cfg.name,
        "metric": "schedule_batch(refine=True) end-to-end wall ms",
        "entries": [],
    }
    for n in (100, 200, 500, 1000):
        ts = generate_tasks(n, A100, cfg, seed=0)
        times, med_phase, res = _timed_runs(ts, reps)
        p50 = float(np.percentile(times, 50))
        p95 = float(np.percentile(times, 95))
        t0 = time.perf_counter()
        miso_opt(ts, A100)
        miso_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        fix_part(ts, A100, partition_of_ones(A100))
        fp_ms = (time.perf_counter() - t0) * 1e3
        rows.add(n, p50, p95, f"{res.evaluated}/{res.family_size}",
                 miso_ms, fp_ms, paper[n])
        report["entries"].append({
            "n": n,
            "p50_ms": p50,
            "p95_ms": p95,
            "phase_median_ms": med_phase,
            "evaluated": res.evaluated,
            "family_size": res.family_size,
        })

    # engine-vs-replay pipeline speedup at n=200 (acceptance tracking).
    # The container's wall clock drifts ±30%, so the two pipelines are
    # measured in strict alternation and the speedup is the median of the
    # per-pair ratios — both sides of every ratio see the same machine
    # state, unlike two sequential best-of-N blocks.
    ts = generate_tasks(200, A100, cfg, seed=0)
    eng_cfg = SchedulerConfig(use_engine=True)
    rep_cfg = SchedulerConfig(use_engine=False)
    schedule_batch(ts, A100, eng_cfg)
    schedule_batch(ts, A100, rep_cfg)
    eng_times, rep_times = [], []
    for _ in range(max(reps, 15)):
        t0 = time.perf_counter()
        schedule_batch(ts, A100, eng_cfg)
        eng_times.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        schedule_batch(ts, A100, rep_cfg)
        rep_times.append(time.perf_counter() - t0)
    eng_times = np.asarray(eng_times) * 1e3
    rep_times = np.asarray(rep_times) * 1e3
    speedup = float(np.median(rep_times / eng_times))
    report["n200_engine_p50_ms"] = float(np.median(eng_times))
    report["n200_engine_best_ms"] = float(np.min(eng_times))
    report["n200_replay_path_p50_ms"] = float(np.median(rep_times))
    report["n200_replay_path_best_ms"] = float(np.min(rep_times))
    report["n200_speedup_engine_vs_replay_path"] = speedup
    report["note"] = (
        "replay path (use_engine=False) includes PR 1's replay "
        "micro-optimisations, so this ratio understates the speedup over "
        "the true pre-change code (the seed commit measured ~28.6 ms "
        "median for this workload on the PR 1 container — a one-off "
        "provenance data point, not reproduced by this script)"
    )
    with open(JSON_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    rows.add("n=200 speedup", f"{speedup:.1f}x", "(engine vs replay path)",
             "", "", "", "")
    return rows
