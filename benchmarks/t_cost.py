"""Paper §6.4.2 / §6.5.2: scheduler compute cost vs batch size.

Paper (C++, Ryzen 5 4600H): 12.3 ms / 532 ms / 1621 ms at n=100/500/1000.
Ours is Python with an admissible allocation-family pruning (far.py), a
warm-started family evaluation with an incremental prune area, the
incremental timing engine (core/timing.py) on every refinement hot path,
and a jax array-program family evaluator (core/family_eval.py) selectable
via ``SchedulerConfig(evaluator=...)``.

Besides the printed table, the run emits ``BENCH_sched_cost.json`` in the
repo root: per batch size and per evaluator (sequential / incremental /
vectorized), p50/p95 scheduler latency with per-phase breakdown (family /
evaluate / refine), *paired* evaluate-phase speedups (both sides of every
ratio measured back-to-back — the container wall clock drifts far too
much for independent medians), and a dedicated ``phase2_sweep`` section:
n in {500, 1000, 2000} x {pruned, full-family} paired speedups of every
evaluator against sequential.  CI's bench-smoke gates on that sweep
(incremental >= 2x sequential at n=1000 pruned; the recorded number on
the reference box is ~4x).

CLI: ``PYTHONPATH=src python -m benchmarks.t_cost [--quick] [--reps N]``
— ``--quick`` restricts the latency table to n <= 200 with few reps and
trims the sweep reps (the CI bench-smoke step still runs the full sweep
sizes, the speedup gate needs n=1000).
"""

import argparse
import json
import os
import time

import numpy as np

from repro.core.baselines import fix_part, miso_opt, partition_of_ones
from repro.core.device_spec import A100
from repro.core.far import schedule_batch
from repro.core.policy import SchedulerConfig
from repro.core.synth import generate_tasks, workload

from benchmarks.common import Rows

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_sched_cost.json")

EVALUATORS = ("sequential", "incremental", "vectorized")
SWEEP_SIZES = (500, 1000, 2000)


def _timed_runs(tasks, reps: int, config: SchedulerConfig):
    """Per-run wall times + per-phase medians for schedule_batch."""
    times, phases = [], []
    schedule_batch(tasks, A100, config)  # warm caches / jit compiles
    for _ in range(reps):
        t0 = time.perf_counter()
        res = schedule_batch(tasks, A100, config)
        times.append(time.perf_counter() - t0)
        phases.append(res.phase_s)
    med_phase = {
        k: float(np.median([p[k] for p in phases]) * 1e3)
        for k in phases[0]
    }
    return np.asarray(times) * 1e3, med_phase, res


def _paired_evaluate_speedups(tasks, reps: int, evaluators=EVALUATORS,
                              **config_kwargs):
    """Median per-pair evaluate-phase ratios of every evaluator against
    sequential.

    All configs run in strict alternation so both sides of every ratio
    see the same machine state (the container clock drifts ±30%+).
    Returns ``({evaluator: speedup}, {evaluator: median_ms})``.
    """
    cfgs = {
        ev: SchedulerConfig(evaluator=ev, **config_kwargs)
        for ev in evaluators
    }
    for cfg in cfgs.values():
        schedule_batch(tasks, A100, cfg)
    med = {ev: [] for ev in evaluators}
    ratios = {ev: [] for ev in evaluators if ev != "sequential"}
    for _ in range(reps):
        step = {}
        for ev, cfg in cfgs.items():
            res = schedule_batch(tasks, A100, cfg)
            step[ev] = res.phase_s["evaluate"] * 1e3
            med[ev].append(step[ev])
        for ev in ratios:
            ratios[ev].append(step["sequential"] / step[ev])
    return (
        {ev: float(np.median(v)) for ev, v in ratios.items()},
        {ev: float(np.median(v)) for ev, v in med.items()},
    )


def _phase2_sweep(reps: int) -> list:
    """The per-evaluator phase-2 sweep CI's speedup gate reads: paired
    evaluate-phase medians and ratios over n x {pruned, full-family}."""
    cfg = workload("mixed", "wide", A100)
    out = []
    for n in SWEEP_SIZES:
        ts = generate_tasks(n, A100, cfg, seed=0)
        for prune in (True, False):
            speedups, med = _paired_evaluate_speedups(
                ts, reps, prune=prune, refine=False
            )
            out.append({
                "n": n,
                "prune": prune,
                "evaluate_median_ms": med,
                "paired_speedup_vs_sequential": speedups,
            })
    return out


def run(reps: int = 5, quick: bool = False) -> Rows:
    reps = max(reps, 3 if quick else 5)
    sizes = (100, 200) if quick else (100, 200, 500, 1000, 2000)
    rows = Rows(
        "Scheduler cost (MixedScaling, WideTimes, A100)",
        ["n", "evaluator", "p50_ms", "p95_ms", "eval_phase_ms",
         "evaluated/family", "paper_far_ms"],
    )
    paper = {100: 12.32, 200: "-", 500: 532.21, 1000: 1620.82, 2000: "-"}
    cfg = workload("mixed", "wide", A100)
    report = {
        "device": "A100",
        "workload": cfg.name,
        "metric": "schedule_batch(refine=True) end-to-end wall ms",
        "entries": [],
    }
    for n in sizes:
        ts = generate_tasks(n, A100, cfg, seed=0)
        per_eval = {}
        for ev in EVALUATORS:
            times, med_phase, res = _timed_runs(
                ts, reps, SchedulerConfig(evaluator=ev))
            p50 = float(np.percentile(times, 50))
            p95 = float(np.percentile(times, 95))
            per_eval[ev] = {
                "p50_ms": p50,
                "p95_ms": p95,
                "phase_median_ms": med_phase,
                "evaluated": res.evaluated,
                "family_size": res.family_size,
            }
            rows.add(n, ev, p50, p95, med_phase["evaluate"],
                     f"{res.evaluated}/{res.family_size}", paper[n])
        speedups, eval_med = _paired_evaluate_speedups(ts, reps)
        entry = {"n": n, "evaluators": per_eval,
                 "evaluate_paired_speedup_vs_seq": speedups,
                 "evaluate_paired_median_ms": eval_med}
        if not quick:
            # the unpruned full-family regime (policy sweeps / research
            # runs score every candidate) scores every single candidate
            fspeed, fmed = _paired_evaluate_speedups(
                ts, max(3, reps // 2), prune=False, refine=False)
            entry["full_family_evaluate_paired_speedup"] = fspeed
            entry["full_family_evaluate_paired_median_ms"] = fmed
        report["entries"].append(entry)

        t0 = time.perf_counter()
        miso_opt(ts, A100)
        entry["miso_ms"] = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        fix_part(ts, A100, partition_of_ones(A100))
        entry["fixpart_ms"] = (time.perf_counter() - t0) * 1e3

    # engine-vs-replay pipeline speedup at n=200 (acceptance tracking,
    # measured in strict alternation like the evaluator pairing above)
    ts = generate_tasks(200, A100, cfg, seed=0)
    eng_cfg = SchedulerConfig(use_engine=True)
    rep_cfg = SchedulerConfig(use_engine=False)
    schedule_batch(ts, A100, eng_cfg)
    schedule_batch(ts, A100, rep_cfg)
    eng_times, rep_times = [], []
    for _ in range(max(reps, 5 if quick else 15)):
        t0 = time.perf_counter()
        schedule_batch(ts, A100, eng_cfg)
        eng_times.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        schedule_batch(ts, A100, rep_cfg)
        rep_times.append(time.perf_counter() - t0)
    eng_times = np.asarray(eng_times) * 1e3
    rep_times = np.asarray(rep_times) * 1e3
    speedup = float(np.median(rep_times / eng_times))
    report["n200_engine_p50_ms"] = float(np.median(eng_times))
    report["n200_engine_best_ms"] = float(np.min(eng_times))
    report["n200_replay_path_p50_ms"] = float(np.median(rep_times))
    report["n200_replay_path_best_ms"] = float(np.min(rep_times))
    report["n200_speedup_engine_vs_replay_path"] = speedup
    # per-driver reconfiguration sequencing on multi-GPU forests: the
    # paper-§2.1-faithful per-tree model vs the old globally-coupled
    # sequence (reconfig_scope="global"); recorded here so the fidelity
    # fix's makespan delta stays tracked alongside the scheduler-cost
    # numbers.  One implementation: benchmarks/t_cluster owns the
    # measurement (and records the same comparison in BENCH_cluster.json)
    from benchmarks.t_cluster import _reconfig_entry

    report["multi_gpu_reconfig"] = [
        _reconfig_entry(count, n, seed=0)
        for count, n in (((2, 24), (4, 48)) if quick else ((2, 48), (4, 96)))
    ]

    # the per-evaluator phase-2 sweep (n x prune grid) CI's paired
    # speedup gate asserts against — incremental >= 2x sequential at
    # n=1000 pruned
    report["phase2_sweep"] = _phase2_sweep(max(3, reps if not quick else 3))

    report["note"] = (
        "evaluator entries are bit-identical in output (enforced by "
        "tests/test_family_eval.py); the incremental evaluator replays "
        "only the post-divergence suffix of each candidate in a compiled "
        "delta engine, so it wins across the board once candidates are "
        "expensive enough (n>=~256) and is auto's first choice; the "
        "vectorized evaluator amortizes a fixed per-step array-program "
        "cost across the scored candidates, so it pays off where many "
        "candidates are scored and no C compiler is available.  The "
        "replay path (use_engine=False) includes PR 1's replay "
        "micro-optimisations, so that ratio understates the speedup over "
        "the true pre-change code."
    )
    with open(JSON_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    rows.add("n=200 speedup", f"{speedup:.1f}x", "(engine vs replay path)",
             "", "", "", "")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="n <= 200, few reps (CI bench-smoke)")
    ap.add_argument("--reps", type=int, default=5)
    args = ap.parse_args()
    print(run(reps=args.reps, quick=args.quick).render())
