"""Paper §6.4.2 / §6.5.2: scheduler compute cost vs batch size.

Paper (C++, Ryzen 5 4600H): 12.3 ms / 532 ms / 1621 ms at n=100/500/1000.
Ours is Python with an admissible allocation-family pruning (far.py), so
we also report the number of allocations actually scheduled."""

import time

from repro.core.baselines import fix_part, miso_opt, partition_of_ones
from repro.core.device_spec import A100
from repro.core.far import schedule_batch
from repro.core.synth import generate_tasks, workload

from benchmarks.common import Rows


def run(reps: int = 5) -> Rows:
    rows = Rows(
        "Scheduler cost (MixedScaling, WideTimes, A100)",
        ["n", "far_ms", "evaluated/family", "miso_ms", "fixpart_ms",
         "paper_far_ms"],
    )
    paper = {100: 12.32, 500: 532.21, 1000: 1620.82}
    for n in (100, 500, 1000):
        ts = generate_tasks(n, A100, workload("mixed", "wide", A100), seed=0)
        t0 = time.perf_counter()
        res = None
        for _ in range(reps):
            res = schedule_batch(ts, A100)
        far_ms = (time.perf_counter() - t0) / reps * 1e3
        t0 = time.perf_counter()
        miso_opt(ts, A100)
        miso_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        fix_part(ts, A100, partition_of_ones(A100))
        fp_ms = (time.perf_counter() - t0) * 1e3
        rows.add(n, far_ms, f"{res.evaluated}/{res.family_size}",
                 miso_ms, fp_ms, paper[n])
    return rows
