"""Shared benchmark helpers."""

from __future__ import annotations

import time

import numpy as np

# default repetition count; the paper uses 1000 — pass --reps 1000 to match
# (results are stable well before that)
DEFAULT_REPS = 200


class Rows:
    """Collects result rows and prints aligned tables + CSV lines."""

    def __init__(self, title: str, columns: list[str]):
        self.title = title
        self.columns = columns
        self.rows: list[list] = []
        # an optional companion table rendered/exported after this one
        # (e.g. a benchmark's secondary comparison)
        self.extra: "Rows | None" = None

    def add(self, *values) -> None:
        self.rows.append(list(values))

    def render(self) -> str:
        w = [
            max(len(str(c)), *(len(_fmt(r[i])) for r in self.rows))
            if self.rows else len(str(c))
            for i, c in enumerate(self.columns)
        ]
        out = [f"== {self.title} =="]
        out.append("  ".join(str(c).ljust(w[i])
                             for i, c in enumerate(self.columns)))
        for r in self.rows:
            out.append("  ".join(_fmt(v).ljust(w[i])
                                 for i, v in enumerate(r)))
        if self.extra is not None:
            out.append("")
            out.append(self.extra.render())
        return "\n".join(out)

    def csv(self) -> list[str]:
        tag = self.title.split(":")[0].replace(" ", "_").lower()
        lines = []
        for r in self.rows:
            lines.append(f"{tag}," + ",".join(_fmt(v) for v in r))
        if self.extra is not None:
            lines.extend(self.extra.csv())
        return lines


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.3f}"
    return str(v)


def timed(fn, *args, reps: int = 5, **kwargs) -> tuple[float, object]:
    out = None
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kwargs)
    dt = (time.perf_counter() - t0) / reps
    return dt, out


def mean(xs) -> float:
    return float(np.mean(xs)) if len(xs) else float("nan")
