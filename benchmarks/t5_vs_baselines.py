"""Paper Table 5 (+ Fig. 12): σ_A = makespan(A) / makespan(FAR).

Baselines: MISO-OPT [31], FixPart(1,...,1), FixPartBest, FixPart(7).
Every comparison is one loop over registered policy names
(:func:`repro.core.policy.get_policy`); paper row order and values are
printed alongside ours."""

import numpy as np

from repro.core.baselines import partition_whole
from repro.core.device_spec import A100
from repro.core.policy import SchedulerConfig, get_policy
from repro.core.rodinia import rodinia_tasks
from repro.core.synth import ALL_WORKLOADS, generate_tasks, workload

from benchmarks.common import Rows

PAPER = {
    ("poor", "narrow"): (1.19, 1.25, 1.24, 3.29),
    ("poor", "wide"): (1.55, 1.29, 1.22, 3.39),
    ("mixed", "narrow"): (1.62, 1.39, 1.13, 2.17),
    ("mixed", "wide"): (2.03, 1.47, 1.09, 2.16),
    ("good", "narrow"): (1.83, 1.61, 1.00, 1.31),
    ("good", "wide"): (2.14, 1.78, 1.01, 1.28),
}

CFG = SchedulerConfig()
# column key -> (policy name, config): FixPart appears twice, once with the
# all-ones default and once pinned to the whole-device partition
BASELINES = {
    "miso": ("miso", CFG),
    "ones": ("fix-part", CFG),
    "best": ("fix-part-best", CFG),
    "whole": ("fix-part", CFG.replace(partition=partition_whole(A100))),
}


def _sigmas(tasks) -> dict[str, float]:
    far = get_policy("far").plan(tasks, A100, CFG).makespan
    return {
        key: get_policy(name).plan(tasks, A100, cfg).makespan / far
        for key, (name, cfg) in BASELINES.items()
    }


def run(reps: int = 100) -> Rows:
    rows = Rows(
        "Table 5: sigma vs FAR (A100, n=15)",
        ["workload", "miso", "ones", "best", "whole",
         "paper(miso,ones,best,whole)"],
    )
    sig = _sigmas(rodinia_tasks(A100))
    rows.add("rodinia-fixture", *(sig[k] for k in BASELINES),
             "(2.10,2.18,1.16,1.26)")
    for scaling, times in ALL_WORKLOADS:
        cfg = workload(scaling, times, A100)
        acc = {k: [] for k in BASELINES}
        for seed in range(reps):
            ts = generate_tasks(15, A100, cfg, seed=seed)
            for k, v in _sigmas(ts).items():
                acc[k].append(v)
        rows.add(
            cfg.name,
            *(float(np.mean(acc[k])) for k in BASELINES),
            str(PAPER[(scaling, times)]),
        )
    return rows
