"""Paper Table 5 (+ Fig. 12): σ_A = makespan(A) / makespan(FAR).

Baselines: MISO-OPT [31], FixPart(1,...,1), FixPartBest, FixPart(7).
Paper row order and values are printed alongside ours."""

import numpy as np

from repro.core.baselines import (
    fix_part, fix_part_best, miso_opt, partition_of_ones, partition_whole,
)
from repro.core.device_spec import A100
from repro.core.far import schedule_batch
from repro.core.rodinia import rodinia_tasks
from repro.core.synth import ALL_WORKLOADS, generate_tasks, workload

from benchmarks.common import Rows

PAPER = {
    ("poor", "narrow"): (1.19, 1.25, 1.24, 3.29),
    ("poor", "wide"): (1.55, 1.29, 1.22, 3.39),
    ("mixed", "narrow"): (1.62, 1.39, 1.13, 2.17),
    ("mixed", "wide"): (2.03, 1.47, 1.09, 2.16),
    ("good", "narrow"): (1.83, 1.61, 1.00, 1.31),
    ("good", "wide"): (2.14, 1.78, 1.01, 1.28),
}


def run(reps: int = 100) -> Rows:
    rows = Rows(
        "Table 5: sigma vs FAR (A100, n=15)",
        ["workload", "miso", "ones", "best", "whole",
         "paper(miso,ones,best,whole)"],
    )
    tasks = rodinia_tasks(A100)
    far = schedule_batch(tasks, A100).makespan
    rows.add(
        "rodinia-fixture",
        miso_opt(tasks, A100).makespan / far,
        fix_part(tasks, A100, partition_of_ones(A100)).makespan / far,
        fix_part_best(tasks, A100)[0].makespan / far,
        fix_part(tasks, A100, partition_whole(A100)).makespan / far,
        "(2.10,2.18,1.16,1.26)",
    )
    for scaling, times in ALL_WORKLOADS:
        cfg = workload(scaling, times, A100)
        sig = {k: [] for k in ("miso", "ones", "best", "whole")}
        for seed in range(reps):
            ts = generate_tasks(15, A100, cfg, seed=seed)
            f = schedule_batch(ts, A100).makespan
            sig["miso"].append(miso_opt(ts, A100).makespan / f)
            sig["ones"].append(
                fix_part(ts, A100, partition_of_ones(A100)).makespan / f
            )
            sig["best"].append(fix_part_best(ts, A100)[0].makespan / f)
            sig["whole"].append(
                fix_part(ts, A100, partition_whole(A100)).makespan / f
            )
        rows.add(
            cfg.name,
            *(float(np.mean(sig[k])) for k in ("miso", "ones", "best",
                                               "whole")),
            str(PAPER[(scaling, times)]),
        )
    return rows
