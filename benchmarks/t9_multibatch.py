"""Paper Table 9: multi-batch error vs the offline area lower bound.

p = (makespan / baseline_multibatch - 1)·100 over a long chain of FAR
batches spliced online.  NOTE (EXPERIMENTS.md): the paper reports 84-95%
here, which is inconsistent with its own per-batch ρ ≤ 1.08 under any
work-conserving concatenation (trivial chaining of batches with ρ≈1.05
yields p≈5-25%); our concatenation is work-conserving, so our numbers are
far lower.  We report trivial vs move_swap to isolate the seam gain."""

import numpy as np

from repro.core.device_spec import A100
from repro.core.multibatch import MultiBatchScheduler
from repro.core.policy import SchedulerConfig, get_policy
from repro.core.synth import generate_tasks, workload

from benchmarks.common import Rows


def run(reps: int = 0, n_batches: int = 60) -> Rows:
    rows = Rows(
        "Table 9: multi-batch p vs offline lower bound (A100, WideTimes)",
        ["config", "n", "p_trivial_%", "p_move/swap_%", "paper_%"],
    )
    paper = {("poor", 10): 84.42, ("poor", 20): 95.21, ("poor", 30): 92.32,
             ("mixed", 10): 89.56, ("mixed", 20): 93.01,
             ("mixed", 30): 90.21,
             ("good", 10): 82.67, ("good", 20): 94.46, ("good", 30): 92.32}
    for scaling in ("poor", "mixed", "good"):
        cfg = workload(scaling, "wide", A100)
        for n in (10, 20, 30):
            batches = [
                generate_tasks(n, A100, cfg, seed=s, id_offset=10_000 * s)
                for s in range(n_batches)
            ]
            flat = [t for b in batches for t in b]
            lb = get_policy("lower-bound").plan(flat, A100).makespan
            out = {}
            for mode in ("trivial", "move_swap"):
                mb = MultiBatchScheduler(
                    A100, config=SchedulerConfig(concat_mode=mode)
                )
                for b in batches:
                    mb.add_batch(b)
                out[mode] = (mb.makespan / lb - 1) * 100
            rows.add(f"{scaling}Scaling", n, out["trivial"],
                     out["move_swap"], paper[(scaling, n)])
    return rows
