"""Roofline table from the dry-run reports (EXPERIMENTS.md §Roofline) and
the TPU-pod scheduling benchmark that consumes it.

Reads ``reports/dryrun/*__single.json`` (written by
``python -m repro.launch.dryrun --all``), prints the three roofline terms
per (arch × shape), the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS and the
MFU upper bound; then schedules a mixed job set on the TPU pod with FAR
using the cost model calibrated to the same constants."""

import glob
import json
import os

from repro.configs import ARCHS
from repro.core.costmodel import Job, job_to_task
from repro.core.device_spec import TPU_POD_256
from repro.core.far import rho, schedule_batch
from repro.models.config import SHAPES

from benchmarks.common import Rows

_BASE = os.path.join(os.path.dirname(__file__), "..", "reports")
# prefer the final (post-§Perf) dry-run reports; fall back to the baseline
REPORT_DIR = (
    os.path.join(_BASE, "dryrun_final")
    if os.path.isdir(os.path.join(_BASE, "dryrun_final"))
    else os.path.join(_BASE, "dryrun")
)


def run(reps: int = 0) -> Rows:
    rows = Rows(
        "Roofline (single pod, per device): terms in seconds/step",
        ["arch", "shape", "compute", "memory", "collective", "bottleneck",
         "useful/hlo", "mfu_ub", "fits_hbm"],
    )
    files = sorted(glob.glob(os.path.join(REPORT_DIR, "*__single.json")))
    if not files:
        rows.add("(run `python -m repro.launch.dryrun --all` first)",
                 "", "", "", "", "", "", "")
        return rows
    for path in files:
        with open(path) as f:
            rep = json.load(f)
        if rep.get("status") != "ok":
            rows.add(rep["arch"], rep["shape"], "-", "-", "-",
                     rep.get("status"), "-", "-",
                     rep.get("reason", rep.get("error", ""))[:40])
            continue
        t = rep["roofline_s"]
        rows.add(
            rep["arch"], rep["shape"], t["compute"], t["memory"],
            t["collective"], rep["bottleneck"],
            rep["useful_flops_ratio"], rep["mfu_upper_bound"],
            rep["fits_hbm"],
        )
    return rows


def run_far_on_pod(reps: int = 0) -> Rows:
    """FAR scheduling a mixed (arch × shape) job set on the TPU pod."""
    rows = Rows(
        "FAR on TPU_POD_256: mixed production job set",
        ["jobs", "makespan_s", "rho", "alloc_sizes"],
    )
    jobs = []
    jid = 0
    for arch in ("qwen2.5-3b", "gemma3-12b", "qwen2-moe-a2.7b",
                 "zamba2-2.7b", "xlstm-350m", "whisper-small"):
        for shape in ("train_4k", "decode_32k"):
            jobs.append(Job(jid, ARCHS[arch], SHAPES[shape],
                            steps=200 + 50 * jid))
            jid += 1
    tasks = [job_to_task(j, TPU_POD_256) for j in jobs]
    res = schedule_batch(tasks, TPU_POD_256)
    sizes = sorted(
        (it.task.name.split("/")[0], it.size) for it in res.schedule.items
    )
    rows.add(len(jobs), res.makespan, rho(res, tasks),
             " ".join(f"{n}:{s}" for n, s in sizes[:6]) + " …")
    return rows
