"""Sharded async serving vs the synchronous service at trace scale.

Streams 10^4-10^5 tasks from the deterministic trace harness
(:mod:`repro.core.traces` — seeded Poisson/bursty/diurnal arrival mixes
with capped heavy-tailed durations) through both serving frontends on
the same four-device pool:

* **sync** — ``SchedulingService``: planning runs inline inside
  ``submit`` whenever a batch fires, so the submit-path p99 is a planner
  flush;
* **sharded** — ``ShardedSchedulingService(defer=True)``: ``submit`` is
  the fast admission path only (shard pick + inbox append), planning
  happens in ``pump()`` off the submit path, work-stealing between the
  shard inboxes.

Reported per ``(mix, n)`` entry: sustained tasks/sec (total ingest wall
time, pumps included — the planning work does not disappear, it just
moves off the submit path), p50/p99 *decision latency* (wall time of
each ``submit`` call), peak/mean queue depth at the pump cadence, and
the p99 speedup of the fast path over the synchronous submit.  The
acceptance gate asserted here: on the 10^5-task stream the sharded p99
decision latency is **>= 5x** below the synchronous p99 at the same
arrival rate.  Each entry also records the trace digest prefix (over
the first 10k events) so the stream is pinned to ``(seed, mix, n)``.

Emits ``BENCH_scale.json``.  ``--quick`` shrinks the streams for the CI
bench-smoke job (the acceptance ratio is asserted at every size).
"""

import argparse
import json
import os
import time

import numpy as np

from repro.core.cluster import cluster
from repro.core.device_spec import A30, A100
from repro.core.policy import SchedulerConfig
from repro.core.service import SchedulingService
from repro.core.sharded import ShardedSchedulingService
from repro.core.traces import TraceSpec, trace_digest, trace_events

from benchmarks.common import Rows

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_scale.json")

POOL = cluster(A100, A30, A30, A100)
PUMP_EVERY = 256
MIN_P99_SPEEDUP = 5.0


def _cfg() -> SchedulerConfig:
    return SchedulerConfig(max_wait_s=10.0, max_batch=64, min_batch=2,
                           replan=False)


def _run_sync(spec: TraceSpec) -> dict:
    svc = SchedulingService(pool=POOL, policy="auto-serve", config=_cfg())
    lat = []
    t0 = time.perf_counter()
    for ev in trace_events(POOL, spec):
        s = time.perf_counter()
        svc.submit(ev.task, arrival=ev.arrival)
        lat.append(time.perf_counter() - s)
    svc.drain()
    wall = time.perf_counter() - t0
    return {"svc": svc, "wall_s": wall, "lat": np.asarray(lat)}


def _run_sharded(spec: TraceSpec, shards: int) -> dict:
    svc = ShardedSchedulingService(POOL, shards=shards, policy="auto-serve",
                                   config=_cfg(), defer=True)
    t0 = time.perf_counter()
    i = 0
    for ev in trace_events(POOL, spec):
        svc.submit(ev.task, arrival=ev.arrival)
        i += 1
        if i % PUMP_EVERY == 0:
            svc.pump(ev.arrival)
    svc.drain()
    wall = time.perf_counter() - t0
    return {"svc": svc, "wall_s": wall,
            "lat": np.asarray(svc.scale.admit_wall_s())}


def _entry(mix: str, n: int, shards: int, seed: int = 2026) -> dict:
    spec = TraceSpec(seed=seed, mix=mix, n=n, rate=8.0)
    sync = _run_sync(spec)
    shard = _run_sharded(spec, shards)
    sync_lat_us = sync["lat"] * 1e6
    shard_lat_us = shard["lat"] * 1e6
    p99_sync = float(np.percentile(sync_lat_us, 99))
    p99_shard = float(np.percentile(shard_lat_us, 99))
    speedup = p99_sync / p99_shard if p99_shard > 0 else float("inf")
    assert speedup >= MIN_P99_SPEEDUP, (
        f"{mix}/n={n}: sharded p99 decision latency {p99_shard:.1f}us is "
        f"only {speedup:.1f}x below sync {p99_sync:.1f}us "
        f"(gate: >= {MIN_P99_SPEEDUP}x)"
    )
    depths = [d for _, d in shard["svc"].scale.queue_depths]
    placed = sum(len(s.items) for s in (
        shard["svc"].shard_schedules()))
    assert placed == n, f"{mix}/n={n}: placed {placed} of {n} tasks"
    return {
        "mix": mix,
        "n_tasks": n,
        "rate_per_s": spec.rate,
        "seed": seed,
        "shards": shards,
        "pump_every": PUMP_EVERY,
        "trace_digest_10k": trace_digest(POOL, spec, limit=10_000)[:16],
        "sync_tasks_per_s": n / sync["wall_s"],
        "sharded_tasks_per_s": n / shard["wall_s"],
        "sync_decision_us_p50": float(np.percentile(sync_lat_us, 50)),
        "sync_decision_us_p99": p99_sync,
        "sharded_decision_us_p50": float(np.percentile(shard_lat_us, 50)),
        "sharded_decision_us_p99": p99_shard,
        "p99_speedup": speedup,
        "queue_depth_peak": int(max(depths)) if depths else 0,
        "queue_depth_mean": float(np.mean(depths)) if depths else 0.0,
        "steals": shard["svc"].scale.steals,
        "pumps": shard["svc"].scale.pumps,
    }


def run(reps: int = 0, quick: bool = False) -> Rows:
    sizes = {
        "poisson": 20_000 if quick else 100_000,
        "bursty": 10_000 if quick else 30_000,
        "diurnal": 10_000 if quick else 30_000,
    }
    entries = [_entry(mix, n, shards=2) for mix, n in sizes.items()]
    report = {
        "pool": "A100+A30+A30+A100",
        "metric": (
            "sync vs sharded-deferred serving on deterministic traces: "
            "sustained tasks/s, submit-path decision latency p50/p99 "
            "(us), queue depth at the pump cadence; gate asserted: "
            f"sharded p99 >= {MIN_P99_SPEEDUP}x below sync p99"
        ),
        "entries": entries,
    }
    with open(JSON_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    rows = Rows(
        "Sharded async serving vs sync at trace scale",
        ["mix", "n", "sync_t/s", "shard_t/s", "sync_p99_us",
         "shard_p99_us", "p99_speedup", "q_peak", "steals"],
    )
    for e in entries:
        rows.add(e["mix"], e["n_tasks"], e["sync_tasks_per_s"],
                 e["sharded_tasks_per_s"], e["sync_decision_us_p99"],
                 e["sharded_decision_us_p99"], e["p99_speedup"],
                 e["queue_depth_peak"], e["steals"])
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="smaller streams (CI bench-smoke)")
    args = ap.parse_args()
    print(run(quick=args.quick).render())
