"""Fault-tolerant serving: closed-loop feedback vs the open-loop plan.

Sweeps the deterministic fault injector over a Poisson arrival stream
with per-task deadlines and scores the closed loop (runtime feedback:
completion/failure reports, implicit straggler detection, retry with
backoff, device loss + recovery) against the open-loop counterfactual —
the same frozen plan executed under the *same* seeded faults with no
feedback and no retries.  Emits ``BENCH_faults.json``:

* deadline miss-rate vs fault rate, closed vs open loop (the closed
  loop must do strictly better on the straggler stream — asserted);
* makespan overhead of the faults (last completion vs the no-fault
  plan's makespan);
* recovery latency p50/p95 on device-loss streams (how far an outage
  pushes the placements it withdraws);
* retry amplification (total attempts per submitted task);
* hardening entries: the speculative-backup + checkpoint-credit loop
  vs the stretch-only closed loop under identical seeded draws, on a
  straggler stream and a correlated domain-outage stream — the
  hardened loop must be strictly better on BOTH miss-rate and
  makespan (asserted).

CLI: ``PYTHONPATH=src python -m benchmarks.t_faults [--quick]``
"""

import argparse
import dataclasses
import json
import os

import numpy as np

from repro.core.device_spec import A30, A100
from repro.core.cluster import cluster
from repro.core.faults import (
    FaultInjector,
    FaultSpec,
    RetryPolicy,
    SpeculationPolicy,
    execute_open_loop,
    run_with_faults,
)
from repro.core.policy import SchedulerConfig
from repro.core.service import SchedulingService
from repro.core.synth import generate_tasks, workload

from benchmarks.common import Rows

JSON_PATH = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_faults.json")

MAX_WAIT_S = 5.0
STRAGGLER_FACTOR = 2.0


def _stream(n, seed, mean_gap=1.0, slack=150.0, checkpoint_s=None):
    cfg = workload("mixed", "wide", A100)
    tasks = generate_tasks(n, A100, cfg, seed=seed)
    if checkpoint_s is not None:
        tasks = [dataclasses.replace(t, checkpoint_period_s=checkpoint_s)
                 for t in tasks]
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(mean_gap, size=n))
    stream, deadlines = [], {}
    for t, a in zip(tasks, arrivals):
        dl = float(a) + slack
        deadlines[t.id] = dl
        stream.append((float(a), t, dl))
    return stream, deadlines


def _closed_cfg():
    return SchedulerConfig(
        max_wait_s=MAX_WAIT_S, max_batch=8, min_batch=2, replan=True,
        straggler_factor=STRAGGLER_FACTOR,
        retry=RetryPolicy(max_attempts=3, backoff_base=0.5),
    )


def _hardened_cfg():
    """The stretch-only closed loop plus speculative backups; checkpoint
    credit rides on the stream (``checkpoint_period_s`` per task)."""
    cfg = _closed_cfg()
    return dataclasses.replace(cfg, speculation=SpeculationPolicy())


def _versus_entry(n, seed, fspec: FaultSpec, slack, pool=None,
                  checkpoint_s=2.0, label="") -> dict:
    """One hardening comparison: the PR 6 stretch-only closed loop vs
    the speculation + checkpoint-credit loop, same arrivals, same seeded
    draws.  The only stream difference is ``checkpoint_period_s`` on the
    hardened tasks (ids, profiles, and therefore all injector draws for
    first attempts are identical)."""

    def make(cfg):
        if pool is not None:
            return SchedulingService(pool=cluster(*pool), config=cfg)
        return SchedulingService(A100, config=cfg)

    stream, deadlines = _stream(n, seed, slack=slack)
    base = make(_closed_cfg())
    base_rep = run_with_faults(base, stream, injector=FaultInjector(fspec))

    hstream, hdeadlines = _stream(n, seed, slack=slack,
                                  checkpoint_s=checkpoint_s)
    hard = make(_hardened_cfg())
    hard_rep = run_with_faults(hard, hstream, injector=FaultInjector(fspec))

    for svc, rep in ((base, base_rep), (hard, hard_rep)):
        resolved = (set(rep.completions) | set(rep.failed)
                    | set(svc.stats.rejected))
        missing = {t.id for _, t, _ in stream} - resolved
        assert not missing, f"{label}: stranded tasks {sorted(missing)}"

    base_mk = max(list(base_rep.completions.values()) or [0.0])
    hard_mk = max(list(hard_rep.completions.values()) or [0.0])
    spec_wins = sum(1 for ev in hard.stats.speculations
                    if ev.winner == "backup")
    return {
        "label": label,
        "n_tasks": n,
        "pool": "+".join(s.name for s in pool) if pool else "A100",
        "fault_seed": fspec.seed,
        "slack_s": slack,
        "checkpoint_period_s": checkpoint_s,
        "domains": list(map(list, fspec.domains)),
        "miss_rate_stretch_only": base_rep.miss_rate(deadlines),
        "miss_rate_hardened": hard_rep.miss_rate(hdeadlines),
        "makespan_stretch_only": base_mk,
        "makespan_hardened": hard_mk,
        "speculations_launched": len(hard.stats.speculations),
        "speculation_wins": spec_wins,
        "checkpoints_banked": len(hard.stats.checkpoints),
        "outages": len(hard.stats.outages),
    }


def _entry(n, seed, fspec: FaultSpec, pool=False, label="") -> dict:
    """One fault configuration: open-loop vs closed-loop under the same
    seeded draws."""
    stream, deadlines = _stream(n, seed)
    tasks = [t for _, t, _ in stream]

    def make(cfg):
        if pool:
            return SchedulingService(pool=cluster(A100, A30), config=cfg)
        return SchedulingService(A100, config=cfg)

    # the no-fault plan: the open loop executes it frozen; its makespan
    # is the overhead baseline for both loops
    ref = make(SchedulerConfig(max_wait_s=MAX_WAIT_S, max_batch=8,
                               min_batch=2))
    for a, t, dl in stream:
        ref.submit(t, arrival=a, deadline=dl)
    plan = ref.drain()
    open_rep = execute_open_loop(plan, FaultInjector(fspec))

    svc = make(_closed_cfg())
    closed_rep = run_with_faults(svc, stream, injector=FaultInjector(fspec))

    # no stranding: every submitted task ends resolved — completed,
    # permanently failed, or explicitly rejected (parked through an
    # unrecovered outage)
    resolved = (set(closed_rep.completions) | set(closed_rep.failed)
                | set(svc.stats.rejected))
    missing = {t.id for t in tasks} - resolved
    assert not missing, f"closed loop stranded tasks {sorted(missing)}"

    plan_mk = max((it.end for it in plan.items), default=0.0)
    closed_mk = max(
        list(closed_rep.completions.values()) or [0.0])
    open_mk = max(list(open_rep.completions.values()) or [0.0])
    lat = sorted(closed_rep.recovery_latency)
    attempts = n + len(svc.stats.retries)
    return {
        "label": label,
        "n_tasks": n,
        "pool": "A100+A30" if pool else "A100",
        "fault_seed": fspec.seed,
        "task_fail_rate": fspec.task_fail_rate,
        "straggler_prob": fspec.straggler_prob,
        "noise_sigma": fspec.noise_sigma,
        "device_mtbf_s": fspec.device_mtbf_s,
        "miss_rate_open": open_rep.miss_rate(deadlines),
        "miss_rate_closed": closed_rep.miss_rate(deadlines),
        "open_failed": len(open_rep.failed),
        "closed_failed": len(closed_rep.failed),
        "rejected": len(svc.stats.rejected),
        "makespan_nofault": plan_mk,
        "makespan_overhead_closed": float(closed_mk / plan_mk),
        "makespan_overhead_open": float(open_mk / plan_mk),
        "stragglers_detected": svc.stats.stragglers,
        "corrections": len(svc.stats.corrections),
        "outages": len(svc.stats.outages),
        "recovery_latency_p50": float(np.percentile(lat, 50)) if lat
        else None,
        "recovery_latency_p95": float(np.percentile(lat, 95)) if lat
        else None,
        "retry_amplification": float(attempts / n),
        "harness_events": closed_rep.events,
    }


def run(quick: bool = False, reps: int | None = None) -> Rows:
    n = 16 if quick else 32
    entries = [
        # control: injector off — the closed loop must be a no-op layer
        _entry(n, seed=31, fspec=FaultSpec(seed=4), label="no-fault"),
        # stragglers only: feedback's cleanest win (re-plan around the
        # slow attempt instead of queueing behind it)
        _entry(n, seed=31,
               fspec=FaultSpec(seed=7, straggler_prob=0.25,
                               straggler_factor=4.0),
               label="stragglers"),
        # task failures at increasing rates: retry path + backoff
        _entry(n, seed=31,
               fspec=FaultSpec(seed=4, task_fail_rate=0.005,
                               noise_sigma=0.05),
               label="fail-lo"),
        _entry(n, seed=31,
               fspec=FaultSpec(seed=4, task_fail_rate=0.02,
                               noise_sigma=0.05),
               label="fail-hi"),
        # device loss on a two-device pool: quarantine + re-partition +
        # recovery (the recovery-latency percentiles come from here)
        _entry(n, seed=31, pool=True,
               fspec=FaultSpec(seed=5, noise_sigma=0.05,
                               straggler_prob=0.1, task_fail_rate=0.005,
                               device_mtbf_s=60.0, device_repair_s=20.0),
               label="device-loss"),
    ]
    if not quick:
        entries.append(_entry(
            n, seed=8,
            fspec=FaultSpec(seed=7, straggler_prob=0.25,
                            straggler_factor=4.0, task_fail_rate=0.01,
                            noise_sigma=0.1),
            label="combined"))

    ctl = entries[0]
    # with the injector off the feedback layer must be a pure no-op:
    # nothing corrected, nothing retried, nothing lost (plan-level
    # bit-identity vs the feedback-free service is pinned in
    # tests/test_faults.py)
    assert ctl["corrections"] == 0 and ctl["stragglers_detected"] == 0, \
        "control entry must not trigger any correction"
    assert ctl["closed_failed"] == 0 and ctl["retry_amplification"] == 1.0
    assert ctl["makespan_overhead_open"] == 1.0
    strag = entries[1]
    # the acceptance bar: feedback strictly beats the frozen plan on the
    # straggler stream (same seeded faults)
    assert strag["miss_rate_closed"] < strag["miss_rate_open"], (
        f"closed loop must beat open loop on stragglers: "
        f"{strag['miss_rate_closed']} !< {strag['miss_rate_open']}")

    # hardening: speculation + checkpoint credit vs the stretch-only
    # loop, on a straggler stream and a correlated domain-outage stream
    hardening = [
        _versus_entry(
            16 if quick else 32, seed=31,
            fspec=FaultSpec(seed=7, straggler_prob=0.25,
                            straggler_factor=4.0),
            slack=300.0 if quick else 550.0,
            label="spec-ckpt-stragglers"),
        _versus_entry(
            16 if quick else 24, seed=31,
            fspec=FaultSpec(seed=3, noise_sigma=0.05, task_fail_rate=0.01,
                            domains=((1, 2),), domain_mtbf_s=30.0,
                            domain_repair_s=10.0),
            slack=100.0, pool=(A100, A30, A30),
            label="spec-ckpt-domain"),
    ]
    for h in hardening:
        # the acceptance bar: strictly better on BOTH metrics
        assert h["miss_rate_hardened"] < h["miss_rate_stretch_only"], (
            f"{h['label']}: hardened loop must strictly cut the miss "
            f"rate: {h['miss_rate_hardened']} !< "
            f"{h['miss_rate_stretch_only']}")
        assert h["makespan_hardened"] < h["makespan_stretch_only"], (
            f"{h['label']}: hardened loop must strictly cut the "
            f"makespan: {h['makespan_hardened']} !< "
            f"{h['makespan_stretch_only']}")
    assert hardening[0]["speculation_wins"] >= 1, \
        "straggler stream must resolve at least one race for the backup"
    assert hardening[1]["checkpoints_banked"] >= 1, \
        "domain outages must bank checkpoint credit"
    assert hardening[1]["outages"] >= 2, \
        "the correlated domain must shock both members"

    report = {
        "device": "A100 (+A30 pool for device-loss entries)",
        "metric": "closed-loop serving (feedback/retry/quarantine) vs "
                  "open-loop frozen plan under identical seeded faults; "
                  "miss-rate, makespan overhead, recovery latency, "
                  "retry amplification",
        "note": "the open-loop executor has no device-loss model (a "
                "frozen plan cannot react to one), so on device-loss "
                "entries its miss-rate is optimistic — compare loops on "
                "the task-fault streams, and read the device-loss "
                "entries for recovery latency and no-stranding",
        "max_wait_s": MAX_WAIT_S,
        "straggler_factor": STRAGGLER_FACTOR,
        "entries": entries,
        "hardening": hardening,
    }
    with open(JSON_PATH, "w") as fh:
        json.dump(report, fh, indent=2)

    rows = Rows(
        "Fault injection: closed loop vs open loop (deterministic seeds)",
        ["stream", "pool", "fail_rate", "strag_p", "miss%_open",
         "miss%_closed", "mk_ovh_closed", "retries_x", "recov_p95_s"],
    )
    for e in entries:
        rows.add(e["label"], e["pool"], e["task_fail_rate"],
                 e["straggler_prob"], 100 * e["miss_rate_open"],
                 100 * e["miss_rate_closed"],
                 e["makespan_overhead_closed"],
                 e["retry_amplification"],
                 e["recovery_latency_p95"] if e["recovery_latency_p95"]
                 is not None else float("nan"))
    hrows = Rows(
        "Hardening: speculation + checkpoint credit vs stretch-only "
        "closed loop (identical seeded draws)",
        ["stream", "pool", "miss%_stretch", "miss%_hardened",
         "mk_stretch", "mk_hardened", "specs", "spec_wins", "ckpts"],
    )
    for h in hardening:
        hrows.add(h["label"], h["pool"],
                  100 * h["miss_rate_stretch_only"],
                  100 * h["miss_rate_hardened"],
                  h["makespan_stretch_only"], h["makespan_hardened"],
                  h["speculations_launched"], h["speculation_wins"],
                  h["checkpoints_banked"])
    rows.extra = hrows
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true",
                    help="small sweep (CI bench-smoke)")
    args = ap.parse_args()
    print(run(quick=args.quick).render())
