"""Paper Table 6: refinement (phase 3) contribution p_ref and op counts."""

import numpy as np

from repro.core.device_spec import A100
from repro.core.far import schedule_batch
from repro.core.policy import SchedulerConfig
from repro.core.synth import ALL_WORKLOADS, generate_tasks, workload

from benchmarks.common import Rows

PAPER_PREF = {
    ("poor", "narrow"): (0.31, 13.15, 11.45),
    ("poor", "wide"): (0.28, 14.98, 8.76),
    ("mixed", "narrow"): (0.76, 13.87, 9.04),
    ("mixed", "wide"): (3.21, 11.45, 9.01),
    ("good", "narrow"): (0.78, 13.44, 7.54),
    ("good", "wide"): (1.34, 12.56, 9.32),
}


def run(reps: int = 100) -> Rows:
    rows = Rows(
        "Table 6: refinement contribution (A100)",
        ["workload", "n", "p_ref_%", "moves", "swaps", "paper_p_ref"],
    )
    for scaling, times in ALL_WORKLOADS:
        cfg = workload(scaling, times, A100)
        for idx, n in enumerate((10, 20, 30)):
            prefs, moves, swaps = [], [], []
            for seed in range(reps):
                ts = generate_tasks(n, A100, cfg, seed=seed)
                r_no = schedule_batch(ts, A100, SchedulerConfig(refine=False))
                r_yes = schedule_batch(ts, A100, SchedulerConfig(refine=True))
                prefs.append(
                    (r_no.makespan / r_yes.makespan - 1.0) * 100
                )
                moves.append(r_yes.refine_stats.moves)
                swaps.append(r_yes.refine_stats.swaps)
            rows.add(cfg.name, n, float(np.mean(prefs)),
                     float(np.mean(moves)), float(np.mean(swaps)),
                     PAPER_PREF[(scaling, times)][idx])
    return rows
