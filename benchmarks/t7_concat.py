"""Paper Tables 7+8: concatenation contribution at batch seams.

Pairwise protocol: schedule B_k and B_{k+1} with FAR, commit B_k, then
splice B_{k+1} three ways — trivial barrier, reversed with per-slice
overlap (§4.2), reversed + seam move/swap (§4.3) — and report the
improvement percentages and the number of seam operations."""

import numpy as np

from repro.core.device_spec import A100
from repro.core.far import schedule_batch
from repro.core.multibatch import Tail, concatenate
from repro.core.synth import ALL_WORKLOADS, generate_tasks, workload

from benchmarks.common import Rows


def run(reps: int = 100) -> Rows:
    rows = Rows(
        "Tables 7+8: seam concatenation (A100, pairwise)",
        ["workload", "n", "p_rev_%", "p_move/swap_%", "moves", "swaps"],
    )
    for scaling, times in ALL_WORKLOADS:
        cfg = workload(scaling, times, A100)
        for n in (10, 20, 30):
            p_rev, p_ms, nm, ns = [], [], [], []
            for seed in range(reps):
                b1 = generate_tasks(n, A100, cfg, seed=2 * seed)
                b2 = generate_tasks(n, A100, cfg, seed=2 * seed + 1,
                                    id_offset=1000)
                f1 = schedule_batch(b1, A100)
                tail = concatenate(
                    f1.assignment, Tail.empty(A100), mode="reverse",
                    reverse=False,
                ).tail
                f2 = schedule_batch(b2, A100)
                triv = concatenate(f2.assignment, tail, mode="trivial")
                rev = concatenate(f2.assignment, tail, mode="reverse",
                                  reverse=True)
                ms = concatenate(f2.assignment, tail, mode="move_swap",
                                 reverse=True)
                t = triv.schedule.makespan
                p_rev.append((t / rev.schedule.makespan - 1) * 100)
                p_ms.append((t / ms.schedule.makespan - 1) * 100)
                nm.append(ms.moves)
                ns.append(ms.swaps)
            rows.add(cfg.name, n, float(np.mean(p_rev)),
                     float(np.mean(p_ms)), float(np.mean(nm)),
                     float(np.mean(ns)))
    return rows
